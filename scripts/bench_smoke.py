#!/usr/bin/env python
"""CI bench-smoke regression gate for the steady-state tick cost.

Runs ``benchmarks/fig8_throughput.py`` in quick measured mode (the
sharded measured workload is identical to the full mode, so its schedule
metrics are deterministic) and diffs the regenerated
``measured_engine_sharded`` block against the committed
``BENCH_fig8.json``.  Fails (non-zero exit) when a PR silently
re-inflates the tick:

  * ``ticks_per_timestep`` of the overlapped schedule must stay exactly
    1.0 — one ring tick per executed global timestep, admission
    timesteps included;
  * overlapped ``hops_per_timestep`` must not exceed the committed
    baseline (1 hop per tick; the flush schedule must still span
    ``n_stages`` hops so the two regimes stay distinguishable);
  * the measured ctrl-active rate must not inflate past the committed
    baseline (tolerance ``--rate-slack``, default 0.05): the gated ctrl
    channel must keep closing on quiet ticks;
  * admission prefill must keep riding the tick —
    ``separate_prefill_dispatches == 0`` and ``prefill_in_ring`` > 0;
  * the flush / overlapped / ungated / paged schedules must stay
    token-for-token ``bit_identical``;
  * the quantized KV arena must keep its capacity win — int8
    bytes-per-slot ≤ 0.55x fp32 (≥1.9x slots at an equal byte budget);
  * the paged overlapped schedule must keep ``ticks_per_timestep`` at
    exactly 1.0 while its prompts stream through the ring in chunks
    (``prefill_chunks`` > admissions, 0 separate prefill dispatches);
  * the paged allocator must keep its fixed-HBM-budget capacity win —
    ≥1.5x the dense slot count (measured through the real
    ``PagedKVArena`` admission fit-check) and fewer bytes per active
    token;
  * the async free-running schedule must keep its message accounting
    exact — every entry message steps every stage exactly once
    (``stage_steps == entry_msgs * mesh_stages``), empty timesteps push
    nothing (``entry_msgs <= timesteps``), and the disaggregated draft
    actor actually runs ahead of commits (``max_draft_lead >= 1``); its
    tokens are covered by the same ``bit_identical`` gate as the
    lockstep schedules.

Wall-clock numbers (``tick_cost_s``) are reported but never gated —
runner noise is not a regression.  The regenerated JSON is written to
``--out`` (uploaded as a workflow artifact by the CI job) so a failing
run leaves the evidence behind.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def check(baseline: dict, fresh: dict, rate_slack: float):
    errors = []

    def gate(cond: bool, msg: str):
        print(("  ok   " if cond else "  FAIL ") + msg)
        if not cond:
            errors.append(msg)

    base = baseline["measured_engine_sharded"]
    new = fresh["measured_engine_sharded"]
    over_b, over_n = base["overlapped"], new["overlapped"]

    gate(new["bit_identical"],
         "flush/overlapped/ungated schedules bit-identical")
    gate(over_n["ticks_per_timestep"] == 1.0,
         f"overlapped ticks_per_timestep == 1.0 "
         f"(got {over_n['ticks_per_timestep']})")
    gate(over_n["hops_per_timestep"] <= over_b["hops_per_timestep"] + 1e-9,
         f"overlapped hops_per_timestep {over_n['hops_per_timestep']} <= "
         f"baseline {over_b['hops_per_timestep']}")
    gate(new["flush"]["hops_per_timestep"] >= new["mesh_stages"],
         f"flush still spans n_stages hops "
         f"(got {new['flush']['hops_per_timestep']}, "
         f"mesh {new['mesh_stages']})")
    gate(over_n["ctrl_active_rate"]
         <= over_b["ctrl_active_rate"] + rate_slack,
         f"ctrl-active rate {over_n['ctrl_active_rate']} <= baseline "
         f"{over_b['ctrl_active_rate']} + {rate_slack}")
    gate(over_n["ctrl_active_rate"] < 1.0,
         "gated ctrl closes on some ticks")
    gate(over_n["separate_prefill_dispatches"] == 0,
         "no separate prefill dispatches on the overlapped backend")
    gate(over_n["dispatch_counts"].get("prefill_in_ring", 0) > 0,
         "admissions prefilled in-ring")

    arena = fresh["arena_bytes_per_slot"]
    gate(arena["ratio"] <= 0.55,
         f"int8 arena bytes/slot ratio {arena['ratio']} <= 0.55 "
         f"(int8 {arena['int8']} vs fp32 {arena['fp32']})")
    gate(arena["slots_multiplier"] >= 1.9,
         f"int8 arena slots multiplier {arena['slots_multiplier']} >= 1.9")

    # paged arena: chunked prefill keeps the one-tick schedule, and the
    # block allocator's capacity win at a fixed HBM budget holds
    paged = new["overlapped_paged"]
    gate(paged["ticks_per_timestep"] == 1.0,
         f"paged overlapped ticks_per_timestep == 1.0 with chunked "
         f"prefill (got {paged['ticks_per_timestep']})")
    gate(paged["separate_prefill_dispatches"] == 0,
         "chunked prefill keeps long prompts in-ring (0 separate "
         "prefill dispatches)")
    gate(paged["dispatch_counts"].get("prefill_chunks", 0)
         > paged["dispatch_counts"].get("prefill_in_ring", 0),
         f"long prompts actually chunk "
         f"({paged['dispatch_counts'].get('prefill_chunks', 0)} chunks "
         f"over {paged['dispatch_counts'].get('prefill_in_ring', 0)} "
         f"admissions)")
    cap = fresh["paged_capacity"]
    gate(cap["slots_ratio"] >= 1.5,
         f"paged slots at a fixed byte budget {cap['paged_slots']} >= "
         f"1.5x dense {cap['dense_slots']} "
         f"(ratio {cap['slots_ratio']})")
    gate(cap["paged_bytes_per_active_token"]
         < cap["dense_bytes_per_active_token"],
         f"paged bytes/active-token {cap['paged_bytes_per_active_token']} "
         f"< dense {cap['dense_bytes_per_active_token']}")

    # async free-running schedule: gate only the deterministic message
    # accounting — wall-clock (timestep_cost_s) stays informational
    asy = new["async"]
    gate(asy["stage_steps"] == asy["entry_msgs"] * new["mesh_stages"],
         f"async: every entry message steps every stage exactly once "
         f"({asy['stage_steps']} stage steps == {asy['entry_msgs']} "
         f"entries x {new['mesh_stages']} stages)")
    gate(asy["ticks_per_timestep"] <= 1.0 + 1e-9,
         f"async: empty timesteps push nothing "
         f"(ticks_per_timestep {asy['ticks_per_timestep']} <= 1.0)")
    gate(asy["max_draft_lead"] >= 1,
         f"async: disaggregated draft runs ahead of commits "
         f"(max_draft_lead {asy['max_draft_lead']})")

    print(f"  info tick_cost_s gated={over_n.get('tick_cost_s')} "
          f"ungated={new['overlapped_ungated'].get('tick_cost_s')} "
          f"async_timestep={asy.get('timestep_cost_s')} "
          f"(not gated: wall-clock noise)")
    return errors


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline",
                    default=os.path.join(REPO, "BENCH_fig8.json"))
    ap.add_argument("--out", default="BENCH_fig8.regen.json",
                    help="regenerated JSON (uploaded as a CI artifact)")
    ap.add_argument("--rate-slack", type=float, default=0.05)
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)

    sys.path[:0] = [REPO, os.path.join(REPO, "src")]
    from benchmarks import fig8_throughput

    fig8_throughput.run(verbose=True, quick=True, out_json=args.out)
    with open(args.out) as f:
        fresh = json.load(f)

    print("# bench-smoke gate (fresh quick run vs committed "
          "BENCH_fig8.json)")
    errors = check(baseline, fresh, args.rate_slack)
    if errors:
        print(f"BENCH_SMOKE fail ({len(errors)} regression(s)) — the "
              f"steady-state tick got more expensive; see {args.out}")
        return 1
    print("BENCH_SMOKE ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
