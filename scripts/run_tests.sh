#!/usr/bin/env bash
# Tier-1 test entrypoint: sets PYTHONPATH=src and forwards extra args to
# pytest (e.g. scripts/run_tests.sh -k serving_db -x).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -q "$@"
