#!/usr/bin/env python
"""Docs/CLI drift gate: every ``--flag`` a doc mentions must exist.

Scans the documentation surface (README.md, docs/*.md, tests/README.md)
for ``--flag`` tokens and checks each one against the union of flags
actually defined by ``add_argument`` calls in the CLI entry points
(``launch/serve.py``, ``launch/sharded_check.py``, ``launch/train.py``,
``launch/dryrun.py``, ``scripts/bench_smoke.py``,
``benchmarks/fig8_throughput.py``).  A flag that is renamed or removed
without updating the docs fails CI here, in the lint job, before the
test jobs spend minutes reaching it.

Pure stdlib + regex on source text: the lint job that runs this has no
jax installed, so the argparse definitions are scraped, not imported.

Exit status: 0 when every documented flag exists, 1 otherwise (the
unknown flags and the closest defined names are printed).
"""
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

CLI_SOURCES = [
    "src/repro/launch/serve.py",
    "src/repro/launch/sharded_check.py",
    "src/repro/launch/train.py",
    "src/repro/launch/dryrun.py",
    "scripts/bench_smoke.py",
    "benchmarks/fig8_throughput.py",
]

DOC_SOURCES = ["README.md", "tests/README.md"]

# non-argparse flags docs legitimately mention: tool flags (pytest,
# pip, XLA) that are not this repo's CLI surface
ALLOW = {
    "--xla_force_host_platform_device_count",
    "--upgrade",  # pip install --upgrade in quickstart snippets
    "-x", "-q", "-k", "-m",  # pytest short flags
}

FLAG_DEF_RE = re.compile(r"add_argument\(\s*['\"](--[A-Za-z][\w-]*)['\"]")
FLAG_USE_RE = re.compile(r"(?<![\w-])(--[A-Za-z][\w-]*)")


def defined_flags():
    flags = {}
    for rel in CLI_SOURCES:
        text = (REPO / rel).read_text()
        for m in FLAG_DEF_RE.finditer(text):
            flags.setdefault(m.group(1), []).append(rel)
    return flags


def doc_files():
    files = [REPO / rel for rel in DOC_SOURCES]
    files += sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def main():
    defined = defined_flags()
    if not defined:
        print("check_docs_flags: no add_argument definitions found "
              "(CLI_SOURCES stale?)")
        return 1
    bad = []
    n_mentions = 0
    for doc in doc_files():
        for ln, line in enumerate(doc.read_text().splitlines(), 1):
            for m in FLAG_USE_RE.finditer(line):
                flag = m.group(1)
                n_mentions += 1
                if flag in defined or flag in ALLOW:
                    continue
                bad.append((doc.relative_to(REPO), ln, flag))
    if bad:
        print("check_docs_flags: documented flags that no CLI defines:")
        for rel, ln, flag in bad:
            near = [f for f in defined if flag[:5] in f] or sorted(defined)
            print(f"  {rel}:{ln}: {flag}  (defined flags include: "
                  f"{', '.join(near[:4])})")
        return 1
    print(f"check_docs_flags ok: {n_mentions} flag mentions across "
          f"{len(doc_files())} docs, all defined "
          f"({len(defined)} flags in {len(CLI_SOURCES)} CLI sources)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
