"""Training example: byte-level LM with the full substrate (data pipeline,
AdamW + cosine, checkpointing).  Default config is laptop-scale; pass
``--hundred-m`` for the ~100M-parameter configuration (same code path the
dry-run lowers onto the production mesh).

    PYTHONPATH=src python examples/train_char_lm.py --steps 200
"""
import argparse

from repro.launch.train import train
from repro.models.config import ModelConfig

SMALL = ModelConfig(name="char-lm-small", family="dense", num_layers=4,
                    d_model=256, num_heads=8, num_kv_heads=4, d_ff=704,
                    vocab_size=260)

# ~100M params: 12L, d=768 (GPT-2-small-ish shape, byte vocab)
HUNDRED_M = ModelConfig(name="char-lm-100m", family="dense", num_layers=12,
                        d_model=768, num_heads=12, num_kv_heads=4, d_ff=2048,
                        vocab_size=260)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/char_lm.npz")
    args = ap.parse_args()

    cfg = HUNDRED_M if args.hundred_m else SMALL
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    _, losses = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                      lr=1e-3, ckpt=args.ckpt, log_every=20)
    assert losses[-1] < losses[0], "loss must decrease"
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
