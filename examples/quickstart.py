"""Quickstart: PipeDec in ~40 lines.

Builds a tiny target/draft pair, decodes one prompt three ways (vanilla
autoregressive, STPP static-tree, PipeDec) and checks all three emit the
IDENTICAL token sequence — speculative decoding is lossless.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core.baselines import (STPPConfig, STPPEngine,
                                  generate_autoregressive)
from repro.core.pipedec import PipeDecConfig, PipeDecEngine
from repro.core.speculative import ModelBundle
from repro.models import transformer as tf
from repro.models.config import ModelConfig

target_cfg = ModelConfig(name="target", family="dense", num_layers=4,
                         d_model=128, num_heads=8, num_kv_heads=2, d_ff=352,
                         vocab_size=512)
draft_cfg = ModelConfig(name="draft", family="dense", num_layers=2,
                        d_model=64, num_heads=4, num_kv_heads=2, d_ff=176,
                        vocab_size=512)

target = ModelBundle(tf.init_model(jax.random.PRNGKey(0), target_cfg),
                     target_cfg)
draft = ModelBundle(tf.init_model(jax.random.PRNGKey(1), draft_cfg),
                    draft_cfg)

prompt = np.array([11, 42, 7, 3, 99], np.int32)
NEW = 24

ar = generate_autoregressive(target, prompt, NEW)
print(f"autoregressive : {ar.tolist()}")

stpp, sstats = STPPEngine(target, draft,
                          STPPConfig(depth=3, width=8, branch=4)
                          ).generate(prompt, NEW)
print(f"STPP           : {stpp.tolist()}  "
      f"(accepted/round={sstats.mean_accepted:.2f})")

pipedec, pstats = PipeDecEngine(target, draft,
                                PipeDecConfig(n_stages=4, width=8, branch=4)
                                ).generate(prompt, NEW)
print(f"PipeDec        : {pipedec.tolist()}  "
      f"(acceptance={pstats.acceptance:.2f}, "
      f"tokens/timestep={pstats.tokens_per_timestep:.2f})")

assert np.array_equal(ar, stpp) and np.array_equal(ar, pipedec)
print("\nall three sequences identical — speculative decoding is lossless ✓")
