"""End-to-end serving driver (the paper is an inference system, so this is
the flagship example): train a draft/target pair on the same corpus, then
serve a batch of requests in both engine modes and compare.

    PYTHONPATH=src python examples/serve_pipedec.py [--steps 150]
"""
import argparse

import numpy as np

from repro.core.pipedec import PipeDecConfig
from repro.core.speculative import ModelBundle
from repro.data import ByteCorpus, DataConfig, synthetic_corpus
from repro.launch.train import train
from repro.models.config import ModelConfig
from repro.serving import (OverlappedShardedExecutor, Request,
                           ServingEngine, ShardedPipelineExecutor,
                           SpecPipeDBEngine)

TARGET = ModelConfig(name="srv-target", family="dense", num_layers=4,
                     d_model=256, num_heads=8, num_kv_heads=2, d_ff=704,
                     vocab_size=260)
DRAFT = ModelConfig(name="srv-draft", family="dense", num_layers=2,
                    d_model=128, num_heads=4, num_kv_heads=2, d_ff=352,
                    vocab_size=260, tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--quant", choices=["none", "int8"], default="int8",
                    help="int8: also demo the quantized serving path "
                         "(ModelBundle.quantize() — int8 weights + int8 "
                         "KV arena)")
    args = ap.parse_args()

    print(f"== training target ({TARGET.param_count()/1e6:.1f}M params) ==")
    tp, _ = train(TARGET, steps=args.steps, batch=8, seq=64, lr=1e-3,
                  seed=0, log_every=50)
    print(f"== training draft  ({DRAFT.param_count()/1e6:.1f}M params) ==")
    dp, _ = train(DRAFT, steps=args.steps, batch=8, seq=64, lr=1e-3,
                  seed=1, log_every=50)
    target, draft = ModelBundle(tp, TARGET), ModelBundle(dp, DRAFT)

    corpus = ByteCorpus(synthetic_corpus(1 << 14, seed=7),
                        DataConfig(seq_len=32, batch_size=1))
    reqs = [Request(i, corpus.example(i)[0], args.new_tokens)
            for i in range(args.requests)]

    print("\n== mode=pp (batched autoregressive) ==")
    pp = ServingEngine(target, mode="pp", max_batch=4)
    for r in reqs:
        pp.submit(r)
    pp_results = pp.run()
    for uid, res in sorted(pp_results.items()):
        print(f"  req {uid}: {res.latency_s*1e3:7.1f} ms")

    print("\n== mode=pipedec (draft-in-pipeline speculative) ==")
    pcfg = PipeDecConfig(n_stages=6, width=16, branch=4)
    pd = ServingEngine(target, draft, mode="pipedec", pipedec=pcfg)
    for r in reqs:
        pd.submit(r)
    pd_results = pd.run()
    accs = []
    for uid, res in sorted(pd_results.items()):
        accs.append(res.stats.acceptance)
        print(f"  req {uid}: {res.latency_s*1e3:7.1f} ms  "
              f"acc={res.stats.acceptance:.2f} "
              f"tokens/timestep={res.stats.tokens_per_timestep:.2f}")
        assert np.array_equal(res.tokens, pp_results[uid].tokens), \
            "PipeDec output must equal the PP output (lossless)"
    print(f"\nmean acceptance {np.mean(accs):.2f}; outputs identical to "
          f"PP for every request ✓")

    print("\n== mode=pipedec-db (SpecPipe-DB dynamic batching, staggered "
          "arrivals, streaming) ==")
    db = ServingEngine(target, draft, mode="pipedec-db", max_batch=3,
                       pipedec=pcfg)
    for r in reqs:
        # stagger arrivals: a new request every 4 pipeline timesteps
        db.submit(Request(r.uid, r.prompt, r.max_new_tokens,
                          arrival_t=4 * r.uid))
    # streaming: tokens arrive at COMMIT time (not at retire) — collect
    # (uid, token, timestep) and verify the prefix matches the final result
    streamed = {}
    db_results = db.run(
        on_token=lambda uid, tok, t: streamed.setdefault(uid, []).append(tok))
    for uid, res in sorted(db_results.items()):
        adm = db.db_stats.per_request[uid]
        print(f"  req {uid}: acc={adm.acceptance:.2f} "
              f"tokens/timestep={adm.tokens_per_timestep:.2f} "
              f"streamed={len(streamed[uid])} tokens")
        assert np.array_equal(res.tokens, pp_results[uid].tokens), \
            "SpecPipe-DB output must equal the PP output (lossless)"
        assert np.array_equal(np.asarray(streamed[uid]), res.tokens), \
            "streamed prefix must equal the final result"
    s = db.db_stats
    print(f"\nDB: {s.timesteps} shared timesteps, "
          f"{s.total_commits} tokens, "
          f"{s.tokens_per_timestep:.2f} tokens/timestep aggregate, "
          f"peak occupancy {s.peak_occupancy}; outputs identical to PP ✓")

    print("\n== executor API: same engine, pluggable compute backend ==")
    # default backend = LocalFusedExecutor (fused single-device dispatch).
    # ShardedPipelineExecutor runs the identical logical schedule on the
    # n-stage pipelined deployment (stage-partitioned target, ppermute
    # activation ring).  On a 1-device host the mesh has one stage; run
    #   XLA_FLAGS=--xla_force_host_platform_device_count=8 ...
    # (or a real multi-device host) for one stage per device.
    import jax
    sharded = ShardedPipelineExecutor(
        target, draft, slots=3, max_len=512,
        tree_capacity=pcfg.tree_buffer_capacity, capacity=pcfg.capacity,
        n_stages=len(jax.devices()))
    dbx = SpecPipeDBEngine(target, draft, pcfg, max_slots=3,
                           executor=sharded)
    for r in reqs:
        dbx.submit(Request(r.uid, r.prompt, r.max_new_tokens,
                           arrival_t=4 * r.uid))
    shard_results = dbx.run()
    for uid, res in sorted(shard_results.items()):
        assert np.array_equal(res.tokens, pp_results[uid].tokens), \
            "sharded executor output must be bit-identical too"
    print(f"  {sharded.n_stages}-stage mesh: "
          f"{dbx.stats.tokens_per_timestep:.2f} tokens/timestep, "
          f"{sharded.calls['pipeline_verify']} batched pipeline dispatches "
          f"in {dbx.stats.timesteps} timesteps; outputs identical ✓")

    print("\n== overlapped executor: one ring tick per timestep ==")
    # the steady-state schedule (launch.serve --overlap): the ring stays
    # full across timesteps, each timestep is ONE stage-hop instead of an
    # n_stages-hop flush, verify logits resolve at each layer's exit, and
    # prunes propagate in-ring — same committed tokens, paper wall-clock
    # (the flush dispatches n_stages hops per timestep, this one hop).
    # PipeDecConfig.n_stages must equal the mesh stage count: the ring IS
    # the flight bookkeeping.
    pcfg_ov = PipeDecConfig(n_stages=len(jax.devices()), width=pcfg.width,
                            branch=pcfg.branch)
    overlapped = OverlappedShardedExecutor(
        target, draft, slots=3, max_len=512,
        tree_capacity=pcfg_ov.tree_buffer_capacity,
        capacity=pcfg_ov.capacity, n_stages=len(jax.devices()))
    dbo = SpecPipeDBEngine(target, draft, pcfg_ov, max_slots=3,
                           executor=overlapped)
    for r in reqs:
        dbo.submit(Request(r.uid, r.prompt, r.max_new_tokens,
                           arrival_t=4 * r.uid))
    over_results = dbo.run()
    for uid, res in sorted(over_results.items()):
        assert np.array_equal(res.tokens, pp_results[uid].tokens), \
            "overlapped executor output must be bit-identical too"
    assert overlapped.calls["pipeline_tick"] == dbo.stats.timesteps
    rate = (overlapped.calls["ctrl_active_ticks"]
            / max(overlapped.calls["pipeline_tick"], 1))
    print(f"  {overlapped.n_stages}-stage mesh: "
          f"{dbo.stats.tokens_per_timestep:.2f} tokens/timestep, "
          f"{overlapped.calls['pipeline_tick']} ring ticks in "
          f"{dbo.stats.timesteps} timesteps (1 tick/timestep), "
          f"{overlapped.calls['kill']} in-ring kills; outputs identical ✓")
    print(f"  cheap ticks: ctrl gate open on {rate:.0%} of ticks, "
          f"{overlapped.calls['prefill_in_ring']} admissions prefilled "
          f"in-ring (0 separate prefill dispatches), ring/stage buffers "
          f"donated through the tick")

    print("\n== async executor: free-running stage actors + "
          "disaggregated draft ==")
    # launch.serve --executor async: no host lockstep.  Each stage is an
    # actor thread on its own device (round-robin when the host has fewer
    # devices than stages — no mesh needed, unlike the sharded backends)
    # pulling ring layers from a bounded inbox, applying its compiled
    # stage step, pushing to the next stage; the draft model speculates
    # continuously on its own actor.  Kill messages short-circuit stale
    # in-flight layers at whatever stage they sit instead of letting them
    # ride a full revolution.  Same committed tokens, bit-identical.
    from repro.serving import AsyncPipelineExecutor
    pcfg_as = PipeDecConfig(n_stages=4, width=pcfg.width,
                            branch=pcfg.branch)
    async_ex = AsyncPipelineExecutor(
        target, draft, slots=3, max_len=512,
        tree_capacity=pcfg_as.tree_buffer_capacity,
        capacity=pcfg_as.capacity, n_stages=pcfg_as.n_stages)
    dba = SpecPipeDBEngine(target, draft, pcfg_as, max_slots=3,
                           executor=async_ex)
    for r in reqs:
        dba.submit(Request(r.uid, r.prompt, r.max_new_tokens,
                           arrival_t=4 * r.uid))
    try:
        async_results = dba.run()
        for uid, res in sorted(async_results.items()):
            assert np.array_equal(res.tokens, pp_results[uid].tokens), \
                "async executor output must be bit-identical too"
        ctr = async_ex.counters()
    finally:
        async_ex.shutdown()
    print(f"  {async_ex.n_stages} stage actors + draft actor: "
          f"{dba.stats.tokens_per_timestep:.2f} tokens/timestep, "
          f"{async_ex.calls['entry_msgs']} entry msgs "
          f"({async_ex.calls['stage_steps']} stage steps), "
          f"{async_ex.calls['kill']} kills; outputs identical ✓")
    print(f"  draft lead: up to {ctr['max_draft_lead']} verify jobs "
          f"ahead of the committed tree")
    for k, sc in enumerate(ctr["stages"]):
        occ = sc["busy_s"] / max(sc["busy_s"] + sc["idle_s"], 1e-9)
        print(f"  stage {k}: {sc['layers']:3d} layers  "
              f"occupancy {occ:5.1%}  busy {sc['busy_s']*1e3:7.1f} ms  "
              f"idle {sc['idle_s']*1e3:7.1f} ms  "
              f"inbox depth<= {sc['max_depth']}  "
              f"stale rows {sc['stale_rows']}")

    print("\n== paged KV arena: block tables + per-tick pool counters ==")
    # --paged serving (launch.serve --paged): every KV buffer becomes a
    # physical block pool behind a per-slot block table, and admission
    # backs only each request's horizon (prompt + budget + tree slack)
    # instead of max_len rows — same bit-identical outputs, far fewer
    # bytes pinned per request.  DBStats.page_counters records the pool
    # occupancy every executed timestep.
    from repro.serving import LocalFusedExecutor
    paged_ex = LocalFusedExecutor(
        target, draft, slots=3, max_len=512,
        tree_capacity=pcfg.tree_buffer_capacity, capacity=pcfg.capacity,
        paged=True, page=32)
    dbp = SpecPipeDBEngine(target, draft, pcfg, max_slots=3,
                           executor=paged_ex)
    for r in reqs:
        dbp.submit(Request(r.uid, r.prompt, r.max_new_tokens,
                           arrival_t=4 * r.uid))
    paged_results = dbp.run()
    for uid, res in sorted(paged_results.items()):
        assert np.array_equal(res.tokens, pp_results[uid].tokens), \
            "paged arena output must be bit-identical too"
    ctrs = dbp.stats.page_counters
    peak = max(c["peak_blocks"] for c in ctrs)
    last = ctrs[-1]
    traj = [c["blocks_in_use"] for c in
            ctrs[::max(len(ctrs) // 8, 1)]][:8]
    print(f"  page=32: blocks in use per tick {traj} "
          f"(peak {peak}/{last['blocks_total']}, "
          f"frag {max(c['frag_pct'] for c in ctrs):.1f}%)")
    print(f"  swaps {last['swaps']}, preemptions {last['preemptions']}, "
          f"copy-on-expand {last['expand_copies']}; outputs identical ✓")

    if args.quant == "int8":
        print("\n== quantized serving path (--quant int8) ==")
        # ModelBundle.quantize() converts the weights ONCE (per-out-channel
        # int8) and flips every cache to the int8 KV layout; the fp32
        # bundles above are untouched.  Quantized outputs are not bitwise
        # fp32 outputs — the regression currency is the acceptance rate
        # (DBStats.accepted/proposed) and the arena bytes per slot.
        from repro.serving.scheduler import KVArena
        q_target, q_draft = target.quantize(), draft.quantize()
        dbq = ServingEngine(q_target, q_draft, mode="pipedec-db",
                            max_batch=3, pipedec=pcfg)
        for r in reqs:
            dbq.submit(Request(r.uid, r.prompt, r.max_new_tokens,
                               arrival_t=4 * r.uid))
        q_results = dbq.run()
        sq = dbq.db_stats
        exact = sum(
            bool(np.array_equal(q_results[uid].tokens, res.tokens))
            for uid, res in pp_results.items())

        def bps(t, d):
            return KVArena(t, d, slots=1, max_len=512,
                           tree_capacity=pcfg.tree_buffer_capacity
                           ).bytes_per_slot()

        fp32_b, int8_b = bps(target, draft), bps(q_target, q_draft)
        print(f"  int8: acceptance {sq.acceptance_rate:.2f} "
              f"(fp32 {s.acceptance_rate:.2f}), "
              f"{sq.tokens_per_timestep:.2f} tokens/timestep, "
              f"{exact}/{len(pp_results)} outputs equal fp32 greedy")
        print(f"  arena: {int8_b} B/slot vs {fp32_b} B/slot fp32 "
              f"({int8_b / fp32_b:.2f}x bytes -> "
              f"{fp32_b // int8_b}x slots at an equal budget)")


if __name__ == "__main__":
    main()
