"""Baseline comparison on one prompt: PP vs STPP vs PipeDec with a trained
pair — prints acceptance, tokens/timestep and the modelled Fig.-5-style
speedups for the paper's 70B/1B deployment at 7/14/21 stages.

    PYTHONPATH=src python examples/compare_baselines.py
"""
import numpy as np

from benchmarks import common
from benchmarks.fig5_latency import hardware, measure_acceptance
from repro.core import sim
from repro.core.baselines import (STPPConfig, STPPEngine,
                                  generate_autoregressive)
from repro.core.pipedec import PipeDecConfig, PipeDecEngine


def main():
    target, draft = common.trained_pair()
    prompt = common.eval_prompts(n=1, length=32)[0]
    NEW = 48

    ar = generate_autoregressive(target, prompt, NEW)
    pd, pstats = PipeDecEngine(
        target, draft, PipeDecConfig(n_stages=14, width=16, branch=4),
        max_len=256).generate(prompt, NEW)
    st, sstats = STPPEngine(
        target, draft, STPPConfig(depth=4, width=16, branch=4),
        max_len=256).generate(prompt, NEW)
    assert np.array_equal(ar, pd) and np.array_equal(ar, st)
    print(f"outputs identical across PP/STPP/PipeDec ✓")
    print(f"PipeDec: acceptance={pstats.acceptance:.2f}, "
          f"tokens/timestep={pstats.tokens_per_timestep:.2f}")
    print(f"STPP:    accepted/round={sstats.mean_accepted:.2f}")

    print("\nmodelled single-task latency (paper deployment, ms/token):")
    for stages in (7, 14, 21):
        tps, acc, stpp_acc = measure_acceptance(stages)
        hw = hardware(stages, 16)
        pp_l = sim.pp_latency_per_token(hw)
        pd_l = sim.pipedec_latency_per_token(hw, tps)
        st_l = sim.stpp_latency_per_token(hw, 4, stpp_acc)
        print(f"  {stages:2d} stages: PP {pp_l*1e3:7.2f}  "
              f"STPP {st_l*1e3:7.2f}  PipeDec {pd_l*1e3:7.2f}  "
              f"→ {pp_l/pd_l:.2f}x vs PP, {st_l/pd_l:.2f}x vs STPP")


if __name__ == "__main__":
    main()
