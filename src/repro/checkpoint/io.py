"""Checkpointing: flat-key .npz serialisation of arbitrary pytrees.

Keys encode the tree path; structure is reconstructed on load from the keys
alone (dict/list nesting), so no pickle and no schema file.  Sharded arrays
are gathered to host before save (single-host writer; multi-host would
write per-process shards — out of scope for the CPU container but the key
scheme is shard-suffix ready).
"""
from __future__ import annotations

import os
import re
from typing import Any

import jax
import numpy as np

_SEP = "||"


def _flatten(tree: Any, prefix: str = "") -> dict:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{_SEP}d:{k}" if prefix else f"d:{k}"))
    elif isinstance(tree, (list, tuple)):
        tag = "l" if isinstance(tree, list) else "t"
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{_SEP}{tag}:{i}" if prefix
                                else f"{tag}:{i}"))
    elif tree is None:
        out[prefix + _SEP + "none:" if prefix else "none:"] = np.zeros(0)
    else:
        out[prefix] = np.asarray(tree)
    return out


def save_pytree(path: str, tree: Any) -> None:
    flat = _flatten(jax.device_get(tree))
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **flat)


def _assign(root, parts, value):
    key = parts[0]
    kind, _, name = key.partition(":")
    if kind == "none":
        return None
    if len(parts) == 1:
        leaf = value
        if kind == "d":
            root[name] = leaf
        else:
            root.append(leaf)
        return root
    if kind == "d":
        child = root.setdefault(name, _container(parts[1]))
        res = _assign(child, parts[1:], value)
        if res is None:
            root[name] = None
        return root
    idx = int(name)
    while len(root) <= idx:
        root.append(_container(parts[1]))
    res = _assign(root[idx], parts[1:], value)
    if res is None:
        root[idx] = None
    return root


def _container(next_key: str):
    return {} if next_key.startswith("d:") else []


def load_pytree(path: str) -> Any:
    data = np.load(path, allow_pickle=False)
    keys = sorted(data.files)
    root = _container(keys[0].split(_SEP)[0])
    for k in keys:
        _assign(root, k.split(_SEP), data[k])
    return root
