"""Training driver: real loop on host devices (CPU tests / examples) with
the same step function the dry-run lowers for the production meshes.

  PYTHONPATH=src python -m repro.launch.train --arch gemma-7b --smoke \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfg_reg
from repro.checkpoint import save_pytree
from repro.data import ByteCorpus, DataConfig, batch_iterator, synthetic_corpus
from repro.launch.steps import make_train_step
from repro.models import transformer as tf
from repro.optim import AdamWConfig, adamw_init


def train(cfg, *, steps: int, batch: int, seq: int, lr: float = 3e-4,
          seed: int = 0, ckpt: str = "", log_every: int = 10,
          corpus_bytes: int = 1 << 18, remat: bool = False):
    """Train ``cfg`` on the synthetic byte corpus for ``steps`` steps;
    returns (params, losses) and optionally saves a checkpoint.
    """
    assert cfg.vocab_size >= 260, "byte pipeline needs vocab >= 260"
    params = tf.init_model(jax.random.PRNGKey(seed), cfg)
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=max(10, steps // 20),
                          total_steps=steps)
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=remat))

    data_cfg = DataConfig(seq_len=seq, batch_size=batch, seed=seed)
    corpus = ByteCorpus(synthetic_corpus(corpus_bytes, seed=seed), data_cfg)
    it = batch_iterator(corpus, epochs=1000)

    losses = []
    t0 = time.perf_counter()
    for i in range(steps):
        tokens, labels = next(it)
        batch_d = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        params, opt, metrics = step_fn(params, opt, batch_d)
        losses.append(float(metrics["loss"]))
        if log_every and (i % log_every == 0 or i == steps - 1):
            print(f"step {i:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.perf_counter()-t0)/(i+1):.2f}s/step)", flush=True)
    if ckpt:
        save_pytree(ckpt, {"params": params})
        print(f"saved checkpoint to {ckpt}")
    return params, losses


def main(argv=None):
    """CLI entry: train one arch (``--smoke`` for the reduced config)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pipedec-target")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config variant")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args(argv)
    cfg = cfg_reg.get_config(args.arch, smoke=args.smoke)
    if cfg.vocab_size < 260:
        import dataclasses
        cfg = dataclasses.replace(cfg, vocab_size=260)
    train(cfg, steps=args.steps, batch=args.batch, seq=args.seq, lr=args.lr,
          ckpt=args.ckpt)


if __name__ == "__main__":
    main()
