"""Multi-pod dry-run: lower + compile every (arch × input-shape)
combination on the production meshes and extract roofline terms.

The XLA_FLAGS line below MUST stay the first executable statement in
this module (jax locks the device count at first init).  Do not import
this module from tests that expect a single device — run
``python -m repro.launch.dryrun``.

Usage::

  python -m repro.launch.dryrun --arch qwen2.5-32b --shape decode_32k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.jsonl]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs as cfg_reg
from repro.launch import analysis, sharding, specs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (make_prefill_step, make_serve_step,
                                make_train_step)


def _opt_specs(param_specs_tree):
    return {
        "m": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                          param_specs_tree),
        "v": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                          param_specs_tree),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              verbose: bool = True):
    """Lower + compile one (arch, input-shape) combination on its
    production mesh and return the roofline row."""
    cfg = cfg_reg.get_config(arch)
    shape = specs.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    mesh_desc = "x".join(str(s) for s in mesh.devices.shape)
    wo = specs.window_override(cfg, shape)

    pspecs = specs.param_specs(cfg)
    pshard = sharding.params_shardings(pspecs, cfg, mesh)
    b = shape.global_batch

    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models.transformer import set_activation_sharding
    from repro.launch.mesh import data_axes

    from repro.models import moe as moe_mod
    dp_axes = data_axes(mesh)
    dp_total = 1
    for a in dp_axes:
        dp_total *= mesh.shape[a]

    with mesh:
        if cfg.moe is not None:
            # group-wise MoE dispatch: shard-local sorts, constrained buffers,
            # batch-only token sharding at block entry (§Perf H2)
            moe_mod.set_dispatch(
                groups=dp_total,
                buf_sharding=NamedSharding(mesh, P(dp_axes, "model",
                                                   None, None)),
                x_sharding=NamedSharding(mesh, P(dp_axes, None, None)))
        if shape.kind == "train":
            # sequence parallelism on the residual stream (train only)
            set_activation_sharding(
                NamedSharding(mesh, P(data_axes(mesh), "model", None)))
            step = make_train_step(cfg, window_override=wo, remat=True)
            batch = specs.input_specs(cfg, shape)
            zshard = sharding.zero1_shardings(pspecs, cfg, mesh)
            oshard = {
                "m": zshard, "v": zshard,
                "step": sharding.replicated(mesh),
            }
            bshard = {k: sharding.batch_shardings(mesh, b, v.ndim)
                      for k, v in batch.items()}
            fn = jax.jit(
                step,
                in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, None),
                donate_argnums=(0, 1),
            )
            args = (pspecs, _opt_specs(pspecs), batch)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, window_override=wo)
            ins = specs.input_specs(cfg, shape)
            cache_spec = specs.cache_specs(
                cfg, b, shape.seq_len + cfg.prefix_tokens)
            cshard = sharding.cache_shardings(cache_spec, cfg, mesh, batch=b)
            args_list = [pspecs, ins["tokens"]]
            in_sh = [pshard, sharding.batch_shardings(mesh, b, 2)]
            kwargs_map = {}
            if "prefix_embeds" in ins:
                kwargs_map["prefix_embeds"] = len(args_list)
                args_list.append(ins["prefix_embeds"])
                in_sh.append(sharding.batch_shardings(mesh, b, 3))
            if "frames" in ins:
                kwargs_map["frames"] = len(args_list)
                args_list.append(ins["frames"])
                in_sh.append(sharding.batch_shardings(mesh, b, 3))

            def wrapped(*a):
                kw = {k: a[i] for k, i in kwargs_map.items()}
                return step(a[0], a[1], **kw)

            fn = jax.jit(wrapped, in_shardings=tuple(in_sh),
                         out_shardings=(None, cshard))
            args = tuple(args_list)
        else:  # decode
            import repro.models.transformer as tf_mod
            step = make_serve_step(cfg, window_override=wo)
            ins = specs.input_specs(cfg, shape)
            # serving layout for params too: per-layer buffers (see
            # EXPERIMENTS.md §Perf H1 — avoids whole-stack converts/copies
            # hoisted ahead of the unrolled layer loop)
            pspecs = jax.eval_shape(
                lambda p: tf_mod.unstack_params(cfg, p), pspecs)
            pshard = sharding.params_shardings(pspecs, cfg, mesh)
            shard_seq = shape.name == "long_500k"
            cshard = sharding.cache_shardings(ins["cache"], cfg, mesh,
                                              batch=b, shard_seq=shard_seq)
            in_sh = [pshard, sharding.batch_shardings(mesh, b, 1), cshard,
                     sharding.replicated(mesh)]
            args_list = [pspecs, ins["token"], ins["cache"],
                         ins["cache_len"]]
            if "enc_out" in ins:
                args_list.append(ins["enc_out"])
                in_sh.append(sharding.batch_shardings(mesh, b, 3))
            fn = jax.jit(step, in_shardings=tuple(in_sh),
                         out_shardings=(None, cshard),
                         donate_argnums=(2,))
            args = tuple(args_list)

        t0 = time.time()
        lowered = fn.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        set_activation_sharding(None)
        moe_mod.set_dispatch(1, None)

    roof = analysis.analyze_compiled(
        arch, shape_name, mesh_desc, chips, lowered, compiled, cfg, shape,
        shape.kind)
    row = roof.row()
    row.update({"lower_s": round(t1 - t0, 1), "compile_s": round(t2 - t1, 1),
                "multi_pod": multi_pod})
    try:
        ma = compiled.memory_analysis()
        if verbose:
            print(f"  memory_analysis: {ma}")
    except Exception as e:  # CPU backend may not support it
        if verbose:
            print(f"  memory_analysis unavailable: {e}")
    if verbose:
        print(f"  cost: flops={row['hlo_flops']:.3e} "
              f"bytes={row['hlo_bytes']:.3e} coll={row['coll_bytes']:.3e}")
        print(f"  roofline: compute={row['t_compute_s']:.3e}s "
              f"memory={row['t_memory_s']:.3e}s "
              f"collective={row['t_collective_s']:.3e}s "
              f"-> {row['bottleneck']}-bound; "
              f"useful={row['useful_ratio']:.2f}")
    return row


def lower_pipeline_tick(arch: str, *, n_stages: int = 16, width: int = 32,
                        multi_pod: bool = False, verbose: bool = True):
    """Lower + compile the paper-faithful shard_map PipeDec tick on the
    production mesh ('model' = stage axis).  Used by §Perf."""
    import dataclasses as dc

    from repro.launch import pipeline as pl

    cfg = cfg_reg.get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    pcfg = pl.PipelineConfig(n_stages=n_stages, width=width,
                             tree_capacity=width * (n_stages + 4),
                             max_len=32768)
    pspecs = specs.param_specs(cfg)
    sp_spec, valid = (None, None)

    def build():
        params = tf_init_specs(cfg)
        return params

    # stage params via eval_shape on the reshaping
    import repro.models.transformer as tf
    stage_p = jax.eval_shape(
        lambda p: pl.stage_params(cfg, p, n_stages)[0], pspecs)
    lps, padded = pl.stage_layout(cfg, n_stages)
    valid_spec = jax.ShapeDtypeStruct((n_stages, lps), jnp.bool_)
    mkv, tkv = jax.eval_shape(
        lambda: pl.init_stage_caches(cfg, pcfg, dtype=jnp.bfloat16))
    ring = jax.eval_shape(lambda: pl.init_ring(cfg, pcfg,
                                               dtype=jnp.bfloat16))
    tcap = pcfg.tree_capacity + pcfg.width
    # batched entry (B=1 KV slot — single-request deployment)
    entry = {
        "act": jax.ShapeDtypeStruct((1, width, cfg.d_model), jnp.bfloat16),
        "positions": jax.ShapeDtypeStruct((1, width), jnp.int32),
        "mask": jax.ShapeDtypeStruct((1, width, tcap), jnp.bool_),
        "write_idx": jax.ShapeDtypeStruct((1,), jnp.int32),
        "model_len": jax.ShapeDtypeStruct((1,), jnp.int32),
        "valid": jax.ShapeDtypeStruct((1,), jnp.bool_),
        "version": jax.ShapeDtypeStruct((1,), jnp.int32),
    }
    from jax.sharding import NamedSharding, PartitionSpec as P
    stage_sh = lambda tree_: jax.tree.map(
        lambda _: NamedSharding(mesh, P("model")), tree_)
    repl = lambda tree_: jax.tree.map(
        lambda _: NamedSharding(mesh, P()), tree_)

    tick = pl.make_pipedec_tick(cfg, pcfg, mesh)
    with mesh:
        fn = jax.jit(tick,
                     in_shardings=(stage_sh(stage_p),
                                   NamedSharding(mesh, P("model")),
                                   stage_sh(mkv), stage_sh(tkv),
                                   stage_sh(ring), repl(entry)),
                     donate_argnums=(3,))
        t0 = time.time()
        lowered = fn.lower(stage_p, valid_spec, mkv, tkv, ring, entry)
        compiled = lowered.compile()
        t1 = time.time()
    shape = specs.SHAPES["decode_32k"]
    roof = analysis.analyze_compiled(
        arch, f"pipedec_tick_w{width}", "x".join(
            str(s) for s in mesh.devices.shape), chips, lowered, compiled,
        cfg, shape, "decode")
    row = roof.row()
    row.update({"compile_s": round(t1 - t0, 1), "multi_pod": multi_pod,
                "n_stages": n_stages, "width": width})
    if verbose:
        print(f"  pipeline tick: flops={row['hlo_flops']:.3e} "
              f"bytes={row['hlo_bytes']:.3e} coll={row['coll_bytes']:.3e} "
              f"-> {row['bottleneck']}-bound")
        try:
            print(f"  memory_analysis: {compiled.memory_analysis()}")
        except Exception:
            pass
    return row


def tf_init_specs(cfg):
    """Shape-only (eval_shape) bf16 param specs for ``cfg``."""
    import repro.models.transformer as tf
    return jax.eval_shape(
        lambda: tf.init_model(jax.random.PRNGKey(0), cfg,
                              dtype=jnp.bfloat16))


def main(argv=None):
    """CLI entry: dry-run one combination, or ``--all`` of them."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(specs.SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--pipeline", action="store_true",
                    help="lower the shard_map PipeDec tick instead")
    ap.add_argument("--stages", type=int, default=16)
    ap.add_argument("--width", type=int, default=32)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if args.pipeline:
        row = lower_pipeline_tick(args.arch or "pipedec-target",
                                  n_stages=args.stages, width=args.width,
                                  multi_pod=args.multi_pod)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(row) + "\n")
        return 0

    combos = []
    archs = cfg_reg.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(specs.SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    rows, failures = [], []
    for a, s, mp in combos:
        tag = f"{a} × {s} × {'2x16x16' if mp else '16x16'}"
        print(f"[dryrun] {tag}", flush=True)
        try:
            row = lower_one(a, s, multi_pod=mp)
            rows.append(row)
        except Exception as e:
            traceback.print_exc()
            failures.append((tag, repr(e)))
    if args.out:
        with open(args.out, "a") as f:
            for r in rows:
                f.write(json.dumps(r) + "\n")
    print(f"\n[dryrun] {len(rows)} ok, {len(failures)} failed")
    for tag, err in failures:
        print(f"  FAIL {tag}: {err}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
