"""Roofline-term extraction from lowered/compiled XLA artifacts.

  compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory term     = HLO_bytes / (chips × HBM_bw)
  collective term = collective_bytes / (chips × link_bw)

``cost_analysis`` supplies flops / bytes accessed; collective bytes are not
in cost_analysis, so we parse the (optimized when available) HLO text and
sum the operand/result sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the task statement).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  `bf16[16,512,128]{2,1,0} all-gather(...)` or tuple results
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind result bytes of every collective instruction.

    Skips the ``-done`` halves of async pairs (the ``-start`` carries the
    shape).  Result-size is the proxy for bytes moved (exact per-op cost
    depends on algorithm; for ring all-gather, result≈moved).
    """
    out = {k: 0 for k in _COLLECTIVES}
    for m in _INSTR_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class Roofline:
    """Roofline cost terms for one compiled (arch, shape, mesh) combo:
    HLO flops/bytes vs per-chip peaks, collective bytes vs ICI, and
    the resulting bottleneck / useful-flops ratio.
    """
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_by_kind: Dict[str, int]
    model_flops: float
    per_device_mem: Optional[float] = None

    # NOTE: cost_analysis() and the HLO text refer to the *partitioned*
    # (per-device) module, so the terms divide by per-chip peak only.
    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "per_device_mem": self.per_device_mem,
            "coll_by_kind": self.coll_by_kind,
        }


def model_flops_estimate(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode D=batch·1."""
    n_active = cfg.param_count(active_only=True)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token/seq


def analyze_compiled(arch: str, shape_name: str, mesh_desc: str, chips: int,
                     lowered, compiled, cfg, shape, kind: str) -> Roofline:
    """Build the ``Roofline`` row from a lowered+compiled function
    (``cost_analysis`` flops/bytes, HLO-text collective bytes).
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = collective_bytes(hlo)
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = (getattr(ma, "argument_size_in_bytes", 0)
                   + getattr(ma, "output_size_in_bytes", 0)
                   + getattr(ma, "temp_size_in_bytes", 0)
                   + getattr(ma, "generated_code_size_in_bytes", 0))
    except Exception:
        pass
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_desc, chips=chips,
        hlo_flops=flops, hlo_bytes=byts,
        coll_bytes=float(sum(coll.values())), coll_by_kind=coll,
        model_flops=model_flops_estimate(cfg, shape, kind),
        per_device_mem=mem)
