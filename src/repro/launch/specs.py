"""Input ShapeDtypeStruct stand-ins for every (arch × input-shape) combo.

No device allocation — everything here is ``jax.eval_shape``-style metadata
that ``dryrun.py`` feeds to ``jax.jit(...).lower()``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.models.config import ModelConfig

PARAM_DTYPE = jnp.bfloat16
CACHE_DTYPE = jnp.bfloat16


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One named benchmark shape: sequence length, global batch and
    kind (train | prefill | decode).
    """
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def window_override(cfg: ModelConfig, shape: InputShape) -> int:
    """long_500k needs sub-quadratic attention: SSM/hybrid are natively
    sub-quadratic; full-attention archs run the sliding-window variant."""
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm",
                                                    "audio"):
        return 4096
    return -1


def sds(shape, dtype):
    """``jax.ShapeDtypeStruct`` shorthand."""
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def param_specs(cfg: ModelConfig):
    """Shape-only (eval_shape) param specs for ``cfg`` at the training
    param dtype.
    """
    return jax.eval_shape(
        lambda: tf.init_model(jax.random.PRNGKey(0), cfg, dtype=PARAM_DTYPE))


def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                stacked: bool = True):
    """Shape-only (eval_shape) KV-cache specs for ``cfg`` at the cache
    dtype.
    """
    return jax.eval_shape(
        lambda: tf.init_cache(cfg, batch, max_len, dtype=CACHE_DTYPE,
                              stacked=stacked))


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Model-input specs for one step of the given kind."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out = {"tokens": sds((b, s), jnp.int32),
               "labels": sds((b, s), jnp.int32)}
        if cfg.family == "vlm":
            out["prefix_embeds"] = sds((b, cfg.prefix_tokens, cfg.d_model),
                                       PARAM_DTYPE)
        if cfg.is_encdec:
            out["frames"] = sds((b, cfg.encoder.max_source_positions,
                                 cfg.d_model), PARAM_DTYPE)
        return out
    if shape.kind == "prefill":
        # prefill allocates its cache internally; no cache input spec
        out = {"tokens": sds((b, s), jnp.int32)}
        if cfg.family == "vlm":
            out["prefix_embeds"] = sds((b, cfg.prefix_tokens, cfg.d_model),
                                       PARAM_DTYPE)
        if cfg.is_encdec:
            out["frames"] = sds((b, cfg.encoder.max_source_positions,
                                 cfg.d_model), PARAM_DTYPE)
        return out
    # decode: ONE new token against a seq_len cache (serving layout:
    # per-layer buffers so donation aliases in place)
    out = {"token": sds((b,), jnp.int32),
           "cache": cache_specs(cfg, b, s, stacked=False),
           "cache_len": sds((), jnp.int32)}
    if cfg.is_encdec:
        out["enc_out"] = sds((b, cfg.encoder.max_source_positions,
                              cfg.d_model), PARAM_DTYPE)
    return out
