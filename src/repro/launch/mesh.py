"""Production meshes.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module does not touch jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The paper-scale mesh: (data=16, model=16), or
    (pod=2, data=16, model=16) with ``multi_pod``.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int = 1):
    """Small mesh over whatever devices exist (CPU tests)."""
    n = len(jax.devices())
    model = min(model, n)
    data = max(1, min(data, n // model))
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Mesh axes usable for batch sharding (('pod',) 'data')."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_sharding_spec(mesh, batch: int):
    """Partition the batch over ('pod','data') when divisible, else
    replicate (long_500k, batch=1, shards the sequence instead)."""
    axes = data_axes(mesh)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    return axes if batch % total == 0 else None
