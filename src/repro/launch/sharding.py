"""Parameter / activation sharding rules for the production meshes.

Rules are name-based over the param pytree paths produced by
``repro.models.transformer.init_model``.  The "model" axis carries
tensor/expert parallelism; ("pod","data") carry the batch (or, for
``long_500k``, the KV-cache sequence).  Every rule degrades to replication
when the relevant dimension is not divisible by the axis size — e.g.
qwen*-32b's 40 heads on a 16-way model axis fall back to head_dim sharding
(128 % 16 == 0), and whisper's 51865-entry vocab table replicates.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _axis(mesh, name: str) -> int:
    return mesh.shape[name]


def _div(n: int, m: int) -> bool:
    return n % m == 0


def _attn_spec(name: str, leaf, cfg: ModelConfig, ms: int):
    """Sharding for attention projection params (possibly stacked)."""
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    kv = cfg.num_kv_heads
    heads_ok = _div(h, ms)
    kv_ok = _div(kv, ms)
    hd_ok = _div(hd, ms)
    if cfg.mla is not None:
        m = cfg.mla
        if name in ("w_q", "w_ukv"):
            return P(None, "model", None) if heads_ok else P()
        if name == "w_o":
            return P("model", None, None) if heads_ok else P()
        return P()  # w_dq, w_dkv, w_kr: small LoRA factors, replicated
    if name == "w_q":
        if heads_ok:
            return P(None, "model", None)
        return P(None, None, "model") if hd_ok else P()
    if name in ("w_k", "w_v"):
        if kv_ok:
            return P(None, "model", None)
        return P(None, None, "model") if hd_ok else P()
    if name == "w_o":
        if heads_ok:
            return P("model", None, None)
        return P(None, "model", None) if hd_ok else P()
    if name == "b_q":
        return P("model", None) if heads_ok else (
            P(None, "model") if hd_ok else P())
    if name in ("b_k", "b_v"):
        return P("model", None) if kv_ok else (
            P(None, "model") if hd_ok else P())
    return P()


def _moe_spec(name: str, leaf, cfg: ModelConfig, ms: int, ds: int = 1):
    """Expert weights: 2-D sharded — expert dim over 'model' (expert
    parallelism) AND ff dim over 'data' (FSDP-style storage shard; gathers
    amortise into the weight stream that a memory-bound MoE reads anyway).
    Required for 100B+ MoEs: deepseek-v2 bf16 is 29.5 GB/device with E-only
    sharding vs 1.8 GB with 2-D (§Perf H1)."""
    e = cfg.moe.num_experts
    f = cfg.moe.d_ff_expert
    e_ok, f_ok_m = _div(e, ms), _div(f, ms)
    f_data = "data" if _div(f, ds) else None
    if name == "router":
        return P()
    if name in ("w_gate", "w_up"):
        if e_ok:
            return P("model", None, f_data)
        return P(None, None, "model") if f_ok_m else P()
    if name == "w_down":
        if e_ok:
            return P("model", f_data, None)
        return P(None, "model", None) if f_ok_m else P()
    return P()


def _mlp_spec(name: str, leaf, cfg: ModelConfig, ms: int, ff: int):
    if not _div(ff, ms):
        return P()
    if name in ("w_gate", "w_up"):
        return P(None, "model")
    if name == "w_down":
        return P("model", None)
    return P()


def _rglru_spec(name: str, leaf, cfg: ModelConfig, ms: int):
    w = cfg.rglru.lru_width or cfg.d_model
    if not _div(w, ms):
        return P()
    if name in ("in_x", "in_y"):
        return P(None, "model")
    if name in ("conv_w",):
        return P(None, "model")
    if name in ("conv_b", "lambda"):
        return P("model")
    if name in ("w_a", "w_i"):
        return P(None, "model")
    if name == "out":
        return P("model", None)
    return P()


def param_pspec(path, leaf, cfg: ModelConfig, mesh) -> P:
    """PartitionSpec for one param leaf (tensor-parallel over 'model',
    vocab-sharded tables, replicated norms/scalars).
    """
    ms = _axis(mesh, "model")
    keys = [p.key for p in path if hasattr(p, "key")]
    name = keys[-1]
    stacked = "stack" in keys

    if name == "table":
        spec = P("model", None) if _div(cfg.vocab_size, ms) else P()
    elif name in ("scale", "bias", "A_log", "dt_bias", "D", "dt"):
        spec = P()
    elif "mixer" in keys and cfg.family == "ssm":
        spec = P()  # mamba2-130m: tiny, replicated (see DESIGN.md)
    elif "mixer" in keys and name in ("in_x", "in_y", "w_a", "w_i", "lambda",
                                      "conv_w", "conv_b", "out"):
        spec = _rglru_spec(name, leaf, cfg, ms)
    elif name in ("w_q", "w_k", "w_v", "w_o", "b_q", "b_k", "b_v",
                  "w_dq", "w_dkv", "w_kr", "w_ukv"):
        spec = _attn_spec(name, leaf, cfg, ms)
    elif name == "router":
        spec = P()
    elif name in ("w_gate", "w_up", "w_down"):
        base_ndim = leaf.ndim - (1 if stacked else 0)
        if base_ndim == 3 and cfg.moe is not None and "shared" not in keys:
            spec = _moe_spec(name, leaf, cfg, ms,
                             _axis(mesh, "data"))  # expert weights [E,d,f]
        else:
            ff = leaf.shape[-1] if name != "w_down" else leaf.shape[-2]
            spec = _mlp_spec(name, leaf, cfg, ms, ff)
    elif name == "in_proj":  # ssm
        spec = P()
    elif name == "out_proj":
        spec = P()
    else:
        spec = P()

    if stacked and len(spec) == leaf.ndim - 1:
        spec = P(None, *spec)
    elif len(spec) not in (0, leaf.ndim):
        spec = P()  # dimensionality mismatch -> replicate safely
    return spec


def params_shardings(params, cfg: ModelConfig, mesh):
    """``NamedSharding`` pytree for a param pytree (see
    ``param_pspec``).
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_pspec(path, leaf, cfg,
                                                           mesh)),
        params)


def zero1_pspec(path, leaf, cfg: ModelConfig, mesh) -> P:
    """Optimizer-state sharding (ZeRO-1): the param spec plus a 'data'
    shard on the first still-replicated divisible axis."""
    base = param_pspec(path, leaf, cfg, mesh)
    spec = list(base) + [None] * (leaf.ndim - len(base))
    if any(ax == "data" or (isinstance(ax, tuple) and "data" in ax)
           for ax in spec):
        return P(*spec)  # base spec already uses 'data' (2-D experts)
    ds = _axis(mesh, "data")
    for i, (ax, dim) in enumerate(zip(spec, leaf.shape)):
        if ax is None and dim % ds == 0 and dim >= ds:
            spec[i] = "data"
            break
    return P(*spec)


def zero1_shardings(params, cfg: ModelConfig, mesh):
    """``NamedSharding`` pytree for optimizer state (see
    ``zero1_pspec``).
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, zero1_pspec(path, leaf, cfg,
                                                           mesh)),
        params)


def cache_pspec(path, leaf, cfg: ModelConfig, mesh, *, batch: int,
                shard_seq: bool = False) -> P:
    """KV-cache / state sharding.  batch over ('pod','data') when divisible;
    long_500k (batch=1) shards the cache sequence over 'data' instead."""
    from repro.launch.mesh import batch_sharding_spec
    ms = _axis(mesh, "model")
    keys = [p.key for p in path if hasattr(p, "key")]
    name = keys[-1]
    stacked = "stack" in keys
    baxes = batch_sharding_spec(mesh, batch)
    b = baxes if baxes else None

    if name in ("k", "v"):
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        seq = "data" if (shard_seq and b is None) else None
        head_ax = "model" if _div(kv, ms) else None
        hd_ax = "model" if (head_ax is None and _div(hd, ms)) else None
        spec = P(b, seq, head_ax, hd_ax)
    elif name in ("c_kv", "k_rope"):
        # MLA compressed cache has no head dim to shard — shard the
        # *sequence* over every mesh axis the batch doesn't use (§Perf H1:
        # 18 GB -> 1.1 GB/device for deepseek-v2 decode_32k).
        used = set(b) if isinstance(b, tuple) else ({b} if b else set())
        rest = tuple(a for a in mesh.axis_names if a not in used)
        spec = P(b, rest if rest else None, None)
    elif name == "conv":
        spec = P(b, None, None)
    elif name == "ssd":
        spec = P(b, None, None, None)
    elif name == "h":
        spec = P(b, None)
    else:
        spec = P()
    if stacked:
        spec = P(None, *spec)
    return spec


def cache_shardings(cache, cfg: ModelConfig, mesh, *, batch: int,
                    shard_seq: bool = False):
    """``NamedSharding`` pytree for a KV-cache pytree (see
    ``cache_pspec``).
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_pspec(path, leaf, cfg, mesh, batch=batch,
                              shard_seq=shard_seq)),
        cache)


def batch_shardings(mesh, batch: int, ndim: int = 2):
    """Batch-axis ``NamedSharding`` for activations/token arrays."""
    from repro.launch.mesh import batch_sharding_spec
    baxes = batch_sharding_spec(mesh, batch)
    spec = P(baxes, *([None] * (ndim - 1))) if baxes else P()
    return NamedSharding(mesh, spec)


def replicated(mesh):
    """Fully-replicated ``NamedSharding`` on ``mesh``."""
    return NamedSharding(mesh, P())
