"""Sharded-deployment equivalence check (the PR's acceptance pin, as a
runnable): on an ``--stages``-device CPU mesh, ``SpecPipeDBEngine`` with
``ShardedPipelineExecutor`` must produce per-uid token outputs
bit-identical to ``LocalFusedExecutor`` AND to the single-request
``PipeDecEngine`` under greedy decoding (staggered arrivals included),
and the dispatch-count hook must show exactly one batched sharded tick
per timestep with pending entries.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.sharded_check --stages 8

``--overlap`` additionally checks the steady-state overlapped executor
(``OverlappedShardedExecutor``: persistent always-full ring, ONE tick per
global timestep, deferred exit logits, in-ring pruning propagation):

  * per-uid outputs bit-identical to flush / local / single-request on
    TWO workloads — an independent draft (misses dominate: kills with
    layers in flight) and a self-draft (perfect acceptance: every commit
    is a hit, so prune index_maps ride the ring through a full pipeline);
  * exactly ONE ring tick per executed timestep
    (``calls["pipeline_tick"]`` == engine timesteps) — admission
    timesteps included: prefill rides the tick's prefill lane
    (prefill-in-ring), so NEITHER model ever logs a separate ``prefill``
    dispatch on the overlapped backend;
  * the gated ctrl channel actually gates: the measured ctrl-active rate
    (``calls["ctrl_active_ticks"] / calls["pipeline_tick"]``) is < 1;
  * a tick-level pruning-propagation scenario on the real S-stage mesh: a
    slot killed with layers still in flight writes nothing further into
    its stage tree caches (rows bit-untouched), its stale exits come out
    dead, and the other slot's rows/exits are bit-identical to a run
    without the kill.

``--paged`` reruns everything on block-paged KV arenas (``--page-size``
rows per block): the local backend's ``PagedKVArena`` pools plus the
sharded/overlapped stage arenas behind identity block tables.  The pin is
unchanged — paged outputs must stay bit-identical to the single-request
engine (the dense reference), with the same dispatch counts.  With
``--overlap`` the workload set grows a *long-prompt* leg whose prompts all
exceed the ring's ``--prefill-cap``, pinning chunked prefill-in-ring:
every admission streams through the lane over several ticks
(``prefill_chunks`` > requests) with exactly ONE tick per timestep and
``separate_prefill_dispatches == 0`` at any prompt length, and the
slot-recycle scenario reuses a slot under paging with a chunked prompt.

``--async`` runs every workload on the ``AsyncPipelineExecutor`` as well
(free-running per-stage actor threads + a disaggregated draft actor — no
host lockstep), pinning it bit-identical to the same single-request
reference, and adds three async-only scenarios:

  * *kill latency*: with the stage gate paused, an entry is pushed and
    its slot killed before the actors resume — the stale layer must die
    at stage 0 (``stage_counters[0]["stale_rows"]`` > 0), i.e. before
    even ONE hop, let alone a full ring revolution;
  * *fail loudly*: a stage actor forced to raise must surface on the
    main thread as ``AsyncExecutorError`` (original traceback attached)
    within the executor timeout — the check prints ``SHARDED_CHECK
    fail`` instead of hanging;
  * *clean shutdown*: ``shutdown()`` joins every actor thread (none
    leaked), twice (idempotent), and a repeat run is bit-deterministic.

``--async`` composes with ``--overlap`` and ``--quant`` but not
``--paged`` (the async backend has no paged path yet — it rejects the
combination loudly).

``--quant`` additionally runs the whole workload on int8 bundles
(``ModelBundle.quantize()``: per-out-channel int8 weights + int8 KV
arena).  The strong pin is the same as fp32's, *within* the quantized
path: quantized DB outputs across every executor are bit-identical to
the quantized single-request engine, with the identical dispatch-count
assertions (one tick per timestep, prefill-in-ring, no separate prefill
dispatch).  Against fp32 the gates are statistical, not bitwise — the
acceptance-rate delta stays within ``QUANT_ACCEPTANCE_TOL``, the
self-draft workload keeps ~perfect acceptance, and the int8 arena costs
at most ``QUANT_BYTES_RATIO_MAX`` of the fp32 bytes per slot (so an
equal byte budget admits >= ``QUANT_SLOTS_MULT_MIN`` x the slots).

Prints one JSON summary line plus one machine-greppable status line —
``SHARDED_CHECK ok stages=8 ...`` on success, ``SHARDED_CHECK fail ...``
(and a non-zero exit code, no traceback spelunking needed) on any
mismatch.  Run in its own process: the forced host-device count must not
leak into other jax users (tests spawn it via subprocess, CI runs it as a
dedicated leg and greps the status line).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# int8 regression thresholds (committed gates; see module docstring)
QUANT_ACCEPTANCE_TOL = 0.15     # |acc(int8) - acc(fp32)| on the workload
QUANT_BYTES_RATIO_MAX = 0.55    # int8 arena bytes / fp32 arena bytes
QUANT_SLOTS_MULT_MIN = 1.9      # slots admitted at an equal byte budget


def _pruning_propagation_scenario(stages: int):
    """Tick-level pin of the in-ring kill on a real S-stage mesh."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.launch import pipeline as pl
    from repro.models import transformer as tf
    from repro.models.config import ModelConfig

    cfg = ModelConfig(name="pp-chk", family="dense", num_layers=stages,
                      d_model=32, num_heads=2, num_kv_heads=1, d_ff=64,
                      vocab_size=64)
    params = tf.init_model(jax.random.PRNGKey(3), cfg)
    mesh = jax.make_mesh((1, stages), ("data", "model"))
    w = 4
    ticks = stages + 2
    cap = 1 + w * (ticks + 1)
    pcfg = pl.PipelineConfig(n_stages=stages, width=w, tree_capacity=cap,
                             max_len=32)
    sp, valid = pl.stage_params(cfg, params, stages)
    kill_at = 2

    def entry(t, slot0_on):
        key = jax.random.PRNGKey(100 + t)
        wi = 1 + t * w
        mask = jax.nn.one_hot(wi + jnp.arange(w), cap + w, dtype=bool)
        return {
            "act": jax.random.normal(key, (2, w, cfg.d_model)),
            "positions": jnp.broadcast_to(jnp.arange(w)[None], (2, w))
            .astype(jnp.int32),
            "mask": jnp.broadcast_to(mask[None], (2, w, cap + w)),
            "write_idx": jnp.full((2,), wi, jnp.int32),
            "model_len": jnp.zeros((2,), jnp.int32),
            "valid": jnp.asarray([slot0_on, True]),
            "version": jnp.zeros((2,), jnp.int32),
        }

    jtick = jax.jit(pl.make_pipedec_tick(cfg, pcfg, mesh))

    def run(with_kill: bool):
        model_kv, tree_kv = pl.init_stage_caches(cfg, pcfg, batch=2)
        ring = pl.init_ring(cfg, pcfg, batch=2)
        states, exits = [], []
        with mesh:
            for t in range(ticks):
                killed = with_kill and t >= kill_at
                kill = jnp.asarray([with_kill and t == kill_at, False])
                model_kv, tree_kv, ring, ex = jtick(
                    sp, valid, model_kv, tree_kv, ring,
                    entry(t, not killed), kill)
                states.append(jax.tree.map(np.asarray, tree_kv))
                exits.append((np.asarray(ex["valid"]),
                              np.asarray(ex["act"])))
        return states, exits

    states_a, exits_a = run(False)
    states_b, exits_b = run(True)

    def slot(tree, b):
        return jax.tree.map(lambda x: x[:, b], tree)

    eq = lambda x, y: jax.tree.map(np.testing.assert_array_equal, x, y)
    # (1) killed slot: no write after the kill tick — stale in-flight
    # layers stopped touching the stage tree caches
    for t in range(kill_at, ticks):
        eq(slot(states_b[t], 0), slot(states_b[kill_at - 1], 0))
    # ...whereas without the kill the same layers DID keep writing
    changed = any(
        bool(np.any(x != y))
        for x, y in zip(jax.tree.leaves(slot(states_a[ticks - 1], 0)),
                        jax.tree.leaves(slot(states_b[ticks - 1], 0))))
    assert changed, "control run must show the writes the kill suppressed"
    # (2) the other slot is bit-unaffected by the kill, every tick
    for t in range(ticks):
        eq(slot(states_b[t], 1), slot(states_a[t], 1))
    # (3) exits: stale slot-0 exits come out dead; slot 1 identical
    saw_dead = saw_live = False
    for t in range(ticks):
        va, aa = exits_a[t]
        vb, ab = exits_b[t]
        assert bool(va[1]) == bool(vb[1])
        if va[1]:
            np.testing.assert_array_equal(ab[1], aa[1])
            saw_live = True
        if t >= stages - 1:
            assert bool(va[0]), "control run: slot-0 layers must exit live"
        if t >= max(stages - 1, kill_at):
            # from here every slot-0 exit was either in flight at the
            # kill tick or an invalidated entry (at stages <= kill_at a
            # layer entered early enough exits live BEFORE the kill —
            # that exit is legitimately identical in both runs)
            assert not bool(vb[0]), "stale slot-0 exit must be dead"
            saw_dead = True
    assert saw_dead and saw_live
    return {"killed_rows_untouched": True, "other_slot_unaffected": True,
            "stale_exits_dropped": True, "live_exits_match": True,
            "ticks": ticks, "kill_at": kill_at}


def main(argv=None):
    """Run every workload x executor combination plus the async
    scenarios; print one machine-readable SHARDED_CHECK ok/fail
    line (CI greps it).
    """
    ap = argparse.ArgumentParser()
    ap.add_argument("--stages", type=int, default=8)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--layers", type=int, default=0,
                    help="target layers (default: one per stage)")
    ap.add_argument("--overlap", action="store_true",
                    help="also check the overlapped executor (one ring "
                         "tick per timestep; PipeDecConfig.n_stages is "
                         "then --stages so the ring IS the flight "
                         "bookkeeping)")
    ap.add_argument("--async", dest="use_async", action="store_true",
                    help="also check the async free-running executor "
                         "(per-stage actor threads + disaggregated draft; "
                         "PipeDecConfig.n_stages is then --stages), plus "
                         "its kill-latency, fail-loudly and "
                         "clean-shutdown scenarios")
    ap.add_argument("--quant", action="store_true",
                    help="also run the workload on int8 bundles "
                         "(ModelBundle.quantize()): same bit-identity pin "
                         "within the quantized path, acceptance-delta and "
                         "arena-bytes gates against fp32")
    ap.add_argument("--paged", action="store_true",
                    help="run every executor on block-paged KV arenas "
                         "(models.paging pools + block tables); outputs "
                         "must stay bit-identical to the dense reference")
    ap.add_argument("--page-size", type=int, default=16,
                    help="rows per KV block under --paged (power of two)")
    ap.add_argument("--prefill-cap", type=int, default=16,
                    help="overlapped ring prefill-lane chunk size; prompts "
                         "longer than this stream through the lane over "
                         "several ticks (chunked prefill)")
    args = ap.parse_args(argv)

    if "--xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.stages}")

    import jax
    import numpy as np

    from repro.core.pipedec import PipeDecConfig, PipeDecEngine
    from repro.core.speculative import ModelBundle
    from repro.models import transformer as tf
    from repro.models.config import ModelConfig
    from repro.serving import (AsyncExecutorError, AsyncPipelineExecutor,
                               LocalFusedExecutor,
                               OverlappedShardedExecutor, Request,
                               ShardedPipelineExecutor, SpecPipeDBEngine)

    assert len(jax.devices()) >= args.stages, \
        f"need {args.stages} devices, have {len(jax.devices())}"
    assert not (args.use_async and args.paged), \
        "--async has no paged path yet; drop one of --async/--paged"

    layers = args.layers or args.stages
    target_cfg = ModelConfig(name="chk-target", family="dense",
                             num_layers=layers, d_model=64, num_heads=4,
                             num_kv_heads=2, d_ff=128, vocab_size=128)
    draft_cfg = ModelConfig(name="chk-draft", family="dense", num_layers=1,
                            d_model=32, num_heads=2, num_kv_heads=1,
                            d_ff=64, vocab_size=128, tie_embeddings=True)
    target = ModelBundle(tf.init_model(jax.random.PRNGKey(0), target_cfg),
                         target_cfg)
    draft = ModelBundle(tf.init_model(jax.random.PRNGKey(9), draft_cfg),
                        draft_cfg)
    # the overlapped ring length is pcfg.n_stages, so it must equal the
    # mesh's stage count; the flush/local backends accept any pcfg (and
    # the async actor chain is likewise pcfg.n_stages long)
    n_stages = args.stages if (args.overlap or args.use_async) else 4
    pcfg = PipeDecConfig(n_stages=n_stages, width=4, branch=2)
    max_len = 160

    rng = np.random.default_rng(0)

    def mk_reqs(lo_new, hi_new):
        return [Request(i,
                        rng.integers(0, 100, size=int(rng.integers(3, 8)))
                        .astype(np.int32),
                        int(rng.integers(lo_new, hi_new)),
                        arrival_t=int(rng.integers(0, 3 * args.requests)))
                for i in range(args.requests)]

    mk = {
        "local": lambda t, d: LocalFusedExecutor(
            t, d, slots=args.slots, max_len=max_len,
            tree_capacity=pcfg.tree_buffer_capacity,
            capacity=pcfg.capacity, paged=args.paged,
            page=args.page_size),
        "sharded": lambda t, d: ShardedPipelineExecutor(
            t, d, slots=args.slots, max_len=max_len,
            tree_capacity=pcfg.tree_buffer_capacity,
            capacity=pcfg.capacity, n_stages=args.stages,
            paged=args.paged, page=args.page_size),
    }
    if args.overlap:
        mk["sharded_overlapped"] = lambda t, d: OverlappedShardedExecutor(
            t, d, slots=args.slots, max_len=max_len,
            tree_capacity=pcfg.tree_buffer_capacity,
            capacity=pcfg.capacity, n_stages=args.stages,
            prefill_cap=args.prefill_cap, paged=args.paged,
            page=args.page_size)
    if args.use_async:
        mk["sharded_async"] = lambda t, d: AsyncPipelineExecutor(
            t, d, slots=args.slots, max_len=max_len,
            tree_capacity=pcfg.tree_buffer_capacity,
            capacity=pcfg.capacity, n_stages=args.stages)

    def check_workload(tgt, drf, reqs):
        single = PipeDecEngine(tgt, drf, pcfg, max_len=max_len)
        want, acc = {}, {}
        for r in reqs:
            want[r.uid], st = single.generate(r.prompt, r.max_new_tokens)
            acc[r.uid] = st.acceptance
        part = {"acceptance_mean": round(float(np.mean(list(acc.values()))),
                                         4)}
        for name, make in mk.items():
            ex = make(tgt, drf)
            eng = SpecPipeDBEngine(tgt, drf, pcfg, max_len=max_len,
                                   max_slots=args.slots, executor=ex)
            before = {m: dict(m.calls) for m in (tgt, drf)}
            for r in reqs:
                eng.submit(r)
            res = eng.run()
            for uid, tokens in want.items():
                np.testing.assert_array_equal(
                    res[uid].tokens, tokens,
                    err_msg=f"{name} executor vs single-request uid={uid}")
            disp = eng.stats.verify_dispatches
            assert max(disp) == 1, f"{name}: >1 dispatch in one timestep"
            assert ex.calls["verify_rows"] == sum(disp), \
                f"{name}: one batched dispatch per pending timestep"
            # per-request acceptance counters (DBStats.accepted/proposed)
            # must agree with the single-request trace — the runs are
            # bit-identical, so the verify decisions are too
            for r in reqs:
                st = res[r.uid].stats
                assert eng.stats.accepted[r.uid] == st.hits, \
                    f"{name}: DBStats.accepted mismatch uid={r.uid}"
                assert eng.stats.proposed[r.uid] == st.hits + st.misses, \
                    f"{name}: DBStats.proposed mismatch uid={r.uid}"
            part[name] = {
                "timesteps": eng.stats.timesteps,
                "tokens_per_timestep": round(eng.stats.tokens_per_timestep,
                                             4),
                "peak_occupancy": eng.stats.peak_occupancy,
                "acceptance_rate": round(eng.stats.acceptance_rate, 4),
                "dispatches": dict(ex.calls),
            }
            if name == "sharded":
                assert ex.calls["pipeline_verify"] == sum(disp), \
                    "one batched sharded flush per pending timestep"
            if name == "sharded_overlapped":
                # the steady-state pin: ONE ring tick per executed global
                # timestep — admission timesteps included (prefill rides
                # the tick's prefill lane, never its own dispatch)
                assert ex.calls["pipeline_tick"] == eng.stats.timesteps, \
                    "overlapped: one ring tick per executed timestep"
                assert eng.stats.tick_dispatches == \
                    [1] * eng.stats.timesteps
                assert ex.calls["drain_tick"] == 0, \
                    "per-timestep ticks must resolve every live flight"
                assert ex.calls["prefill_in_ring"] == len(reqs), \
                    "every admission must prefill in-ring"
                assert eng.stats.separate_prefill_dispatches == 0, \
                    "overlapped: no standalone executor.prefill at ANY " \
                    "prompt length (chunked prefill streams long prompts)"
                for m in (tgt, drf):
                    assert m.calls["prefill"] == \
                        before[m].get("prefill", 0), \
                        "overlapped: no separate ModelBundle prefill " \
                        "dispatch"
                rate = ex.calls["ctrl_active_ticks"] / \
                    max(ex.calls["pipeline_tick"], 1)
                assert rate < 1.0, \
                    "gated ctrl must close on some ticks"
                part[name]["ctrl_active_rate"] = round(rate, 4)
            if name == "sharded_async":
                # every entering layer steps every free-running stage
                # actor exactly once, and the drained pipe consumed every
                # message it was fed
                assert ex.calls["stage_steps"] == \
                    ex.calls["entry_msgs"] * args.stages, \
                    "async: one stage step per entry per stage"
                assert ex._consumed == ex._pushed, \
                    "async: drained pipe must consume every message"
                # admission on the async backend is separate-dispatch:
                # one ModelBundle.prefill per model per request (the
                # self-draft workload shares ONE bundle for both roles,
                # so its counter sees both prefills)
                per_model = len(reqs) * (2 if tgt is drf else 1)
                for m in {id(tgt): tgt, id(drf): drf}.values():
                    assert m.calls["prefill"] - \
                        before[m].get("prefill", 0) == per_model, \
                        "async: one separate prefill per admission"
                ctr = ex.counters()
                part[name]["max_draft_lead"] = ctr["max_draft_lead"]
                part[name]["max_inbox_depth"] = max(
                    s["max_depth"] for s in ctr["stages"])
                part[name]["stale_rows"] = sum(
                    s["stale_rows"] for s in ctr["stages"])
                ex.shutdown()
                import threading
                assert not [t for t in threading.enumerate()
                            if t.name.startswith("async-")], \
                    "async: shutdown must join every actor thread"
        return part

    summary = {"stages": args.stages, "slots": args.slots,
               "requests": args.requests, "layers": layers,
               "overlap": args.overlap, "paged": args.paged,
               "page_size": args.page_size,
               "prefill_cap": args.prefill_cap}
    def check_recycle():
        """Regression: a retired occupant's in-ring ctrl must not leak
        into the recycled slot's next occupant.  Short request A (tiny
        prompt, back-to-back commits) retires while its final commits'
        ctrl messages still trail its killed layers in the ring; B joins
        the same slot the next timestep with a LONGER prompt whose low
        KV positions those stale commits would overwrite."""
        a = Request(0, np.arange(1, 4, dtype=np.int32), 2, arrival_t=0)
        b = Request(1, (np.arange(5, 45, dtype=np.int32) % 100), 4,
                    arrival_t=1)
        single = PipeDecEngine(target, target, pcfg, max_len=max_len)
        want = {r.uid: single.generate(r.prompt, r.max_new_tokens)[0]
                for r in (a, b)}
        ex = OverlappedShardedExecutor(
            target, target, slots=1, max_len=max_len,
            tree_capacity=pcfg.tree_buffer_capacity,
            capacity=pcfg.capacity, n_stages=args.stages,
            prefill_cap=args.prefill_cap, paged=args.paged,
            page=args.page_size)
        eng = SpecPipeDBEngine(target, target, pcfg, max_len=max_len,
                               max_slots=1, executor=ex)
        eng.submit(a)
        eng.submit(b)
        res = eng.run()
        for uid, tokens in want.items():
            np.testing.assert_array_equal(
                res[uid].tokens, tokens,
                err_msg=f"slot-recycle ctrl leak uid={uid}")
        assert ex.calls["kill"] >= 2, "both retires must kill in-ring"
        return {"bit_identical": True, "kills": int(ex.calls["kill"])}

    def check_recycle_async():
        """The slot-recycle leg on the async backend: same A-retires/
        B-reuses-the-slot workload as ``check_recycle``, with the retire's
        ctrl-version bump neutralising A's in-flight ctrl messages at
        whatever stage they sit."""
        a = Request(0, np.arange(1, 4, dtype=np.int32), 2, arrival_t=0)
        b = Request(1, (np.arange(5, 45, dtype=np.int32) % 100), 4,
                    arrival_t=1)
        single = PipeDecEngine(target, target, pcfg, max_len=max_len)
        want = {r.uid: single.generate(r.prompt, r.max_new_tokens)[0]
                for r in (a, b)}
        ex = AsyncPipelineExecutor(
            target, target, slots=1, max_len=max_len,
            tree_capacity=pcfg.tree_buffer_capacity,
            capacity=pcfg.capacity, n_stages=args.stages)
        eng = SpecPipeDBEngine(target, target, pcfg, max_len=max_len,
                               max_slots=1, executor=ex)
        eng.submit(a)
        eng.submit(b)
        res = eng.run()
        for uid, tokens in want.items():
            np.testing.assert_array_equal(
                res[uid].tokens, tokens,
                err_msg=f"async slot-recycle ctrl leak uid={uid}")
        kills = int(ex.calls["kill"])
        assert kills >= 2, "both retires must kill in-flight state"
        ex.shutdown()
        return {"bit_identical": True, "kills": kills}

    def check_async_kill_latency():
        """The short-circuit pin: with the stage gate paused, an entry is
        pushed and its slot killed before any actor touches it.  The
        layer must then die at stage 0 — suppressed before even ONE hop,
        where the lockstep ring can only invalidate one stage per tick
        and a stale layer rides ``n_stages - 1`` further hops before its
        exit is dropped."""
        ex = mk["sharded_async"](target, draft)
        try:
            ex.pause()
            row_on = np.zeros(args.slots, bool)
            row_on[0] = True
            _d, handles = ex.tick_rows(*ex.dead_entry, row_on)
            ex.kill(0)
            ex.resume()
            ex.drain()
            ctr = ex.counters()
            stale0 = ctr["stages"][0]["stale_rows"]
            assert stale0 >= 1, \
                "kill must beat the paused layer to stage 0"
            # ...and since rows go stale at processing time, every later
            # stage suppressed it too — never a live write after the kill
            assert all(s["stale_rows"] >= 1 for s in ctr["stages"])
            assert handles[0].dead, "the flight's future must be dead"
            assert ex.calls["stale_exits"] >= 1, \
                "the stale exit must be dropped, not delivered"
        finally:
            ex.shutdown()
        return {"stale_at_stage0": int(stale0),
                "revolution_hops_saved": args.stages - 1}

    def check_async_failfast():
        """The fail-loudly pin: a stage actor forced to raise must
        surface on the main thread as ``AsyncExecutorError`` carrying the
        original traceback, well inside the executor timeout — never a
        hang.  (The workload ``try`` below turns any such error into the
        ``SHARDED_CHECK fail`` status line.)"""
        import time as _time

        ex = mk["sharded_async"](target, draft)
        ex.timeout_s = 60.0

        def boom(*a, **k):
            raise RuntimeError("injected stage fault")

        ex._apply_j = boom
        row_on = np.zeros(args.slots, bool)
        row_on[0] = True
        t0 = _time.monotonic()
        try:
            ex.tick_rows(*ex.dead_entry, row_on)
            ex.drain()
        except AsyncExecutorError as e:
            elapsed = _time.monotonic() - t0
            assert "injected stage fault" in str(e), \
                "original traceback must ride the host-side error"
            assert elapsed < ex.timeout_s, "must fail fast, not time out"
        else:
            raise AssertionError(
                "stage fault must surface as AsyncExecutorError")
        finally:
            ex.shutdown()
        return {"propagates": True, "seconds": round(elapsed, 3)}

    def check_async_shutdown(reqs):
        """Clean-shutdown pin: ``shutdown()`` joins every actor thread
        (none leaked), is idempotent, and a fresh executor re-running the
        workload is bit-deterministic."""
        import threading

        def run_once():
            ex = mk["sharded_async"](target, draft)
            eng = SpecPipeDBEngine(target, draft, pcfg, max_len=max_len,
                                   max_slots=args.slots, executor=ex)
            for r in reqs:
                eng.submit(r)
            res = eng.run()
            ex.shutdown()
            ex.shutdown()    # idempotent
            return {u: res[u].tokens for u in res}

        a, b = run_once(), run_once()
        leaked = [t.name for t in threading.enumerate()
                  if t.name.startswith("async-")]
        assert not leaked, f"leaked actor threads: {leaked}"
        for u in a:
            np.testing.assert_array_equal(
                a[u], b[u], err_msg=f"async repeat-run uid={u}")
        return {"deterministic": True, "no_leaked_threads": True}

    def check_quant_arena():
        """Byte-budget gate: the int8 arena must cost at most
        ``QUANT_BYTES_RATIO_MAX`` of the fp32 bytes per slot, so an equal
        memory budget admits >= ``QUANT_SLOTS_MULT_MIN`` x the slots.
        Shapes only (``jax.eval_shape``) — nothing is allocated."""
        from repro.serving.scheduler import KVArena

        def bps(t, d):
            return KVArena(t, d, slots=1, max_len=max_len,
                           tree_capacity=pcfg.tree_buffer_capacity
                           ).bytes_per_slot()

        fp32_b = bps(target, draft)
        int8_b = bps(target.quantize(), draft.quantize())
        ratio = int8_b / fp32_b
        mult = fp32_b // int8_b if int8_b else 0
        assert ratio <= QUANT_BYTES_RATIO_MAX, \
            f"int8 arena ratio {ratio:.3f} > {QUANT_BYTES_RATIO_MAX}"
        assert mult >= QUANT_SLOTS_MULT_MIN, \
            f"int8 slots multiplier {mult} < {QUANT_SLOTS_MULT_MIN}"
        return {"fp32": fp32_b, "int8": int8_b,
                "ratio": round(ratio, 4), "slots_multiplier": int(mult)}

    try:
        reqs_main = mk_reqs(3, 7)
        summary["independent_draft"] = check_workload(target, draft,
                                                      reqs_main)
        if args.quant:
            # same requests, int8 bundles: bit-identity within the
            # quantized path (DB executors vs quant single-request) with
            # the identical dispatch-count assertions, then the
            # statistical gates against fp32
            q_target, q_draft = target.quantize(), draft.quantize()
            summary["quant_int8"] = check_workload(q_target, q_draft,
                                                   reqs_main)
            delta = abs(summary["quant_int8"]["acceptance_mean"]
                        - summary["independent_draft"]["acceptance_mean"])
            assert delta <= QUANT_ACCEPTANCE_TOL, \
                f"int8 acceptance delta {delta:.4f} > {QUANT_ACCEPTANCE_TOL}"
            summary["quant_int8"]["acceptance_delta_vs_fp32"] = \
                round(delta, 4)
            summary["quant_int8"]["arena_bytes_per_slot"] = \
                check_quant_arena()
            if args.overlap:
                # quantized self-draft: draft == target, so acceptance
                # must stay ~perfect (quant noise hits both identically)
                qsd = check_workload(q_target, q_target, mk_reqs(8, 14))
                assert qsd["acceptance_mean"] > 0.99, \
                    "int8 self-draft must keep ~perfect acceptance"
                summary["quant_self_draft"] = qsd
        if args.overlap:
            # self-draft: perfect acceptance — every commit is a hit, so
            # the prune index_maps ride the ring with n_stages-1 layers
            # in flight
            summary["self_draft"] = check_workload(target, target,
                                                   mk_reqs(8, 14))
            # long prompts: every prompt exceeds the ring's prefill lane,
            # so admission MUST stream chunk by chunk over several ticks
            # (one tick per timestep throughout, zero separate prefill
            # dispatches) and still bit-match the single-request engine
            cap = args.prefill_cap
            long_reqs = [
                Request(i,
                        rng.integers(0, 100,
                                     size=int(rng.integers(cap + 4,
                                                           2 * cap + 9)))
                        .astype(np.int32),
                        int(rng.integers(3, 6)),
                        arrival_t=int(rng.integers(0, args.requests)))
                for i in range(args.requests)]
            summary["long_prompt"] = check_workload(target, draft,
                                                    long_reqs)
            lp_disp = summary["long_prompt"]["sharded_overlapped"][
                "dispatches"]
            assert lp_disp["prefill_chunks"] > args.requests, \
                "long-prompt workload must actually chunk its prefills"
            summary["slot_recycle"] = check_recycle()
            assert summary["self_draft"]["acceptance_mean"] > 0.99
            assert summary["self_draft"]["sharded_overlapped"][
                "dispatches"].get("remap_rows", 0) > 0, \
                "self-draft workload must exercise in-ring prune " \
                "propagation"
            summary["pruning_propagation"] = \
                _pruning_propagation_scenario(args.stages)
        if args.use_async:
            asy = summary["independent_draft"]["sharded_async"]
            assert asy["dispatches"].get("kill", 0) > 0, \
                "miss-heavy workload must kill in-flight async layers"
            summary["async_kill_latency"] = check_async_kill_latency()
            summary["async_failfast"] = check_async_failfast()
            summary["async_shutdown"] = check_async_shutdown(reqs_main)
            summary["async_slot_recycle"] = check_recycle_async()
    except Exception as e:  # single loud line, non-zero exit — the CI
        # legs grep this instead of fishing assertion tracebacks
        import traceback
        traceback.print_exc(file=sys.stderr)
        reason = str(e).splitlines()[0][:200] if str(e) else ""
        print(f"SHARDED_CHECK fail stages={args.stages} "
              f"slots={args.slots} requests={args.requests} "
              f"overlap={int(args.overlap)} quant={int(args.quant)} "
              f"paged={int(args.paged)} async={int(args.use_async)} "
              f"error={type(e).__name__}: {reason}")
        return 1
    summary["bit_identical"] = True
    print(json.dumps(summary))
    parts = [f"SHARDED_CHECK ok stages={args.stages}",
             f"slots={args.slots}", f"requests={args.requests}",
             f"overlap={int(args.overlap)}", f"quant={int(args.quant)}",
             f"paged={int(args.paged)}", f"async={int(args.use_async)}",
             "bit_identical=1"]
    if args.paged:
        parts += [f"page_size={args.page_size}"]
    if args.use_async:
        asy = summary["independent_draft"]["sharded_async"]
        parts += [
            f"async_kills={asy['dispatches']['kill']}",
            f"async_stale_at_stage0="
            f"{summary['async_kill_latency']['stale_at_stage0']}",
            f"async_max_draft_lead={asy['max_draft_lead']}",
        ]
    if args.overlap:
        over = summary["independent_draft"]["sharded_overlapped"]
        lp = summary["long_prompt"]["sharded_overlapped"]
        parts += [
            f"ticks_per_timestep="
            f"{over['dispatches']['pipeline_tick'] / over['timesteps']:.2f}",
            f"ctrl_active_rate={over['ctrl_active_rate']:.4f}",
            f"prefill_in_ring={over['dispatches']['prefill_in_ring']}",
            f"prefill_chunks_long={lp['dispatches']['prefill_chunks']}",
            f"long_ticks_per_timestep="
            f"{lp['dispatches']['pipeline_tick'] / lp['timesteps']:.2f}",
        ]
    if args.quant:
        q = summary["quant_int8"]
        arena = q["arena_bytes_per_slot"]
        parts += [
            f"quant_acceptance_delta={q['acceptance_delta_vs_fp32']:.4f}",
            f"quant_arena_ratio={arena['ratio']:.4f}",
            f"quant_slots_multiplier={arena['slots_multiplier']}",
        ]
        if args.overlap:
            qo = q["sharded_overlapped"]
            parts += [
                f"quant_ticks_per_timestep="
                f"{qo['dispatches']['pipeline_tick'] / qo['timesteps']:.2f}",
            ]
    print(" ".join(parts))
    return 0


if __name__ == "__main__":
    sys.exit(main())
