"""Sharded-deployment equivalence check (the PR's acceptance pin, as a
runnable): on an ``--stages``-device CPU mesh, ``SpecPipeDBEngine`` with
``ShardedPipelineExecutor`` must produce per-uid token outputs
bit-identical to ``LocalFusedExecutor`` AND to the single-request
``PipeDecEngine`` under greedy decoding (staggered arrivals included),
and the dispatch-count hook must show exactly one batched sharded tick
per timestep with pending entries.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.sharded_check --stages 8

Prints one JSON summary line; exits non-zero on any mismatch.  Run in its
own process: the forced host-device count must not leak into other jax
users (tests spawn it via subprocess, CI runs it as a dedicated leg).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--stages", type=int, default=8)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--layers", type=int, default=0,
                    help="target layers (default: one per stage)")
    args = ap.parse_args(argv)

    if "--xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.stages}")

    import jax
    import numpy as np

    from repro.core.pipedec import PipeDecConfig, PipeDecEngine
    from repro.core.speculative import ModelBundle
    from repro.models import transformer as tf
    from repro.models.config import ModelConfig
    from repro.serving import (LocalFusedExecutor, Request,
                               ShardedPipelineExecutor, SpecPipeDBEngine)

    assert len(jax.devices()) >= args.stages, \
        f"need {args.stages} devices, have {len(jax.devices())}"

    layers = args.layers or args.stages
    target_cfg = ModelConfig(name="chk-target", family="dense",
                             num_layers=layers, d_model=64, num_heads=4,
                             num_kv_heads=2, d_ff=128, vocab_size=128)
    draft_cfg = ModelConfig(name="chk-draft", family="dense", num_layers=1,
                            d_model=32, num_heads=2, num_kv_heads=1,
                            d_ff=64, vocab_size=128, tie_embeddings=True)
    target = ModelBundle(tf.init_model(jax.random.PRNGKey(0), target_cfg),
                         target_cfg)
    draft = ModelBundle(tf.init_model(jax.random.PRNGKey(9), draft_cfg),
                        draft_cfg)
    pcfg = PipeDecConfig(n_stages=4, width=4, branch=2)
    max_len = 128

    rng = np.random.default_rng(0)
    reqs = [Request(i,
                    rng.integers(0, 100, size=int(rng.integers(3, 8)))
                    .astype(np.int32),
                    int(rng.integers(3, 7)),
                    arrival_t=int(rng.integers(0, 3 * args.requests)))
            for i in range(args.requests)]

    single = PipeDecEngine(target, draft, pcfg, max_len=max_len)
    want = {r.uid: single.generate(r.prompt, r.max_new_tokens)[0]
            for r in reqs}

    mk = {
        "local": lambda: LocalFusedExecutor(
            target, draft, slots=args.slots, max_len=max_len,
            tree_capacity=pcfg.tree_buffer_capacity,
            capacity=pcfg.capacity),
        "sharded": lambda: ShardedPipelineExecutor(
            target, draft, slots=args.slots, max_len=max_len,
            tree_capacity=pcfg.tree_buffer_capacity,
            capacity=pcfg.capacity, n_stages=args.stages),
    }
    summary = {"stages": args.stages, "slots": args.slots,
               "requests": args.requests, "layers": layers}
    for name, make in mk.items():
        ex = make()
        eng = SpecPipeDBEngine(target, draft, pcfg, max_len=max_len,
                               max_slots=args.slots, executor=ex)
        for r in reqs:
            eng.submit(r)
        res = eng.run()
        for uid, tokens in want.items():
            np.testing.assert_array_equal(
                res[uid].tokens, tokens,
                err_msg=f"{name} executor vs single-request uid={uid}")
        disp = eng.stats.verify_dispatches
        assert max(disp) == 1, f"{name}: >1 dispatch in one timestep"
        assert ex.calls["verify_rows"] == sum(disp), \
            f"{name}: one batched dispatch per pending timestep"
        if name == "sharded":
            assert ex.calls["pipeline_verify"] == sum(disp), \
                "one batched sharded tick per pending timestep"
        summary[name] = {
            "timesteps": eng.stats.timesteps,
            "tokens_per_timestep": round(eng.stats.tokens_per_timestep, 4),
            "peak_occupancy": eng.stats.peak_occupancy,
            "dispatches": dict(ex.calls),
        }
    summary["bit_identical"] = True
    print(json.dumps(summary))
    return 0


if __name__ == "__main__":
    sys.exit(main())
