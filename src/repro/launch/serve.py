"""Serving driver: load (or randomly init) target + draft, run a batch of
requests through the ServingEngine in pp, pipedec, or pipedec-db mode.

  PYTHONPATH=src python -m repro.launch.serve --mode pipedec --requests 4

SpecPipe-DB on the sharded pipeline deployment (one stage per device;
combine with XLA_FLAGS=--xla_force_host_platform_device_count=8 on CPU):

  PYTHONPATH=src python -m repro.launch.serve --mode pipedec-db \
      --executor sharded --requests 4

``--overlap`` selects the steady-state overlapped schedule (persistent
always-full ring, ONE tick per global timestep, deferred exit logits,
in-ring pruning propagation) instead of the per-timestep flush; the
PipeDec stage count is then the mesh's device count, since the ring IS
the flight bookkeeping:

  PYTHONPATH=src python -m repro.launch.serve --mode pipedec-db \
      --executor sharded --overlap --requests 4

``--executor async`` replaces the host-lockstep tick entirely:
free-running per-stage actor threads (one per stage/device) plus a
disaggregated draft actor, bit-identical greedy tokens to the lockstep
backends:

  PYTHONPATH=src python -m repro.launch.serve --mode pipedec-db \
      --executor async --stages 4 --requests 4
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro import configs as cfg_reg
from repro.checkpoint import load_pytree
from repro.core.pipedec import PipeDecConfig
from repro.core.speculative import ModelBundle
from repro.models import transformer as tf
from repro.serving import Request, ServingEngine


def build_bundle(arch: str, *, smoke: bool, seed: int, ckpt: str = "",
                 vocab_floor: int = 0):
    """Init (or load from ``ckpt``) one arch and wrap it as a
    ``ModelBundle`` with jitted prefill/decode/tree_verify.
    """
    cfg = cfg_reg.get_config(arch, smoke=smoke)
    if vocab_floor and cfg.vocab_size < vocab_floor:
        cfg = dataclasses.replace(cfg, vocab_size=vocab_floor)
    if ckpt:
        params = load_pytree(ckpt)["params"]
    else:
        params = tf.init_model(jax.random.PRNGKey(seed), cfg)
    return ModelBundle(params, cfg)


def main(argv=None):
    """CLI entry: build target+draft bundles, pick the executor backend
    (``--executor local|sharded|async``), run the engine, print
    per-request results and DB stats.
    """
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["pp", "pipedec", "pipedec-db"],
                    default="pipedec")
    ap.add_argument("--executor", choices=["local", "sharded", "async"],
                    default="local",
                    help="pipedec-db compute backend (sharded = one "
                         "pipeline stage per mesh device; async = "
                         "free-running per-stage actor threads + a "
                         "disaggregated draft actor, no host lockstep)")
    ap.add_argument("--overlap", action="store_true",
                    help="sharded executor only: steady-state overlapped "
                         "schedule (one ring tick per timestep with "
                         "deferred exit logits) instead of the "
                         "per-timestep flush; forces --stages to the "
                         "device count")
    ap.add_argument("--target-arch", default="pipedec-target")
    ap.add_argument("--draft-arch", default="pipedec-draft")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--width", type=int, default=8)
    ap.add_argument("--branch", type=int, default=4)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--quant", choices=["none", "int8"], default="none",
                    help="int8: serve both bundles quantized "
                         "(ModelBundle.quantize() — per-out-channel int8 "
                         "weights + int8 KV arena, ~3x the slots per byte "
                         "budget; dense attention architectures only)")
    ap.add_argument("--paged", action="store_true",
                    help="pipedec-db only: block-paged KV arenas "
                         "(models.paging pools behind per-slot block "
                         "tables; the local backend's PagedKVArena backs "
                         "each request's horizon instead of max_len)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="rows per KV block under --paged (power of two)")
    args = ap.parse_args(argv)

    target = build_bundle(args.target_arch, smoke=args.smoke, seed=0)
    draft = build_bundle(args.draft_arch, smoke=args.smoke, seed=1)
    if args.quant == "int8":
        target, draft = target.quantize(), draft.quantize()
    if args.overlap:
        assert args.mode == "pipedec-db" and args.executor == "sharded", \
            "--overlap needs --mode pipedec-db --executor sharded"
        # the overlapped ring length is pcfg.n_stages — it must equal the
        # mesh's stage count (one device per stage)
        args.stages = len(jax.devices())
    pcfg = PipeDecConfig(n_stages=args.stages, width=args.width,
                         branch=args.branch)
    executor = None
    if args.mode == "pipedec-db" and args.executor == "async":
        assert not args.paged, \
            "--executor async has no paged path yet (use --executor " \
            "sharded --paged)"
        from repro.serving import AsyncPipelineExecutor
        executor = AsyncPipelineExecutor(
            target, draft, slots=args.slots, max_len=512,
            tree_capacity=pcfg.tree_buffer_capacity,
            capacity=pcfg.capacity, n_stages=args.stages)
    elif args.mode == "pipedec-db" and args.executor == "sharded":
        from repro.serving import (OverlappedShardedExecutor,
                                   ShardedPipelineExecutor)
        cls = OverlappedShardedExecutor if args.overlap \
            else ShardedPipelineExecutor
        executor = cls(
            target, draft, slots=args.slots, max_len=512,
            tree_capacity=pcfg.tree_buffer_capacity,
            capacity=pcfg.capacity, n_stages=len(jax.devices()),
            paged=args.paged, page=args.page_size)
    elif args.mode == "pipedec-db" and args.paged:
        from repro.serving import LocalFusedExecutor
        executor = LocalFusedExecutor(
            target, draft, slots=args.slots, max_len=512,
            tree_capacity=pcfg.tree_buffer_capacity,
            capacity=pcfg.capacity, paged=True, page=args.page_size)
    engine = ServingEngine(
        target, draft, mode=args.mode, max_batch=args.slots,
        pipedec=pcfg, executor=executor)
    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        prompt = rng.integers(0, target.cfg.vocab_size,
                              size=8).astype(np.int32)
        engine.submit(Request(uid, prompt, args.new_tokens))
    results = engine.run()
    if args.executor == "async" and executor is not None:
        executor.shutdown()
    for uid, res in sorted(results.items()):
        extra = ""
        if res.stats is not None and hasattr(res.stats, "acceptance"):
            extra = (f" acc={res.stats.acceptance:.2f}"
                     f" tps={res.stats.tokens_per_timestep:.2f}")
        print(f"req {uid}: {res.tokens.tolist()[:10]}... "
              f"{res.latency_s*1e3:.1f}ms{extra}")


if __name__ == "__main__":
    main()
