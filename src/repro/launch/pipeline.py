"""Paper-faithful pipeline-parallel deployment (shard_map over "model").

The paper's cluster runs MPMD stages coordinated by Redis; on TPU the same
schedule is SPMD: every device executes one *tick* per timestep.

One PipeDec tick (= paper timestep, Fig. 2):
  * stage 0 ingests the newest tree layer (from the draft model); every
    other stage keeps the in-flight layer its ring slot holds;
  * each stage first applies the *control* message that reached it this
    tick (exit-commit + prune compaction — the paper's pruning-propagation
    stage, see below), then applies its layer block to the tree layer it
    holds, reading/writing its local slice of the two-level KV cache;
  * the activation leaving the last stage is gathered and unembedded into
    the verification logits of the layer that completed the pipeline;
  * activations + metadata rotate one stage forward via
    ``jax.lax.ppermute`` — this collective IS the paper's transmission
    scheduler (Appendix A), compiled instead of orchestrated.

A layer entering at timestep t therefore exits at ``t + n_stages - 1`` —
the same pipeline-fill latency the logical engine's ``Flight.exit_t``
books, so one tick per timestep IS the engine schedule, compiled.

Each in-flight layer carries its metadata (absolute positions, ancestor
mask rows, tree-buffer write index, committed length, and a per-slot tree
**version** counter) in the same ring so every stage uses the values
frozen at that layer's entry — exactly the paper's data-flow semantics.

SpecPipe-DB rides the same ring *batched*: every ring/entry leaf and every
stage cache carries a leading slot axis (``batch`` = KV slots), so one tick
moves EVERY in-flight request's tree layer one stage forward.

The per-stage math itself (layer application, ctrl commit+compact, chunk
prefill) is factored into ``make_stage_fns`` so it has exactly ONE
definition: the lockstep tick below composes those functions inside a
``shard_map`` body, and the free-running async executor
(``serving.executor.AsyncPipelineExecutor``) jits the *same* functions
per stage actor — which is how the async schedule stays bit-identical to
the lockstep references by construction.

Two lockstep executor schedules drive this tick (``serving.executor``);
a third (async) backend replaces the tick with free-running per-stage
actors over the same stage functions:

  * **flush** (``ShardedPipelineExecutor`` via ``make_pipeline_verify``):
    each global timestep pushes the batched entry layer through all
    ``n_stages`` hops inside ONE compiled dispatch, so verify logits are
    available at the *entry* timestep and buffered by the engine until
    exit.  Bit-exact by construction; prices at ``n_stages`` hops per
    timestep (``core.sim.specpipe_db_sharded_* flush=True``).
  * **overlapped** (``OverlappedShardedExecutor``): the ring persists
    across timesteps and stays *full* — ONE tick per global timestep, the
    paper's steady-state wall-clock regime (``flush=False`` pricing).
    Verify logits only exist at the layer's *exit* timestep, so the
    engine's ``Flight``s resolve deferred-logit futures, and correctness
    under pruning needs the in-ring mechanisms this module compiles:

      - **gated ctrl channel** (pruning propagation): the exit decision
        at timestep t (commit length + old→new prune ``index_map``)
        enters the ring at t+1 and reaches stage k at tick t+1+k —
        exactly after stage k processed every pre-prune in-flight layer
        (stage k runs layer j at tick j+k) and exactly before it
        processes the first post-prune layer.  Each stage applies
        commit-then-compact to its local cache slice on arrival, so
        pre-prune layers always read pre-prune rows and post-prune layers
        always read compacted rows — the in-flight schedule computes
        bit-identical logits to the flush.  The channel is *gated*: an
        ``active`` predicate enters with the message and rides the ring
        beside it (``c_active``, one bool per stage slot), and each
        stage's commit-scatter + prune-gather is wrapped in
        ``jax.lax.cond`` on its local predicate — the all-identity /
        no-commit message that rides most ticks costs a predicate check
        instead of a full scatter+gather per stage.  The executor only
        raises the predicate on timesteps where exit ctrl was actually
        queued, and an inactive message is by construction the identity,
        so gating is bit-exact.
      - **kill + version** (miss / retire invalidation): a ``kill [B]``
        input invalidates every in-flight layer of a pruned-to-miss or
        retired slot wherever it is in the ring (stale layers stop
        writing their stage tree-cache rows and exit with
        ``valid=False``); the per-slot ``version`` counter rides with
        each layer and is returned at exit so the executor can prove a
        resolved future belongs to the slot's *current* tree.
      - **prefill-in-ring** (overlapped admission): with
        ``prefill_cap > 0`` the ring carries a second lane
        (``p_act [S, B, Pcap, d]`` + per-slot ``p_len``/``p_on``/
        ``p_off``) for admission prefills.  A joining request's padded
        prompt *chunk* enters at stage 0 as a special layer kind the
        same tick the in-flight tree layers advance; each stage applies
        its layers in *chunk* (prefill) mode to the lane — gated by
        ``jax.lax.cond`` on "any prefill at this stage", so the empty
        lane that rides most ticks is free — writing the slot's
        model-cache rows [p_off, p_off + Pcap) stage by stage.  The
        chunk's last-position hidden state exits ``n_stages - 1`` ticks
        later (``p_last``/``p_valid``; the lane never touches the tree
        exit, so the prefill is a *dead exit* there), and admitting a
        request no longer costs the ring a separate dispatch or an idle
        timestep.  Prompts longer than ``prefill_cap`` stream through
        the lane over several consecutive ticks (*chunked prefill*):
        the executor feeds chunk c at tick t+c with its row offset
        ``p_off = c * Pcap``, so stage k sees chunk c at tick t+c+k —
        strictly after it wrote chunk c-1's rows — and each chunk
        attends over every earlier chunk's cached rows, which makes the
        cached K/V bit-identical to a one-shot prefill (row projections
        are row-independent; see ``attention.attn_prefill_chunk``).
        Pad rows beyond ``p_len`` are causally masked at positions <
        len and only ever overwrite model rows that the growing
        ``model_len`` (or the next chunk) overwrites again before
        reading — outputs stay bit-identical to the separate-dispatch
        prefill.

Supports attention-family architectures (dense / VLM / MoE-with-attention);
recurrent families use chain-mode speculative decoding instead (DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Tuple

import jax
import jax.numpy as jnp
try:                                   # jax >= 0.6 exports it at top level
    from jax import shard_map as _shard_map
except ImportError:                    # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

_SHARD_MAP_KW = inspect.signature(_shard_map).parameters


def shard_map(f, **kwargs):
    """``jax.shard_map`` wrapper translating check_vma/check_rep across
    jax versions.
    """
    # jax renamed check_rep -> check_vma; translate for older versions
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_KW:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map(f, **kwargs)

from repro.models import attention as attn_mod
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.models.layers import embed, rmsnorm, unembed


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Static shape of the pipelined deployment: stage count, tree layer
    width w (rows per ring entry), tree KV capacity and model KV
    length.
    """
    n_stages: int
    width: int            # w (tree layer width)
    tree_capacity: int    # tree KV buffer rows
    max_len: int          # model KV buffer rows


def stage_layout(cfg: ModelConfig, n_stages: int) -> Tuple[int, int]:
    """(layers_per_stage, padded_total). Only the uniform 'stack' region is
    pipelined; prefix/tail layers (rare) fold into stage 0 / S-1 ... we
    require a pure-stack arch for the pipeline deployment."""
    n_prefix, reps, tail = tf.layout(cfg)
    assert n_prefix == 0 and not tail, \
        "pipeline deployment expects a uniform layer stack"
    lps = -(-reps // n_stages)
    return lps, lps * n_stages


def stage_params(cfg: ModelConfig, params, n_stages: int):
    """Stage layout: a LIST of ``lps`` per-layer trees, each leaf [S, ...]
    (stage dim stacked/sharded over 'model'; the within-stage layer dim is
    unrolled into separate buffers so XLA cannot hoist whole-stack
    converts/copies ahead of the layer loop — §Perf H3) + validity [S, Lps].
    """
    lps, padded = stage_layout(cfg, n_stages)
    reps = tf.layout(cfg)[1]

    def reshape(x):
        pad = padded - reps
        if pad:
            x = jnp.concatenate(
                [x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], 0)
        return x.reshape(n_stages, lps, *x.shape[1:])

    stacked = jax.tree.map(reshape, params["stack"])
    layers = [jax.tree.map(lambda t: t[:, l], stacked) for l in range(lps)]
    valid = (jnp.arange(padded) < reps).reshape(n_stages, lps)
    return layers, valid


def init_stage_caches(cfg: ModelConfig, pcfg: PipelineConfig,
                      dtype=jnp.float32, batch: int = 1):
    """Per-stage model + tree caches: lists (per in-stage layer) of
    [S, B, rows, ...] buffers.  ``batch`` is the KV-slot axis mirroring
    the slot-stacked ``serving.scheduler.KVArena`` (B=1 = the
    single-request deployment)."""
    lps, _ = stage_layout(cfg, pcfg.n_stages)
    kv = attn_mod.init_kv_cache(cfg, batch, pcfg.max_len, dtype)
    tkv = attn_mod.init_kv_cache(cfg, batch, pcfg.tree_capacity + pcfg.width,
                                 dtype)
    tile = lambda c: [jax.tree.map(
        lambda x: jnp.zeros((pcfg.n_stages, *x.shape), x.dtype), c)
        for _ in range(lps)]
    return tile(kv), tile(tkv)


def init_ring(cfg: ModelConfig, pcfg: PipelineConfig, dtype=jnp.float32,
              batch: int = 1, ctrl: bool = False, prefill_cap: int = 0):
    """In-flight activation + metadata ring, one slot per stage.  Every
    leaf carries the KV-slot axis ``batch`` right after the stage dim —
    a batched tick moves every slot's layer one stage forward together.

    ``ctrl=True`` (the overlapped executor) adds the pruning-propagation
    channel: per stage-slot exit-commit mask/length and an old→new prune
    ``index_map`` that each stage applies to its local cache slice the
    tick the message reaches it (identity maps are the no-op, so the
    channel is always well-formed), plus the per-stage ``c_active``
    gating predicate that rides beside the message (False = the message
    is the identity and the stage skips the whole application).

    ``prefill_cap > 0`` adds the prefill lane (overlapped admission):
    per-stage padded prompt-chunk activations ``p_act`` with their
    ``p_len``/``p_on``/``p_off`` metadata (``p_off`` is the chunk's
    absolute row offset — the per-slot chunk cursor of chunked
    prefill), advancing one stage per tick like the tree layers."""
    s, w = pcfg.n_stages, pcfg.width
    ring = {
        "act": jnp.zeros((s, batch, w, cfg.d_model), dtype),
        "positions": jnp.zeros((s, batch, w), jnp.int32),
        "mask": jnp.zeros((s, batch, w, pcfg.tree_capacity + pcfg.width),
                          bool),
        "write_idx": jnp.zeros((s, batch), jnp.int32),
        "model_len": jnp.zeros((s, batch), jnp.int32),
        "valid": jnp.zeros((s, batch), bool),
        "version": jnp.zeros((s, batch), jnp.int32),
    }
    if ctrl:
        ring["c_commit"] = jnp.zeros((s, batch), bool)
        ring["c_len"] = jnp.zeros((s, batch), jnp.int32)
        ring["c_imap"] = jnp.broadcast_to(
            jnp.arange(pcfg.tree_capacity, dtype=jnp.int32),
            (s, batch, pcfg.tree_capacity))
        ring["c_active"] = jnp.zeros((s,), bool)
    if prefill_cap:
        ring["p_act"] = jnp.zeros((s, batch, prefill_cap, cfg.d_model),
                                  dtype)
        ring["p_len"] = jnp.zeros((s, batch), jnp.int32)
        ring["p_on"] = jnp.zeros((s, batch), bool)
        ring["p_off"] = jnp.zeros((s, batch), jnp.int32)
    return ring


def make_stage_fns(cfg: ModelConfig, pcfg: PipelineConfig):
    """The per-stage compute, defined ONCE for every pipeline schedule.

    Returns ``(stage_apply, stage_ctrl, stage_prefill)``:

      * ``stage_apply(stage_p, valid_row, kv, tkv, x, positions, mask,
        write_idx, model_len, in_valid) -> (x_out, new_tkv)`` — apply one
        stage's layer block to its in-flight batched tree layer
        ([B, w, d]; per-row metadata frozen at that layer's ring entry).
        Invalid rows (``in_valid`` or a padded ``valid_row`` layer) pass
        activations through untouched and leave the tree cache unwritten.
      * ``stage_ctrl(kv, tkv, commit_on, commit_len, index_map) ->
        (kv, tkv)`` — the pruning-propagation message applied to one
        stage's local cache slice: exit-commit tree row 0 into the model
        cache, then compact the tree rows through the old→new
        ``index_map`` (identity map + ``commit_on=False`` is the no-op).
      * ``stage_prefill(stage_p, valid_row, kv, x, on, off) ->
        (new_kv, x_out)`` — one stage's layers in chunk (prefill) mode
        over a padded prompt lane [B, Pcap, d], writing participating
        slots' model-cache rows [off, off + Pcap).

    The lockstep ``make_pipedec_tick`` composes these inside its
    ``shard_map`` body; ``serving.executor.AsyncPipelineExecutor`` jits
    the very same functions once per free-running stage actor.  One
    definition of the math is what makes the two schedules bit-identical
    on greedy workloads — they differ only in WHEN each stage runs, not
    in what it computes.
    """
    kinds = tf.unit_kinds(cfg)
    assert kinds == ("attn",), "pipeline stages support attention stacks"
    lps, _ = stage_layout(cfg, pcfg.n_stages)

    def stage_apply(stage_p, valid_row, kv, tkv, x, positions, mask,
                    write_idx, model_len, in_valid):
        """Apply this stage's layers to its in-flight batched tree layer
        ([B, w, d] activations; per-row metadata rides with the layer)."""
        ctx = tf.Ctx(mode="tree", positions=positions,
                     cache_len=jnp.asarray(model_len, jnp.int32),
                     tree_write_index=jnp.asarray(write_idx, jnp.int32),
                     tree_mask=mask)
        xs = x  # [B, w, d]
        new_tkv = []
        for l in range(lps):
            # per-layer param/cache buffers (lists over the in-stage dim)
            unit_p = stage_p[l]
            c = [kv[l]]
            tc = [tkv[l]]
            y, _, ntc, _ = tf._apply_unit(unit_p, cfg, kinds, xs, c, tc, ctx)
            ok = valid_row[l] & in_valid                 # [B]
            xs = jnp.where(ok[:, None, None], y, xs)
            new_tkv.append(jax.tree.map(
                lambda old, new, k=ok: jnp.where(
                    k.reshape((-1,) + (1,) * (old.ndim - 1)), new, old),
                tc[0], ntc[0]))
        return xs, new_tkv

    def stage_ctrl(kv, tkv, commit_on, commit_len, index_map):
        """Commit-then-compact one stage's local caches (the pruning
        propagation message; identity map + no commit is the no-op)."""
        node0 = jnp.zeros_like(commit_len)
        kv = [tf.commit_tree_nodes(cfg, kv[l], tkv[l], node0, commit_len,
                                   commit_on)
              for l in range(lps)]
        tkv = [tf.remap_tree_cache_rows(tkv[l], index_map)
               for l in range(lps)]
        return kv, tkv

    def stage_prefill(stage_p, valid_row, kv, x, on, off):
        """Apply this stage's layers in CHUNK (prefill) mode over the
        padded prompt lane ([B, Pcap, d]), writing each participating
        slot's model-cache rows [off[b], off[b] + Pcap) — the same
        per-layer math ``tf.prefill_chunk`` runs, partitioned stage by
        stage.  A whole prompt that fits the lane is the off == 0
        single-chunk case."""
        cap = x.shape[1]
        off = jnp.asarray(off, jnp.int32)
        positions = off[:, None] + jnp.arange(cap, dtype=jnp.int32)[None]
        ctx = tf.Ctx(mode="chunk", positions=positions, cache_len=off)
        xs = x
        new_kv = []
        for l in range(lps):
            y, nc, _, _ = tf._apply_unit(stage_p[l], cfg, kinds, xs,
                                         [kv[l]], None, ctx)
            ok = valid_row[l] & on                       # [B]
            xs = jnp.where(ok[:, None, None], y, xs)
            new_kv.append(jax.tree.map(
                lambda old, new, k=ok: jnp.where(
                    k.reshape((-1,) + (1,) * (old.ndim - 1)),
                    new.astype(old.dtype), old),
                kv[l], nc[0]))
        return new_kv, xs

    return stage_apply, stage_ctrl, stage_prefill


def make_pipedec_tick(cfg: ModelConfig, pcfg: PipelineConfig, mesh,
                      prefill_cap: int = 0):
    """Build the jittable one-timestep LOCKSTEP pipeline tick
    (slot-batched): one ``shard_map`` dispatch advances every stage in
    unison.  The per-stage math comes from ``make_stage_fns``; the async
    executor runs those same functions free-running instead of calling
    this tick.

    Inputs (global shapes; ``B`` = KV slots, B=1 = single-request):
      stage_p:    unit params [S, Lps, ...]        (stage-sharded)
      stage_valid:[S, Lps] bool
      caches:     (model_kv, tree_kv) [S, B, rows, ...] per in-stage layer
      ring:       see init_ring (every leaf [S, B, ...])
      entry:      dict with the NEW layer for stage 0:
                  tokens->embedded x [B, w, d], positions [B, w],
                  mask [B, w, tcap+w], write_idx [B], model_len [B],
                  valid [B], version [B]
      kill:       [B] bool or None — invalidate every in-flight layer of
                  these slots (miss / retire: the pruning-propagation
                  kill; the entry ingested THIS tick is never killed)
      ctrl:       None, or {"commit" [B] bool, "commit_len" [B] i32,
                  "index_map" [B, cap] i32, "clear" [B] bool,
                  "active" [] bool} — the exit decision of the previous
                  timestep, entering at stage 0 and applied by each stage
                  (commit row 0 → model cache, then compact the tree
                  rows) the tick it arrives, BEFORE that stage's layer
                  compute.  Identity index_map + commit False is the
                  per-slot no-op; ``active`` is the *gate*: it rides the
                  ring beside the message (``c_active``) and each stage
                  wraps the whole commit-scatter + prune-gather in
                  ``jax.lax.cond`` on it, so an inactive (all-identity)
                  message costs a predicate check instead of a
                  scatter+gather per stage.  The caller must only raise
                  ``active`` when the message is not the identity.
                  ``clear`` neutralises the slot's ctrl messages still
                  RIDING the ring (retire: the slot is being recycled,
                  and a retired occupant's in-flight commits/prunes must
                  never reach the next occupant's freshly prefilled
                  caches); a miss must NOT clear — the missed request's
                  earlier commits stay valid and must finish propagating.
      pentry:     (only when ``prefill_cap > 0``) {"act" [B, Pcap, d],
                  "len" [B] i32, "on" [B] bool, "off" [B] i32} —
                  admission prefill *chunks* entering the prefill lane
                  at stage 0 (``off`` = the chunk's absolute row
                  offset; 0 for a whole prompt that fits the lane).
                  Each stage applies its layers in chunk (prefill) mode
                  to the lane the tick it holds it — under
                  ``jax.lax.cond`` on "any prefill at this stage", so
                  the empty lane is free — writing the slot's
                  model-cache rows [off, off + Pcap).  Chunks of one
                  slot must be fed on consecutive ticks in order; each
                  chunk's queries attend over the rows every earlier
                  chunk already wrote at this stage.  The chunk's
                  last-position hidden state is returned at exit
                  (``p_last [B, d]``, ``p_valid [B]``); the tree-layer
                  exit for those slots stays dead.

    Stage 0 ingests the entry THIS tick (and processes it this tick), so
    an entry at tick t exits at tick ``t + n_stages - 1`` — the engine's
    ``Flight.exit_t``.  Returns (new model_kv, new tree_kv, new ring,
    exit: {act [B, w, d], valid [B], version [B](, p_last, p_valid)}).
    """
    s_axis = "model"
    n_stages = pcfg.n_stages
    stage_apply, stage_ctrl, stage_prefill = make_stage_fns(cfg, pcfg)

    def tick(stage_p, stage_valid, model_kv, tree_kv, ring, entry,
             kill=None, ctrl=None, pentry=None):
        assert (pentry is not None) == bool(prefill_cap), \
            "pass pentry iff the tick was built with prefill_cap > 0"

        def body(stage_p, stage_valid, model_kv, tree_kv, ring, entry,
                 kill, ctrl, pentry):
            # local slices carry a leading stage dim of 1 (dropped below)
            sp = [jax.tree.map(lambda t: t[0], lp) for lp in stage_p]
            sv = stage_valid[0]
            kv = [jax.tree.map(lambda t: t[0], lc) for lc in model_kv]
            tkv = [jax.tree.map(lambda t: t[0], lc) for lc in tree_kv]

            idx = jax.lax.axis_index(s_axis)
            is0 = (idx == 0)

            # 1. kill: invalidate the in-flight layers of pruned/retired
            # slots wherever they are in the ring — they stop writing and
            # exit dead (their tree version is stale)
            valid_r = ring["valid"]
            if kill is not None:
                valid_r = valid_r & ~kill[None]

            # 2. ingest: stage 0 adopts the new layer (+ the ctrl message
            # entering behind the in-flight layers); every other stage
            # works on the layer its ring slot holds
            pick = lambda e, r: jnp.where(is0, e[None], r)
            cur = {
                "act": pick(entry["act"], ring["act"]),
                "positions": pick(entry["positions"], ring["positions"]),
                "mask": pick(entry["mask"], ring["mask"]),
                "write_idx": pick(entry["write_idx"], ring["write_idx"]),
                "model_len": pick(entry["model_len"], ring["model_len"]),
                "valid": pick(entry["valid"], valid_r),
                "version": pick(entry["version"], ring["version"]),
            }
            if ctrl is not None:
                # retire-clear: neutralise the slot's ctrl wherever it is
                # in the ring (a recycled slot's old occupant may still
                # have commit/remap messages trailing its killed layers)
                clr = ctrl["clear"]
                cap_i = ctrl["index_map"].shape[-1]
                ring_commit = ring["c_commit"] & ~clr[None]
                ring_len = jnp.where(clr[None], 0, ring["c_len"])
                ring_imap = jnp.where(
                    clr[None, :, None],
                    jnp.arange(cap_i, dtype=jnp.int32)[None, None],
                    ring["c_imap"])
                cur["c_commit"] = pick(ctrl["commit"], ring_commit)
                cur["c_len"] = pick(ctrl["commit_len"], ring_len)
                cur["c_imap"] = pick(ctrl["index_map"], ring_imap)
                cur["c_active"] = jnp.where(
                    is0, jnp.reshape(ctrl["active"], (1,)),
                    ring["c_active"])

                # 3. pruning propagation: apply the ctrl that reached this
                # stage — commit first (tree row 0 is still the exiting
                # root), then compact this stage's tree rows.  The message
                # trails every pre-prune in-flight layer and leads every
                # post-prune one, so each stage flips its local caches at
                # exactly the schedule point the flush executor does
                # centrally.  Gated: the whole commit-scatter +
                # prune-gather runs under ``lax.cond`` on the message's
                # ``c_active`` flag — the all-identity message that rides
                # most ticks costs one predicate check per stage.
                def apply_ctrl(ops):
                    kv_, tkv_ = ops
                    return stage_ctrl(kv_, tkv_, cur["c_commit"][0],
                                      cur["c_len"][0], cur["c_imap"][0])

                kv, tkv = jax.lax.cond(cur["c_active"][0], apply_ctrl,
                                       lambda ops: ops, (kv, tkv))

            # 3b. prefill lane: a joining slot's padded prompt advances
            # one stage per tick beside the tree layers; the stage
            # applies its layers in full mode (writing the slot's
            # model-cache rows) only when a prefill actually sits here —
            # the empty lane costs one any() per tick.
            p_x = None
            if prefill_cap:
                p_on_r = ring["p_on"]
                if kill is not None:
                    p_on_r = p_on_r & ~kill[None]
                cur["p_act"] = pick(pentry["act"], ring["p_act"])
                cur["p_len"] = pick(pentry["len"], ring["p_len"])
                cur["p_on"] = pick(pentry["on"], p_on_r)
                cur["p_off"] = pick(pentry["off"], ring["p_off"])
                pon = cur["p_on"][0]
                kv, p_x = jax.lax.cond(
                    jnp.any(pon),
                    lambda kv_, px: stage_prefill(sp, sv, kv_, px, pon,
                                                  cur["p_off"][0]),
                    lambda kv_, px: (kv_, px),
                    kv, cur["p_act"][0])

            # 4. compute: this stage's layers over the layer it holds
            x, new_tkv = stage_apply(
                sp, sv, kv, tkv, cur["act"][0], cur["positions"][0],
                cur["mask"][0], cur["write_idx"][0], cur["model_len"][0],
                cur["valid"][0])

            # 5. exit: the layer the last stage just finished
            is_last = (idx == n_stages - 1)
            fl = is_last.astype(x.dtype)
            exit_act = jax.lax.psum(x * fl, s_axis)
            exit_valid = jax.lax.psum(
                (cur["valid"][0] & is_last).astype(jnp.int32), s_axis) > 0
            exit_version = jax.lax.psum(
                cur["version"][0] * is_last.astype(jnp.int32), s_axis)
            exit_out = {"act": exit_act, "valid": exit_valid,
                        "version": exit_version}
            if prefill_cap:
                # the prefill lane's exit: the prompt's last-position
                # hidden state after every stage's layers (the tree exit
                # above stays dead for joining slots)
                last = jnp.clip(cur["p_len"][0] - 1, 0, prefill_cap - 1)
                x_last = jnp.take_along_axis(
                    p_x, last[:, None, None], axis=1)[:, 0]      # [B, d]
                exit_out["p_last"] = jax.lax.psum(
                    x_last * is_last.astype(x_last.dtype), s_axis)
                exit_out["p_valid"] = jax.lax.psum(
                    (pon & is_last).astype(jnp.int32), s_axis) > 0

            # 6. rotate one stage forward (paper's transmission step);
            # stage 0's slot empties (refilled by the next ingest)
            perm = [(i, i + 1) for i in range(n_stages - 1)]
            shift = lambda v: jax.lax.ppermute(v, s_axis, perm)
            # rotate the POST-compute activations; the stale pre-compute
            # acts must not ride (nor cost a dead collective)
            new_ring = {k: shift(v) for k, v in cur.items()
                        if k not in ("act", "p_act")}
            new_ring["act"] = shift(x[None])
            if prefill_cap:
                new_ring["p_act"] = shift(p_x[None])

            new_kv = [jax.tree.map(lambda t: t[None], lc) for lc in kv]
            new_tkv = [jax.tree.map(lambda t: t[None], lc) for lc in new_tkv]
            return (new_kv, new_tkv, new_ring, exit_out)

        kv_spec = jax.tree.map(lambda _: P(s_axis), model_kv)
        tkv_spec = jax.tree.map(lambda _: P(s_axis), tree_kv)
        ring_spec = jax.tree.map(lambda _: P(s_axis), ring)
        entry_spec = jax.tree.map(lambda _: P(), entry)
        kill_spec = None if kill is None else P()
        ctrl_spec = None if ctrl is None else jax.tree.map(
            lambda _: P(), ctrl)
        pentry_spec = None if pentry is None else jax.tree.map(
            lambda _: P(), pentry)
        exit_spec = {"act": P(), "valid": P(), "version": P()}
        if prefill_cap:
            exit_spec["p_last"] = P()
            exit_spec["p_valid"] = P()
        out = shard_map(
            body, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(s_axis), stage_p),
                      P(s_axis), kv_spec, tkv_spec, ring_spec, entry_spec,
                      kill_spec, ctrl_spec, pentry_spec),
            out_specs=(kv_spec, tkv_spec, ring_spec, exit_spec),
            check_vma=False,
        )(stage_p, stage_valid, model_kv, tree_kv, ring, entry, kill, ctrl,
          pentry)
        return out

    return tick


def make_pipeline_verify(cfg: ModelConfig, pcfg: PipelineConfig, mesh,
                         dtype=jnp.float32):
    """One-dispatch batched tree-verify through the sharded pipeline (the
    FLUSH executor schedule).

    Ingests a batched entry layer into stage 0 of a fresh ring, then runs
    exactly ``n_stages`` ticks so the layer traverses every stage and
    exits — yielding the same verification hidden states the
    single-device ``tree_verify_step`` computes, but partitioned
    stage-by-stage over the mesh with the metadata riding the ``ppermute``
    ring.  The whole flush is ONE compiled computation, so the serving
    executor issues exactly one sharded dispatch per global timestep
    (``tests/test_pipeline.py`` pins the tick count: stage 0 ingests AND
    processes on the same tick, so ``n_stages`` hops suffice — no
    trailing dead-entry tick).

    The flush keeps verify logits available at the layer's *entry*
    timestep, which is what keeps the logical engine's schedule — and
    therefore its outputs — bit-identical to the local backends without
    any in-ring pruning machinery; the steady-state one-tick-per-timestep
    deployment is ``serving.executor.OverlappedShardedExecutor``.

    Returns ``verify(stage_p, stage_valid, model_kv, tree_kv, entry) ->
    (exit_act [B, w, d], exit_valid [B], new_tree_kv)``.
    """
    tick = make_pipedec_tick(cfg, pcfg, mesh)

    def verify(stage_p, stage_valid, model_kv, tree_kv, entry):
        batch = entry["act"].shape[0]
        ring = init_ring(cfg, pcfg, dtype=dtype, batch=batch)
        ent = dict(entry)
        ent.setdefault("version", jnp.zeros((batch,), jnp.int32))
        dead = dict(ent, valid=jnp.zeros_like(ent["valid"]))
        exit_out = None
        for _ in range(pcfg.n_stages):
            model_kv, tree_kv, ring, exit_out = tick(
                stage_p, stage_valid, model_kv, tree_kv, ring, ent)
            ent = dead
        return exit_out["act"], exit_out["valid"], tree_kv

    return verify
