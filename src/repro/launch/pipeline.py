"""Paper-faithful pipeline-parallel deployment (shard_map over "model").

The paper's cluster runs MPMD stages coordinated by Redis; on TPU the same
schedule is SPMD: every device executes one *tick* per timestep.

One PipeDec tick (= paper timestep, Fig. 2):
  * each stage applies its layer block to the tree layer it currently
    holds, reading/writing its local slice of the two-level KV cache;
  * activations rotate one stage forward via ``jax.lax.ppermute`` —
    this collective IS the paper's transmission scheduler (Appendix A),
    compiled instead of orchestrated;
  * stage 0 ingests the newest tree layer (from the draft model);
    the activation leaving the last stage is gathered and unembedded into
    the verification logits of the layer that completed the pipeline.

Each in-flight layer carries its metadata (absolute positions, ancestor
mask rows, tree-buffer write index, committed length) in the same ring so
every stage uses the values frozen at that layer's entry — exactly the
paper's data-flow semantics.

SpecPipe-DB rides the same ring *batched*: every ring/entry leaf and every
stage cache carries a leading slot axis (``batch`` = KV slots), so one tick
moves EVERY in-flight request's tree layer one stage forward — the
per-row ``model_len`` / ``tree_write_index`` / ``tree_mask [B, n, Tcap]``
Ctx from the fused single-device path is exactly what each stage applies
to its local slice.  ``make_pipeline_verify`` flushes one batched layer
through all stages inside ONE compiled dispatch (ingest + ``n_stages``
ticks, ``ppermute`` rotation untouched) — the compute backend
``serving.executor.ShardedPipelineExecutor`` issues it once per global
timestep.

Supports attention-family architectures (dense / VLM / MoE-with-attention);
recurrent families use chain-mode speculative decoding instead (DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Any, Tuple

import jax
import jax.numpy as jnp
try:                                   # jax >= 0.6 exports it at top level
    from jax import shard_map as _shard_map
except ImportError:                    # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

_SHARD_MAP_KW = inspect.signature(_shard_map).parameters


def shard_map(f, **kwargs):
    # jax renamed check_rep -> check_vma; translate for older versions
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_KW:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map(f, **kwargs)

from repro.models import attention as attn_mod
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.models.layers import embed, rmsnorm, unembed


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_stages: int
    width: int            # w (tree layer width)
    tree_capacity: int    # tree KV buffer rows
    max_len: int          # model KV buffer rows


def stage_layout(cfg: ModelConfig, n_stages: int) -> Tuple[int, int]:
    """(layers_per_stage, padded_total). Only the uniform 'stack' region is
    pipelined; prefix/tail layers (rare) fold into stage 0 / S-1 ... we
    require a pure-stack arch for the pipeline deployment."""
    n_prefix, reps, tail = tf.layout(cfg)
    assert n_prefix == 0 and not tail, \
        "pipeline deployment expects a uniform layer stack"
    lps = -(-reps // n_stages)
    return lps, lps * n_stages


def stage_params(cfg: ModelConfig, params, n_stages: int):
    """Stage layout: a LIST of ``lps`` per-layer trees, each leaf [S, ...]
    (stage dim stacked/sharded over 'model'; the within-stage layer dim is
    unrolled into separate buffers so XLA cannot hoist whole-stack
    converts/copies ahead of the layer loop — §Perf H3) + validity [S, Lps].
    """
    lps, padded = stage_layout(cfg, n_stages)
    reps = tf.layout(cfg)[1]

    def reshape(x):
        pad = padded - reps
        if pad:
            x = jnp.concatenate(
                [x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], 0)
        return x.reshape(n_stages, lps, *x.shape[1:])

    stacked = jax.tree.map(reshape, params["stack"])
    layers = [jax.tree.map(lambda t: t[:, l], stacked) for l in range(lps)]
    valid = (jnp.arange(padded) < reps).reshape(n_stages, lps)
    return layers, valid


def init_stage_caches(cfg: ModelConfig, pcfg: PipelineConfig,
                      dtype=jnp.float32, batch: int = 1):
    """Per-stage model + tree caches: lists (per in-stage layer) of
    [S, B, rows, ...] buffers.  ``batch`` is the KV-slot axis mirroring
    the slot-stacked ``serving.scheduler.KVArena`` (B=1 = the
    single-request deployment)."""
    lps, _ = stage_layout(cfg, pcfg.n_stages)
    kv = attn_mod.init_kv_cache(cfg, batch, pcfg.max_len, dtype)
    tkv = attn_mod.init_kv_cache(cfg, batch, pcfg.tree_capacity + pcfg.width,
                                 dtype)
    tile = lambda c: [jax.tree.map(
        lambda x: jnp.zeros((pcfg.n_stages, *x.shape), x.dtype), c)
        for _ in range(lps)]
    return tile(kv), tile(tkv)


def init_ring(cfg: ModelConfig, pcfg: PipelineConfig, dtype=jnp.float32,
              batch: int = 1):
    """In-flight activation + metadata ring, one slot per stage.  Every
    leaf carries the KV-slot axis ``batch`` right after the stage dim —
    a batched tick moves every slot's layer one stage forward together."""
    s, w = pcfg.n_stages, pcfg.width
    return {
        "act": jnp.zeros((s, batch, w, cfg.d_model), dtype),
        "positions": jnp.zeros((s, batch, w), jnp.int32),
        "mask": jnp.zeros((s, batch, w, pcfg.tree_capacity + pcfg.width),
                          bool),
        "write_idx": jnp.zeros((s, batch), jnp.int32),
        "model_len": jnp.zeros((s, batch), jnp.int32),
        "valid": jnp.zeros((s, batch), bool),
    }


def make_pipedec_tick(cfg: ModelConfig, pcfg: PipelineConfig, mesh):
    """Build the jittable one-timestep pipeline tick (slot-batched).

    Inputs (global shapes; ``B`` = KV slots, B=1 = single-request):
      stage_p:    unit params [S, Lps, ...]        (stage-sharded)
      stage_valid:[S, Lps] bool
      caches:     (model_kv, tree_kv) [S, B, rows, ...] per in-stage layer
      ring:       see init_ring (every leaf [S, B, ...])
      entry:      dict with the NEW layer for stage 0:
                  tokens->embedded x [B, w, d], positions [B, w],
                  mask [B, w, tcap+w], write_idx [B], model_len [B],
                  valid [B]
    Returns (new tree caches, new ring,
             exit: {act [B, w, d], valid [B]}).
    """
    s_axis = "model"
    n_stages = pcfg.n_stages
    kinds = tf.unit_kinds(cfg)
    assert kinds == ("attn",), "pipeline tick supports attention stacks"
    lps, _ = stage_layout(cfg, n_stages)

    def local_stage(stage_p, valid_row, kv, tkv, x, positions, mask,
                    write_idx, model_len, in_valid):
        """Apply this stage's layers to its in-flight batched tree layer
        ([B, w, d] activations; per-row metadata rides the ring)."""
        ctx = tf.Ctx(mode="tree", positions=positions,
                     cache_len=jnp.asarray(model_len, jnp.int32),
                     tree_write_index=jnp.asarray(write_idx, jnp.int32),
                     tree_mask=mask)
        xs = x  # [B, w, d]
        new_tkv = []
        for l in range(lps):
            # per-layer param/cache buffers (lists over the in-stage dim)
            unit_p = stage_p[l]
            c = [kv[l]]
            tc = [tkv[l]]
            y, _, ntc, _ = tf._apply_unit(unit_p, cfg, kinds, xs, c, tc, ctx)
            ok = valid_row[l] & in_valid                 # [B]
            xs = jnp.where(ok[:, None, None], y, xs)
            new_tkv.append(jax.tree.map(
                lambda old, new, k=ok: jnp.where(
                    k.reshape((-1,) + (1,) * (old.ndim - 1)), new, old),
                tc[0], ntc[0]))
        return xs, new_tkv

    def tick(stage_p, stage_valid, model_kv, tree_kv, ring, entry):
        def body(stage_p, stage_valid, model_kv, tree_kv, ring, entry):
            # local slices carry a leading stage dim of 1 (dropped here)
            sp = [jax.tree.map(lambda t: t[0], lp) for lp in stage_p]
            sv = stage_valid[0]
            kv = [jax.tree.map(lambda t: t[0], lc) for lc in model_kv]
            tkv = [jax.tree.map(lambda t: t[0], lc) for lc in tree_kv]

            x, new_tkv = local_stage(
                sp, sv, kv, tkv, ring["act"][0], ring["positions"][0],
                ring["mask"][0], ring["write_idx"][0], ring["model_len"][0],
                ring["valid"][0])

            # rotate the ring one stage forward (paper's transmission step)
            perm = [(i, i + 1) for i in range(n_stages - 1)]
            shift = lambda v: jax.lax.ppermute(v, s_axis, perm)
            rotated = {
                "act": shift(x[None]),
                "positions": shift(ring["positions"]),
                "mask": shift(ring["mask"]),
                "write_idx": shift(ring["write_idx"]),
                "model_len": shift(ring["model_len"]),
                "valid": shift(ring["valid"]),
            }
            # stage 0 ingests the new layer from the draft model
            idx = jax.lax.axis_index(s_axis)
            is0 = (idx == 0)
            new_ring = {
                "act": jnp.where(is0, entry["act"][None], rotated["act"]),
                "positions": jnp.where(is0, entry["positions"][None],
                                       rotated["positions"]),
                "mask": jnp.where(is0, entry["mask"][None],
                                  rotated["mask"]),
                "write_idx": jnp.where(is0, entry["write_idx"][None],
                                       rotated["write_idx"]),
                "model_len": jnp.where(is0, entry["model_len"][None],
                                       rotated["model_len"]),
                "valid": jnp.where(is0, entry["valid"][None],
                                   rotated["valid"]),
            }
            # the activation leaving the last stage = exiting layer
            is_last = (idx == n_stages - 1).astype(x.dtype)
            exit_act = jax.lax.psum(x * is_last, s_axis)
            exit_valid = jax.lax.psum(
                (ring["valid"][0] & (idx == n_stages - 1))
                .astype(jnp.int32), s_axis) > 0
            new_tkv = [jax.tree.map(lambda t: t[None], lc) for lc in new_tkv]
            return (new_tkv, new_ring,
                    {"act": exit_act, "valid": exit_valid})

        tkv_spec = jax.tree.map(lambda _: P(s_axis), tree_kv)
        ring_spec = jax.tree.map(lambda _: P(s_axis), ring)
        entry_spec = jax.tree.map(lambda _: P(), entry)
        out = shard_map(
            body, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(s_axis), stage_p),
                      P(s_axis),
                      jax.tree.map(lambda _: P(s_axis), model_kv),
                      tkv_spec, ring_spec, entry_spec),
            out_specs=(tkv_spec, ring_spec,
                       {"act": P(), "valid": P()}),
            check_vma=False,
        )(stage_p, stage_valid, model_kv, tree_kv, ring, entry)
        return out

    return tick


def make_pipeline_verify(cfg: ModelConfig, pcfg: PipelineConfig, mesh,
                         dtype=jnp.float32):
    """One-dispatch batched tree-verify through the sharded pipeline.

    Ingests a batched entry layer into stage 0 of a fresh ring, then runs
    ``n_stages`` ticks so the layer traverses every stage and exits —
    yielding the same verification hidden states the single-device
    ``tree_verify_step`` computes, but partitioned stage-by-stage over the
    mesh with the metadata riding the ``ppermute`` ring.  The whole flush
    is ONE compiled computation, so the serving executor issues exactly
    one sharded dispatch per global timestep.

    (The steady-state deployment overlaps consecutive layers — one tick
    per timestep with the ring full; its wall-clock is priced in
    ``core.sim.specpipe_db_sharded_*``.  The flush keeps verify logits
    available at the layer's *entry* timestep, which is what keeps the
    logical engine's schedule — and therefore its outputs — bit-identical
    to the local backends.)

    Returns ``verify(stage_p, stage_valid, model_kv, tree_kv, entry) ->
    (exit_act [B, w, d], exit_valid [B], new_tree_kv)``.
    """
    tick = make_pipedec_tick(cfg, pcfg, mesh)

    def verify(stage_p, stage_valid, model_kv, tree_kv, entry):
        batch = entry["act"].shape[0]
        ring = init_ring(cfg, pcfg, dtype=dtype, batch=batch)
        dead = dict(entry, valid=jnp.zeros_like(entry["valid"]))
        ent = entry
        exit_out = None
        for _ in range(pcfg.n_stages + 1):
            tree_kv, ring, exit_out = tick(stage_p, stage_valid, model_kv,
                                           tree_kv, ring, ent)
            ent = dead
        return exit_out["act"], exit_out["valid"], tree_kv

    return verify
