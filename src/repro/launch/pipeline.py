"""Paper-faithful pipeline-parallel deployment (shard_map over "model").

The paper's cluster runs MPMD stages coordinated by Redis; on TPU the same
schedule is SPMD: every device executes one *tick* per timestep.

One PipeDec tick (= paper timestep, Fig. 2):
  * each stage applies its layer block to the tree layer it currently
    holds, reading/writing its local slice of the two-level KV cache;
  * activations rotate one stage forward via ``jax.lax.ppermute`` —
    this collective IS the paper's transmission scheduler (Appendix A),
    compiled instead of orchestrated;
  * stage 0 ingests the newest tree layer (from the draft model);
    the activation leaving the last stage is gathered and unembedded into
    the verification logits of the layer that completed the pipeline.

Each in-flight layer carries its metadata (absolute positions, ancestor
mask rows, tree-buffer write index, committed length) in the same ring so
every stage uses the values frozen at that layer's entry — exactly the
paper's data-flow semantics.

Supports attention-family architectures (dense / VLM / MoE-with-attention);
recurrent families use chain-mode speculative decoding instead (DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Any, Tuple

import jax
import jax.numpy as jnp
try:                                   # jax >= 0.6 exports it at top level
    from jax import shard_map as _shard_map
except ImportError:                    # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

_SHARD_MAP_KW = inspect.signature(_shard_map).parameters


def shard_map(f, **kwargs):
    # jax renamed check_rep -> check_vma; translate for older versions
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_KW:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map(f, **kwargs)

from repro.models import attention as attn_mod
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.models.layers import embed, rmsnorm, unembed


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_stages: int
    width: int            # w (tree layer width)
    tree_capacity: int    # tree KV buffer rows
    max_len: int          # model KV buffer rows


def stage_layout(cfg: ModelConfig, n_stages: int) -> Tuple[int, int]:
    """(layers_per_stage, padded_total). Only the uniform 'stack' region is
    pipelined; prefix/tail layers (rare) fold into stage 0 / S-1 ... we
    require a pure-stack arch for the pipeline deployment."""
    n_prefix, reps, tail = tf.layout(cfg)
    assert n_prefix == 0 and not tail, \
        "pipeline deployment expects a uniform layer stack"
    lps = -(-reps // n_stages)
    return lps, lps * n_stages


def stage_params(cfg: ModelConfig, params, n_stages: int):
    """Stage layout: a LIST of ``lps`` per-layer trees, each leaf [S, ...]
    (stage dim stacked/sharded over 'model'; the within-stage layer dim is
    unrolled into separate buffers so XLA cannot hoist whole-stack
    converts/copies ahead of the layer loop — §Perf H3) + validity [S, Lps].
    """
    lps, padded = stage_layout(cfg, n_stages)
    reps = tf.layout(cfg)[1]

    def reshape(x):
        pad = padded - reps
        if pad:
            x = jnp.concatenate(
                [x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], 0)
        return x.reshape(n_stages, lps, *x.shape[1:])

    stacked = jax.tree.map(reshape, params["stack"])
    layers = [jax.tree.map(lambda t: t[:, l], stacked) for l in range(lps)]
    valid = (jnp.arange(padded) < reps).reshape(n_stages, lps)
    return layers, valid


def init_stage_caches(cfg: ModelConfig, pcfg: PipelineConfig,
                      dtype=jnp.float32):
    """Per-stage model + tree caches: lists (per in-stage layer) of
    [S, B=1, rows, ...] buffers."""
    lps, _ = stage_layout(cfg, pcfg.n_stages)
    kv = attn_mod.init_kv_cache(cfg, 1, pcfg.max_len, dtype)
    tkv = attn_mod.init_kv_cache(cfg, 1, pcfg.tree_capacity + pcfg.width,
                                 dtype)
    tile = lambda c: [jax.tree.map(
        lambda x: jnp.zeros((pcfg.n_stages, *x.shape), x.dtype), c)
        for _ in range(lps)]
    return tile(kv), tile(tkv)


def init_ring(cfg: ModelConfig, pcfg: PipelineConfig, dtype=jnp.float32):
    """In-flight activation + metadata ring, one slot per stage."""
    s, w = pcfg.n_stages, pcfg.width
    return {
        "act": jnp.zeros((s, w, cfg.d_model), dtype),
        "positions": jnp.zeros((s, w), jnp.int32),
        "mask": jnp.zeros((s, w, pcfg.tree_capacity + pcfg.width), bool),
        "write_idx": jnp.zeros((s,), jnp.int32),
        "model_len": jnp.zeros((s,), jnp.int32),
        "valid": jnp.zeros((s,), bool),
    }


def make_pipedec_tick(cfg: ModelConfig, pcfg: PipelineConfig, mesh):
    """Build the jittable one-timestep pipeline tick.

    Inputs (global shapes):
      stage_p:    unit params [S, Lps, ...]        (stage-sharded)
      stage_valid:[S, Lps] bool
      caches:     (model_kv, tree_kv) [S, Lps, 1, rows, ...]
      ring:       see init_ring
      entry:      dict with the NEW layer for stage 0:
                  tokens->embedded x [w, d], positions [w],
                  mask [w, tcap+w], write_idx (), model_len (), valid ()
    Returns (new caches, new ring, exit: {act [w,d], ...exit metadata}).
    """
    s_axis = "model"
    n_stages = pcfg.n_stages
    kinds = tf.unit_kinds(cfg)
    assert kinds == ("attn",), "pipeline tick supports attention stacks"
    lps, _ = stage_layout(cfg, n_stages)

    def local_stage(stage_p, valid_row, kv, tkv, x, positions, mask,
                    write_idx, model_len, in_valid):
        """Apply this stage's layers to its in-flight tree layer."""
        ctx = tf.Ctx(mode="tree", positions=positions[None],
                     cache_len=jnp.asarray(model_len, jnp.int32).reshape(1),
                     tree_write_index=jnp.asarray(write_idx,
                                                  jnp.int32).reshape(1),
                     tree_mask=mask[None])
        xs = x[None]  # [1, w, d]
        new_tkv = []
        for l in range(lps):
            # per-layer param/cache buffers (lists over the in-stage dim)
            unit_p = stage_p[l]
            c = [kv[l]]
            tc = [tkv[l]]
            y, _, ntc, _ = tf._apply_unit(unit_p, cfg, kinds, xs, c, tc, ctx)
            ok = valid_row[l] & in_valid
            xs = jnp.where(ok, y, xs)
            new_tkv.append(jax.tree.map(
                lambda old, new: jnp.where(ok, new, old), tc[0], ntc[0]))
        return xs[0], new_tkv

    def tick(stage_p, stage_valid, model_kv, tree_kv, ring, entry):
        def body(stage_p, stage_valid, model_kv, tree_kv, ring, entry):
            # local slices carry a leading stage dim of 1 (dropped here)
            sp = [jax.tree.map(lambda t: t[0], lp) for lp in stage_p]
            sv = stage_valid[0]
            kv = [jax.tree.map(lambda t: t[0], lc) for lc in model_kv]
            tkv = [jax.tree.map(lambda t: t[0], lc) for lc in tree_kv]

            x, new_tkv = local_stage(
                sp, sv, kv, tkv, ring["act"][0], ring["positions"][0],
                ring["mask"][0], ring["write_idx"][0], ring["model_len"][0],
                ring["valid"][0])

            # rotate the ring one stage forward (paper's transmission step)
            perm = [(i, i + 1) for i in range(n_stages - 1)]
            shift = lambda v: jax.lax.ppermute(v, s_axis, perm)
            rotated = {
                "act": shift(x[None]),
                "positions": shift(ring["positions"]),
                "mask": shift(ring["mask"]),
                "write_idx": shift(ring["write_idx"]),
                "model_len": shift(ring["model_len"]),
                "valid": shift(ring["valid"]),
            }
            # stage 0 ingests the new layer from the draft model
            idx = jax.lax.axis_index(s_axis)
            is0 = (idx == 0)
            new_ring = {
                "act": jnp.where(is0, entry["act"][None], rotated["act"]),
                "positions": jnp.where(is0, entry["positions"][None],
                                       rotated["positions"]),
                "mask": jnp.where(is0, entry["mask"][None],
                                  rotated["mask"]),
                "write_idx": jnp.where(is0, entry["write_idx"],
                                       rotated["write_idx"]),
                "model_len": jnp.where(is0, entry["model_len"],
                                       rotated["model_len"]),
                "valid": jnp.where(is0, entry["valid"], rotated["valid"]),
            }
            # the activation leaving the last stage = exiting layer
            is_last = (idx == n_stages - 1).astype(x.dtype)
            exit_act = jax.lax.psum(x * is_last, s_axis)
            exit_valid = jax.lax.psum(
                (ring["valid"][0] & (idx == n_stages - 1))
                .astype(jnp.int32), s_axis) > 0
            new_tkv = [jax.tree.map(lambda t: t[None], lc) for lc in new_tkv]
            return (new_tkv, new_ring,
                    {"act": exit_act, "valid": exit_valid})

        specs_stage = P(s_axis)
        tkv_spec = jax.tree.map(lambda _: P(s_axis), tree_kv)
        ring_spec = jax.tree.map(lambda _: P(s_axis), ring)
        entry_spec = jax.tree.map(lambda _: P(), entry)
        out = shard_map(
            body, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(s_axis), stage_p),
                      P(s_axis),
                      jax.tree.map(lambda _: P(s_axis), model_kv),
                      tkv_spec, ring_spec, entry_spec),
            out_specs=(tkv_spec, ring_spec,
                       {"act": P(), "valid": P()}),
            check_vma=False,
        )(stage_p, stage_valid, model_kv, tree_kv, ring, entry)
        return out

    return tick
