"""Step functions lowered by the dry-run and used by train.py / serve.py."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_update


def _enc_out(params, cfg: ModelConfig, batch):
    if cfg.is_encdec and "frames" in batch:
        from repro.models.encdec import encode
        return encode(params["encoder"], cfg, batch["frames"])
    return None


def make_train_step(cfg: ModelConfig, opt_cfg: Optional[AdamWConfig] = None,
                    *, window_override: int = -1, remat: bool = True):
    """Build the jittable ``(params, opt_state, batch) -> (params,
    opt_state, metrics)`` AdamW train step (optionally remat'd).
    """
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(params, opt_state, batch):
        def loss(p):
            return tf.loss_fn(
                p, cfg, batch["tokens"], batch["labels"],
                prefix_embeds=batch.get("prefix_embeds"),
                enc_out=_enc_out(p, cfg, batch), remat=remat,
                window_override=window_override)

        loss_val, grads = jax.value_and_grad(loss)(params)
        new_params, new_opt, metrics = adamw_update(opt_cfg, params, grads,
                                                    opt_state)
        return new_params, new_opt, {"loss": loss_val, **metrics}

    return train_step


def make_prefill_step(cfg: ModelConfig, *, window_override: int = -1,
                      cache_dtype=jnp.bfloat16, max_len: int = 0):
    """Prefill builds and returns its own cache (zeros are elided by XLA
    where overwritten) — callers never allocate an input cache."""

    def prefill_step(params, tokens, prefix_embeds=None, frames=None):
        b, s = tokens.shape
        length = (max_len or s) + cfg.prefix_tokens
        cache = tf.init_cache(cfg, b, length, dtype=cache_dtype)
        enc_out = _enc_out(params, cfg,
                           {"frames": frames} if frames is not None else {})
        return tf.prefill(params, cfg, tokens, cache,
                          prefix_embeds=prefix_embeds, enc_out=enc_out,
                          window_override=window_override)

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, window_override: int = -1):
    """One decode step: ONE new token per sequence against the KV cache."""

    def serve_step(params, token, cache, cache_len, enc_out=None):
        return tf.decode_step(params, cfg, token, cache, cache_len,
                              enc_out=enc_out,
                              window_override=window_override)

    return serve_step
