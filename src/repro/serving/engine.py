"""Serving engine: request queue + three execution modes.

  * ``mode="pp"``         — throughput-oriented batched autoregressive
                            decode (requests bucketed by prompt length,
                            decoded in lockstep batches; the paper's PP
                            baseline).  Bucketing keeps row cache offsets
                            identical so lockstep decode needs no per-row
                            positions; each bucket is split into
                            ``max_batch`` chunks that run to the longest
                            ``max_new_tokens`` in the chunk.
  * ``mode="pipedec"``    — latency-oriented: the whole pipeline works on
                            ONE task at a time with the dynamic prediction
                            tree (the paper's single-request system; Fig. 8
                            shows the throughput trade-off this makes).
  * ``mode="pipedec-db"`` — SpecPipe-DB dynamic batching
                            (``serving.dynbatch.SpecPipeDBEngine``): up to
                            ``max_batch`` requests' trees share every
                            pipeline timestep; finished requests are
                            replaced from the queue (join-on-prefill /
                            retire-on-eos) without draining the pipeline.
                            Greedy output is bit-equal to ``pipedec`` per
                            request; throughput scales with occupancy
                            (``core.sim.specpipe_db_throughput``).

KV management: ``pp`` allocates one fixed-size cache arena per lockstep
batch; ``pipedec-db`` draws per-request arenas from the recycled slot pool
in ``serving.scheduler.KVArena``.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import generate_autoregressive
from repro.core.pipedec import PipeDecConfig, PipeDecEngine
from repro.core.speculative import ModelBundle, SamplingParams, select_token


@dataclasses.dataclass
class Request:
    """One generation request: prompt + budget, plus the DB-mode
    admission knobs (arrival time, priority, deadline, sampling)."""

    uid: int
    prompt: np.ndarray
    max_new_tokens: int = 32
    arrival_t: int = 0        # arrival time in pipeline timesteps (DB mode)
    priority: int = 0         # admission priority (higher = sooner; ties
                              # and all-default traffic are exact FIFO)
    deadline_t: Optional[int] = None   # optional deadline (timesteps);
                              # boosts admission as it approaches
    sampling: Optional[SamplingParams] = None  # per-request override of
                              # the engine's temperature/top-k/top-p


@dataclasses.dataclass
class Result:
    """Per-request outcome: generated tokens, wall-clock latency and the
    engine's per-request stats object (mode-dependent)."""

    uid: int
    tokens: np.ndarray
    latency_s: float
    stats: Optional[object] = None


class ServingEngine:
    """Front door for batch serving: queue ``Request``s, pick a mode
    (``pp`` autoregressive, ``pipedec`` single-request SpecPipe,
    ``pipedec-db`` continuous batching) and ``run()`` them against the
    selected ``PipelineExecutor`` backend."""

    def __init__(self, target: ModelBundle, draft: Optional[ModelBundle]
                 = None, *, mode: str = "pp", max_batch: int = 8,
                 max_len: int = 512,
                 pipedec: Optional[PipeDecConfig] = None,
                 sampling: SamplingParams = SamplingParams(),
                 eos_token: Optional[int] = None, executor=None):
        """``executor`` (mode="pipedec-db" only) selects the SpecPipe-DB
        compute backend — a ``serving.executor.PipelineExecutor``; None
        uses the local fused path, ``ShardedPipelineExecutor`` the
        pipelined multi-device deployment."""
        assert mode in ("pp", "pipedec", "pipedec-db")
        if mode in ("pipedec", "pipedec-db"):
            assert draft is not None, f"{mode} mode needs a draft model"
        assert executor is None or mode == "pipedec-db", \
            "executor backends apply to mode='pipedec-db'"
        self.target, self.draft, self.mode = target, draft, mode
        self.max_batch, self.max_len = max_batch, max_len
        self.pipedec_cfg = pipedec or PipeDecConfig()
        self.sampling = sampling
        self.eos_token = eos_token
        self.executor = executor
        self.db_stats = None      # DBStats after a mode="pipedec-db" run
        self.queue: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _run_pp_batch(self, batch: List[Request]) -> List[Result]:
        t0 = time.perf_counter()
        tgt = self.target
        prompts = np.stack([r.prompt for r in batch])
        b, s = prompts.shape
        new = max(r.max_new_tokens for r in batch)
        cache = tgt.init_cache(b, self.max_len)
        logits, cache = tgt.prefill(jnp.asarray(prompts, jnp.int32), cache)
        toks = np.asarray(jnp.argmax(logits, -1))
        outs = [[int(t)] for t in toks]
        model_len = s
        key = jax.random.PRNGKey(0)
        for _ in range(new):
            logits, cache = tgt.decode(jnp.asarray(toks, jnp.int32), cache,
                                       model_len)
            model_len += 1
            if self.sampling.temperature > 0:
                keys = jax.random.split(key, b + 1)
                key = keys[0]
                toks = np.asarray([
                    int(select_token(logits[i], self.sampling, keys[i + 1]))
                    for i in range(b)])
            else:
                toks = np.asarray(jnp.argmax(logits, -1))
            for i, t in enumerate(toks):
                outs[i].append(int(t))
        dt = time.perf_counter() - t0

        def cut(o, limit):
            o = o[:limit]
            if self.eos_token is not None and self.eos_token in o:
                o = o[: o.index(self.eos_token) + 1]
            return np.asarray(o)

        return [Result(r.uid, cut(o, r.max_new_tokens + 1), dt)
                for r, o in zip(batch, outs)]

    def _run_pipedec_one(self, req: Request) -> Result:
        t0 = time.perf_counter()
        eng = PipeDecEngine(self.target, self.draft, self.pipedec_cfg,
                            max_len=self.max_len)
        out, stats = eng.generate(req.prompt, req.max_new_tokens,
                                  eos=self.eos_token,
                                  sampling=req.sampling)
        return Result(req.uid, out, time.perf_counter() - t0, stats)

    # ------------------------------------------------------------------
    def run(self, on_token=None) -> Dict[int, Result]:
        """``on_token(uid, token, timestep)`` streams committed tokens in
        mode="pipedec-db" (ignored by the batch modes)."""
        results: Dict[int, Result] = {}
        if self.mode == "pipedec":
            for req in self.queue:
                results[req.uid] = self._run_pipedec_one(req)
            self.queue.clear()
            return results
        if self.mode == "pipedec-db":
            from repro.serving.dynbatch import SpecPipeDBEngine
            eng = SpecPipeDBEngine(self.target, self.draft, self.pipedec_cfg,
                                   max_len=self.max_len,
                                   max_slots=self.max_batch,
                                   eos_token=self.eos_token,
                                   executor=self.executor)
            for req in self.queue:
                eng.submit(req)
            self.queue.clear()
            results = eng.run(on_token=on_token)
            self.db_stats = eng.stats
            return results
        # pp: bucket by prompt length, then batch
        buckets = collections.defaultdict(list)
        for r in self.queue:
            buckets[len(r.prompt)].append(r)
        self.queue.clear()
        for _, reqs in sorted(buckets.items()):
            for i in range(0, len(reqs), self.max_batch):
                for res in self._run_pp_batch(reqs[i: i + self.max_batch]):
                    results[res.uid] = res
        return results
