"""Serving engine: request queue + two execution modes.

  * ``mode="pp"``      — throughput-oriented batched autoregressive decode
                         (requests bucketed by prompt length, decoded in
                         lockstep batches; the paper's PP baseline).
  * ``mode="pipedec"`` — latency-oriented: the whole pipeline works on ONE
                         task at a time with the dynamic prediction tree
                         (the paper's system; Fig. 8 shows the throughput
                         trade-off this makes).

The KV-cache manager hands out fixed-size cache arenas per batch; prompt
bucketing keeps row cache offsets identical so lockstep decode needs no
per-row positions.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import generate_autoregressive
from repro.core.pipedec import PipeDecConfig, PipeDecEngine
from repro.core.speculative import ModelBundle, SamplingParams, select_token


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new_tokens: int = 32


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray
    latency_s: float
    stats: Optional[object] = None


class ServingEngine:
    def __init__(self, target: ModelBundle, draft: Optional[ModelBundle]
                 = None, *, mode: str = "pp", max_batch: int = 8,
                 max_len: int = 512,
                 pipedec: Optional[PipeDecConfig] = None,
                 sampling: SamplingParams = SamplingParams()):
        assert mode in ("pp", "pipedec")
        if mode == "pipedec":
            assert draft is not None, "pipedec mode needs a draft model"
        self.target, self.draft, self.mode = target, draft, mode
        self.max_batch, self.max_len = max_batch, max_len
        self.pipedec_cfg = pipedec or PipeDecConfig()
        self.sampling = sampling
        self.queue: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _run_pp_batch(self, batch: List[Request]) -> List[Result]:
        t0 = time.perf_counter()
        tgt = self.target
        prompts = np.stack([r.prompt for r in batch])
        b, s = prompts.shape
        new = max(r.max_new_tokens for r in batch)
        cache = tgt.init_cache(b, self.max_len)
        logits, cache = tgt.prefill(jnp.asarray(prompts, jnp.int32), cache)
        toks = np.asarray(jnp.argmax(logits, -1))
        outs = [[int(t)] for t in toks]
        model_len = s
        key = jax.random.PRNGKey(0)
        for _ in range(new):
            logits, cache = tgt.decode(jnp.asarray(toks, jnp.int32), cache,
                                       model_len)
            model_len += 1
            if self.sampling.temperature > 0:
                keys = jax.random.split(key, b + 1)
                key = keys[0]
                toks = np.asarray([
                    int(select_token(logits[i], self.sampling, keys[i + 1]))
                    for i in range(b)])
            else:
                toks = np.asarray(jnp.argmax(logits, -1))
            for i, t in enumerate(toks):
                outs[i].append(int(t))
        dt = time.perf_counter() - t0
        return [Result(r.uid, np.asarray(o[: r.max_new_tokens + 1]), dt)
                for r, o in zip(batch, outs)]

    def _run_pipedec_one(self, req: Request) -> Result:
        t0 = time.perf_counter()
        eng = PipeDecEngine(self.target, self.draft, self.pipedec_cfg,
                            max_len=self.max_len)
        out, stats = eng.generate(req.prompt, req.max_new_tokens)
        return Result(req.uid, out, time.perf_counter() - t0, stats)

    # ------------------------------------------------------------------
    def run(self) -> Dict[int, Result]:
        results: Dict[int, Result] = {}
        if self.mode == "pipedec":
            for req in self.queue:
                results[req.uid] = self._run_pipedec_one(req)
            self.queue.clear()
            return results
        # pp: bucket by prompt length, then batch
        buckets = collections.defaultdict(list)
        for r in self.queue:
            buckets[len(r.prompt)].append(r)
        self.queue.clear()
        for _, reqs in sorted(buckets.items()):
            for i in range(0, len(reqs), self.max_batch):
                for res in self._run_pp_batch(reqs[i: i + self.max_batch]):
                    results[res.uid] = res
        return results
