"""KV arena + admission/eviction scheduler for SpecPipe-DB.

The paper's dynamic batching keeps the pipeline full of *different*
requests: whenever one finishes, the next queued request joins at its
prefill and decodes alongside the rest.  Two pieces implement that here:

  * ``KVArena`` — a fixed pool of per-slot cache arenas (target + draft
    model caches and the two tree caches).  Slots are recycled across
    requests without zeroing: every attention mask is bounded by the new
    occupant's ``model_len`` / ancestor mask, so a previous occupant's
    stale rows are never attended and outputs are unchanged (the
    equivalence tests pin this).
  * ``DynamicBatchScheduler`` — FIFO arrival queue with per-request
    ``arrival_t`` (in pipeline timesteps), admission onto free slots each
    timestep (join-on-prefill), and retire-on-completion (eos or token
    budget) which frees the slot for the next refill.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple


class KVArena:
    """Fixed pool of per-slot KV cache arenas, allocated lazily and
    recycled across requests."""

    def __init__(self, target, draft, *, slots: int, max_len: int,
                 tree_capacity: int):
        assert slots >= 1
        self.target, self.draft = target, draft
        self.slots, self.max_len, self.tree_capacity = \
            slots, max_len, tree_capacity
        self._free: List[int] = list(range(slots - 1, -1, -1))  # pop -> 0..
        self._in_use: set = set()
        self._arenas: List[Optional[tuple]] = [None] * slots

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return len(self._in_use)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("KVArena exhausted: no free slot")
        slot = self._free.pop()
        if slot in self._in_use:
            raise RuntimeError(f"KV slot {slot} double-allocated")
        self._in_use.add(slot)
        if self._arenas[slot] is None:
            self._arenas[slot] = (
                self.target.init_cache(1, self.max_len),
                self.draft.init_cache(1, self.max_len),
                self.target.init_tree_caches(1, self.tree_capacity),
                self.draft.init_tree_caches(1, self.tree_capacity))
        return slot

    def caches(self, slot: int) -> tuple:
        assert slot in self._in_use, f"slot {slot} not allocated"
        return self._arenas[slot]

    def store(self, slot: int, caches: tuple) -> None:
        """Hand a request's final cache buffers back to the pool so the
        next occupant reuses them (stale rows are masked, never zeroed)."""
        assert slot in self._in_use, f"slot {slot} not allocated"
        self._arenas[slot] = caches

    def free(self, slot: int) -> None:
        if slot not in self._in_use:
            raise RuntimeError(f"KV slot {slot} freed but not in use")
        self._in_use.remove(slot)
        self._free.append(slot)


@dataclasses.dataclass
class SchedulerStats:
    """Per-uid lifecycle timestamps (in global pipeline timesteps) plus an
    occupancy trace — the no-starvation / no-double-allocation invariants
    in tests/test_serving_db.py are asserted against these."""
    submitted_t: Dict[int, int] = dataclasses.field(default_factory=dict)
    admitted_t: Dict[int, int] = dataclasses.field(default_factory=dict)
    finished_t: Dict[int, int] = dataclasses.field(default_factory=dict)
    occupancy: List[int] = dataclasses.field(default_factory=list)

    def queue_delay(self, uid: int) -> int:
        return self.admitted_t[uid] - self.submitted_t[uid]


class DynamicBatchScheduler:
    """FIFO admission of arrived requests onto free KV slots."""

    def __init__(self, arena: KVArena):
        self.arena = arena
        self.queue: Deque = collections.deque()
        self.stats = SchedulerStats()

    def submit(self, req) -> None:
        self.queue.append(req)
        self.stats.submitted_t[req.uid] = getattr(req, "arrival_t", 0)

    @property
    def pending(self) -> int:
        return len(self.queue)

    def next_arrival(self) -> Optional[int]:
        """Earliest arrival_t among queued requests (None if queue empty)."""
        if not self.queue:
            return None
        return min(getattr(r, "arrival_t", 0) for r in self.queue)

    def admit(self, now: int) -> List[Tuple[object, int]]:
        """Admit arrived requests (FIFO) while slots are free.  Returns
        [(request, slot)] for this timestep's joins."""
        admitted: List[Tuple[object, int]] = []
        while self.arena.n_free:
            req = next((r for r in self.queue
                        if getattr(r, "arrival_t", 0) <= now), None)
            if req is None:
                break
            self.queue.remove(req)
            slot = self.arena.alloc()
            self.stats.admitted_t[req.uid] = now
            admitted.append((req, slot))
        return admitted

    def retire(self, uid: int, slot: int, now: int, caches=None) -> None:
        """Release a finished request's slot (optionally recycling its
        cache buffers) so the next refill can claim it."""
        if caches is not None:
            self.arena.store(slot, caches)
        self.arena.free(slot)
        self.stats.finished_t[uid] = now
