"""KV arena + admission/eviction scheduler for SpecPipe-DB.

The paper's dynamic batching keeps the pipeline full of *different*
requests: whenever one finishes, the next queued request joins at its
prefill and decodes alongside the rest.  Three pieces implement that here:

  * ``SlotPool`` — bare slot accounting (free list + in-use set).  The
    compute backend owns the actual cache storage (see
    ``serving.executor``): the local backend's ``KVArena`` extends the
    pool with slot-stacked cache pytrees, while the sharded backend keeps
    stage-layout arenas of its own and uses the pool unadorned.
  * ``KVArena`` — slot-stacked cache arenas (target + draft model caches
    and the two tree caches, each ONE pytree with a leading slot axis) so
    the fused per-timestep tree-verify dispatch reads every in-flight
    request from one buffer; per-slot row views serve admission prefill
    and retire.  Slots are recycled across requests without zeroing:
    every attention mask is bounded by the new occupant's ``model_len`` /
    ancestor mask, and recurrent (ssm/rglru) state is re-seeded from zero
    at prefill, so a previous occupant's stale rows and state never leak
    (the equivalence tests pin this).
  * ``DynamicBatchScheduler`` — priority/deadline-aware arrival queue
    with per-request ``arrival_t`` (in pipeline timesteps), admission
    onto free slots each timestep (join-on-prefill), and
    retire-on-completion (eos or token budget) which frees the slot for
    the next refill.

Admission policy (priority + aging): each ``admit(now)`` considers every
*arrived* request and admits the one with the highest effective priority

    eff(req, now) = req.priority
                    + (now - req.arrival_t) // aging        (anti-starvation)
                    + 1 if req.deadline_t is within ``aging`` timesteps

with ties broken by submission order.  All-default-priority traffic
submitted in arrival order — the engine's case, and everything the PR-1/
PR-2 equivalence tests exercise — degenerates to exact FIFO.  The aging
term applies uniformly, so among equal priorities a request that has
already waited ``aging`` timesteps longer than a peer is preferred
(FIFO-by-wait rather than FIFO-by-submission when submissions arrive out
of arrival order).  Aging bounds starvation: a request waiting
``aging * Δpriority`` timesteps outranks any fresher request ``Δpriority``
levels above it, so queue delay is bounded for any bounded priority range
(tests/test_scheduler_priority.py asserts the reorder, the bound, and the
equal-priority aging preference).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import paging
from repro.models import transformer as tf

# Row write-back donates the full arena buffer so XLA can update the slot
# rows in place (on backends without donation this degrades to a copy —
# same result, just not O(1)).  ``start`` is static: one compile per slot.
_store_rows = jax.jit(tf.update_cache_rows, static_argnames=("start",),
                      donate_argnums=(0,))
# paged variant: leaves share one table array, which cannot be donated twice
_store_rows_nodonate = jax.jit(tf.update_cache_rows,
                               static_argnames=("start",))


class SlotPool:
    """Free-list accounting for ``slots`` recyclable KV slots.

    Storage-agnostic on purpose: the scheduler admits/retires against this
    interface, and each ``PipelineExecutor`` backend attaches whatever
    cache layout it needs (slot-stacked pytrees locally, stage-sharded
    arenas on the pipeline deployment)."""

    def __init__(self, slots: int):
        assert slots >= 1
        self.slots = slots
        self._free: List[int] = list(range(slots - 1, -1, -1))  # pop -> 0..
        self._in_use: set = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return len(self._in_use)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("KVArena exhausted: no free slot")
        slot = self._free.pop()
        if slot in self._in_use:
            raise RuntimeError(f"KV slot {slot} double-allocated")
        self._in_use.add(slot)
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._in_use:
            raise RuntimeError(f"KV slot {slot} freed but not in use")
        self._in_use.remove(slot)
        self._free.append(slot)


class KVArena(SlotPool):
    """Slot-stacked KV cache arenas, allocated lazily and recycled across
    requests.

    All four cache pytrees carry a leading *slot* axis (buffers of the
    repeated-unit "stack" layout carry it right after their reps dim) —
    the layout the fused SpecPipe-DB dispatch and the batched per-row
    commit read/write in place.  ``caches(slot)`` / ``store(slot, ...)``
    expose per-slot row views for admission prefill and retire.
    """

    def __init__(self, target, draft, *, slots: int, max_len: int,
                 tree_capacity: int):
        super().__init__(slots)
        self.target, self.draft = target, draft
        self.max_len, self.tree_capacity = max_len, tree_capacity
        self._stacked: Optional[list] = None

    def bytes_per_slot(self) -> int:
        """KV bytes one slot pins across all four arenas (model + tree,
        target + draft), computed from abstract shapes — no allocation.
        This is the admission currency of the int8 serving path: the
        quantized layout (int8 rows + one fp32 scale per kv-head row)
        roughly quarters this, so the same byte budget admits ~4x the
        slots (the CI gate requires >=1.9x)."""
        total = 0
        for fn, cap in ((self.target.init_cache, self.max_len),
                        (self.draft.init_cache, self.max_len),
                        (self.target.init_tree_caches, self.tree_capacity),
                        (self.draft.init_tree_caches, self.tree_capacity)):
            shapes = jax.eval_shape(lambda f=fn, c=cap: f(1, c))
            total += sum(leaf.size * leaf.dtype.itemsize
                         for leaf in jax.tree_util.tree_leaves(shapes))
        return total

    def _ensure(self) -> None:
        if self._stacked is None:
            self._stacked = [
                self.target.init_cache(self.slots, self.max_len),
                self.draft.init_cache(self.slots, self.max_len),
                self.target.init_tree_caches(self.slots, self.tree_capacity),
                self.draft.init_tree_caches(self.slots, self.tree_capacity)]

    def alloc(self) -> int:
        slot = super().alloc()
        self._ensure()
        return slot

    def caches(self, slot: int) -> tuple:
        """Per-slot row views (t_cache, d_cache, t_tree, d_tree), each a
        batch-1 cache pytree sliced out of the stacked arena."""
        assert slot in self._in_use, f"slot {slot} not allocated"
        return tuple(tf.slice_cache_rows(c, slot, 1) for c in self._stacked)

    def store(self, slot: int, caches: tuple) -> None:
        """Write a request's (t_cache, d_cache, t_tree, d_tree) row views
        back into the stacked arena so the next occupant reuses the slot
        (stale rows are masked, never zeroed)."""
        assert slot in self._in_use, f"slot {slot} not allocated"
        self._stacked = [_store_rows(full, row, start=slot)
                         for full, row in zip(self._stacked, caches)]

    # -- fused-path access (whole-arena pytrees) ------------------------
    @property
    def stacked(self) -> tuple:
        """(t_cache, d_cache, t_tree, d_tree), slot axis leading."""
        self._ensure()
        return tuple(self._stacked)

    def set_model_caches(self, t_cache, d_cache) -> None:
        self._stacked[0], self._stacked[1] = t_cache, d_cache

    def set_tree_caches(self, t_tree, d_tree) -> None:
        self._stacked[2], self._stacked[3] = t_tree, d_tree


class PagePool:
    """Free-list of physical KV blocks for one block kind (model or tree).

    Block ids run 1..n_blocks; physical block 0 is the reserved *null
    block* (see ``models.paging``) and is never handed out.  Tracks peak
    occupancy for the DBStats page counters."""

    def __init__(self, n_blocks: int):
        assert n_blocks >= 1
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks, 0, -1))  # pop -> 1..
        self.in_use = 0
        self.peak = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """All-or-nothing allocation of ``n`` block ids (None if the pool
        cannot satisfy it — the caller requeues or swaps out a victim)."""
        if n > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(n)]
        self.in_use += n
        self.peak = max(self.peak, self.in_use)
        return ids

    def free(self, ids: List[int]) -> None:
        for i in ids:
            assert i != 0, "null block cannot be freed"
            self._free.append(i)
        self.in_use -= len(ids)


class PageAllocator:
    """Host-side block tables + free pools for a paged KV arena.

    Keeps one numpy ``[slots, blocks_per_slot]`` table per block kind —
    "model" rows (length ``max_len``) and "tree" rows (length
    ``tree_capacity``) — shared by the target and the draft (their leaves
    have different row widths but identical row *counts*, so one logical
    block id backs the same rows of every leaf of that kind).  Entry 0
    means unallocated (the null block).  ``PagedKVArena`` mirrors these
    tables to device after every mutation.

    Policy knobs: ``page`` is the power-of-two block size in rows;
    ``model_blocks``/``tree_blocks`` cap the physical pools (defaults back
    every slot fully — set lower to oversubscribe, which is the whole
    point: admission then fit-checks against the *request's* horizon, not
    ``max_len``)."""

    def __init__(self, *, slots: int, page: int, max_len: int,
                 tree_capacity: int, model_blocks: Optional[int] = None,
                 tree_blocks: Optional[int] = None):
        assert page >= 1 and (page & (page - 1)) == 0, \
            f"page size must be a power of two, got {page}"
        self.page = page
        self.slots = slots
        self.nb_model_slot = paging.n_blocks(max_len, page)
        self.nb_tree_slot = paging.n_blocks(tree_capacity, page)
        self.model = PagePool(model_blocks or slots * self.nb_model_slot)
        self.tree = PagePool(tree_blocks or slots * self.nb_tree_slot)
        self.model_table = np.zeros((slots, self.nb_model_slot), np.int32)
        self.tree_table = np.zeros((slots, self.nb_tree_slot), np.int32)
        self._rows = {"model": np.zeros(slots, np.int64),
                      "tree": np.zeros(slots, np.int64)}
        self.swaps = 0
        self.preemptions = 0
        self.expand_copies = 0

    def _of(self, kind: str) -> Tuple[PagePool, np.ndarray]:
        return ((self.model, self.model_table) if kind == "model"
                else (self.tree, self.tree_table))

    def blocks_of(self, kind: str, slot: int) -> int:
        _, table = self._of(kind)
        return int(np.count_nonzero(table[slot]))

    def ensure(self, kind: str, slot: int, rows: int) -> bool:
        """Back logical rows [0, rows) of ``slot``, growing by whole
        blocks.  Growth past the currently-backed region is the
        copy-on-expand event for tree slack: the new block replaces the
        null-block alias, making previously-virtual slack real."""
        pool, table = self._of(kind)
        need = paging.n_blocks(rows, self.page)
        have = self.blocks_of(kind, slot)
        if need > have:
            ids = pool.alloc(need - have)
            if ids is None:
                return False
            table[slot, have:need] = ids
            if have > 0:
                self.expand_copies += need - have
        self._rows[kind][slot] = max(self._rows[kind][slot], rows)
        return True

    def release(self, kind: str, slot: int) -> List[int]:
        pool, table = self._of(kind)
        ids = [int(i) for i in table[slot] if i]
        pool.free(ids)
        table[slot] = 0
        self._rows[kind][slot] = 0
        return ids

    def release_slot(self, slot: int) -> None:
        self.release("model", slot)
        self.release("tree", slot)

    def counters(self) -> Dict[str, float]:
        """The DBStats page-pool counters: occupancy, peak, internal
        fragmentation (allocated-but-unused rows inside backed blocks),
        swap/preemption/expand traffic."""
        in_use = self.model.in_use + self.tree.in_use
        used_rows = int(self._rows["model"].sum() + self._rows["tree"].sum())
        frag = (100.0 * (1.0 - used_rows / (in_use * self.page))
                if in_use else 0.0)
        return {"blocks_in_use": in_use,
                "blocks_total": self.model.n_blocks + self.tree.n_blocks,
                "peak_blocks": self.model.peak + self.tree.peak,
                "frag_pct": frag,
                "swaps": self.swaps,
                "preemptions": self.preemptions,
                "expand_copies": self.expand_copies}


class PagedKVArena(KVArena):
    """Block-paged KV cache arenas behind the same ``KVArena`` interface.

    Every KV buffer (the ``CACHE_LEN_AXIS_FROM_END`` names, including the
    int8 per-row scales) becomes a ``models.paging.Paged`` leaf — a flat
    physical row pool plus the allocator's per-slot block table — while
    recurrent state and other non-length buffers stay dense.  The whole
    executor tower reads/writes these through the paged-aware cache
    helpers; jitted dispatches densify at entry and repaginate at exit,
    so schedules and dispatch counts are unchanged.

    On top of the base arena this adds the production memory policies:

      * **admission fit-check** — ``fits(req)``/``bind(slot, req)`` back a
        request's *horizon* (prompt + token budget + tree slack, capped at
        ``max_len``) instead of ``max_len`` rows, so short requests pin
        proportionally few blocks and a fixed byte budget admits more
        concurrent slots (the fig8 paged capacity claim);
      * **LRU swap-to-host** — ``swap_out(slot)`` gathers the slot's rows
        to host numpy, frees its blocks, and zeroes its table rows;
        ``swap_in(slot)`` re-allocates (possibly different) blocks and
        scatters the rows back — resumed requests are bit-identical
        because attention only ever sees the table-indirected dense view;
      * **preemption of parked slots** — ``park(slot)`` marks a slot
        preemptible; when admission cannot fit a request,
        ``swap_out_lru()`` evicts the least-recently-``touch``ed parked
        slot to make room.
    """

    def __init__(self, target, draft, *, slots: int, max_len: int,
                 tree_capacity: int, page: int = 16,
                 model_blocks: Optional[int] = None,
                 tree_blocks: Optional[int] = None,
                 lazy_tree: bool = False):
        super().__init__(target, draft, slots=slots, max_len=max_len,
                         tree_capacity=tree_capacity)
        self.pages = PageAllocator(slots=slots, page=page, max_len=max_len,
                                   tree_capacity=tree_capacity,
                                   model_blocks=model_blocks,
                                   tree_blocks=tree_blocks)
        self.page = page
        # lazy_tree backs only the busy tree region at bind and relies on
        # ensure_tree() growth calls before expansion (copy-on-expand);
        # the default backs the full tree capacity at admission.
        self.lazy_tree = lazy_tree
        self._tables: Dict[str, jax.Array] = {}
        self._swapped: Dict[int, list] = {}
        self._swap_blocks: Dict[int, Tuple[int, int]] = {}
        self._parked: set = set()
        self._stamp: Dict[int, int] = {}
        self._clock = 0

    # -- arena construction --------------------------------------------
    def _paginate(self, cache, kind: str):
        pool = self.pages.model if kind == "model" else self.pages.tree
        table = self._tables[kind]

        def conv(path, leaf):
            if leaf is None:
                return None
            name = getattr(path[-1], "key", None) if path else None
            if name not in tf.CACHE_LEN_AXIS_FROM_END:
                return leaf          # recurrent state etc. stays dense
            ax = tf.cache_len_axis(name, leaf)
            n_pre = ax - 1
            assert leaf.shape[n_pre] == self.slots
            row = leaf.shape[:n_pre] + leaf.shape[ax + 1:]
            pages = jnp.zeros(((pool.n_blocks + 1) * self.page, *row),
                              leaf.dtype)
            return paging.Paged(pages, table, self.page, leaf.shape[ax],
                                n_pre)

        return jax.tree_util.tree_map_with_path(
            conv, cache, is_leaf=lambda x: x is None)

    def _ensure(self) -> None:
        if self._stacked is not None:
            return
        self._tables = {"model": jnp.asarray(self.pages.model_table),
                        "tree": jnp.asarray(self.pages.tree_table)}
        dense = [self.target.init_cache(self.slots, self.max_len),
                 self.draft.init_cache(self.slots, self.max_len),
                 self.target.init_tree_caches(self.slots,
                                              self.tree_capacity),
                 self.draft.init_tree_caches(self.slots,
                                             self.tree_capacity)]
        kinds = ["model", "model", "tree", "tree"]
        self._stacked = [self._paginate(c, k) for c, k in zip(dense, kinds)]

    def _sync_tables(self) -> None:
        """Mirror the host block tables to device and re-thread them into
        every paged leaf (pools are untouched — tables are tiny)."""
        self._tables = {"model": jnp.asarray(self.pages.model_table),
                        "tree": jnp.asarray(self.pages.tree_table)}

        def retab(cache, table):
            return jax.tree_util.tree_map(
                lambda x: paging.Paged(x.pages, table, x.page, x.length,
                                       x.n_pre)
                if paging.is_paged(x) else x,
                cache, is_leaf=lambda x: x is None or paging.is_paged(x))

        tm, tt = self._tables["model"], self._tables["tree"]
        self._stacked = [retab(self._stacked[0], tm),
                         retab(self._stacked[1], tm),
                         retab(self._stacked[2], tt),
                         retab(self._stacked[3], tt)]

    def pool_bytes(self) -> int:
        """Actual bytes the arena pins: physical pools for paged leaves
        plus any dense (state) leaves — the fixed-HBM-budget currency of
        the fig8 paged-capacity bench."""
        self._ensure()
        total = 0
        for cache in self._stacked:
            for leaf in jax.tree_util.tree_leaves(
                    cache, is_leaf=lambda x: x is None or paging.is_paged(x)):
                if leaf is None:
                    continue
                arr = leaf.pages if paging.is_paged(leaf) else leaf
                total += arr.size * arr.dtype.itemsize
        return total

    # -- per-slot views -------------------------------------------------
    def caches(self, slot: int) -> tuple:
        """Per-slot row views, densified: paged leaves cannot ride the
        layer scan inside ``ModelBundle`` dispatches, so the per-request
        path (admission prefill, engine state machines) sees plain dense
        batch-1 caches; ``store`` scatters them back through the block
        table."""
        return tuple(paging.densify(c) for c in super().caches(slot))

    def store(self, slot: int, caches: tuple) -> None:
        """Scatter a request's dense row views back through the block
        tables.  No donation here: every paged leaf of a cache shares ONE
        table array, and donating the same buffer twice is an XLA error —
        the pools themselves still update functionally."""
        assert slot in self._in_use, f"slot {slot} not allocated"
        self._stacked = [_store_rows_nodonate(full, row, start=slot)
                         for full, row in zip(self._stacked, caches)]

    # -- admission policy ----------------------------------------------
    def _horizon(self, req) -> int:
        prompt = getattr(req, "prompt", None)
        plen = len(prompt) if prompt is not None else self.max_len
        budget = getattr(req, "max_new_tokens", None)
        if budget is None:
            budget = self.max_len
        # + tree_capacity: a final verify may commit a whole tree past the
        # budget boundary before retire truncates the tokens
        return min(self.max_len, plen + budget + self.tree_capacity)

    def _tree_rows(self, req) -> int:
        return 1 if self.lazy_tree else self.tree_capacity

    def fits(self, req) -> bool:
        nm = paging.n_blocks(self._horizon(req), self.page)
        nt = paging.n_blocks(max(self._tree_rows(req), 1), self.page)
        return (self.n_free > 0 and self.pages.model.n_free >= nm
                and self.pages.tree.n_free >= nt)

    def bind(self, slot: int, req) -> None:
        """Back the admitted request's pages (called right after
        ``alloc()``; ``fits`` made this infallible)."""
        ok = self.pages.ensure("model", slot, self._horizon(req))
        ok = ok and self.pages.ensure("tree", slot, self._tree_rows(req))
        assert ok, "bind() without a passing fits() check"
        self.touch(slot)
        self._sync_tables()

    def ensure_tree(self, slot: int, rows: int) -> None:
        """Copy-on-expand growth of the tree slack region (lazy_tree
        mode): back tree rows [0, rows) before an expansion writes
        them."""
        if not self.lazy_tree:
            return
        if not self.pages.ensure("tree", slot, min(rows,
                                                   self.tree_capacity)):
            raise RuntimeError("tree page pool exhausted on expand")
        self._sync_tables()

    def free(self, slot: int) -> None:
        super().free(slot)
        self.pages.release_slot(slot)
        self._swapped.pop(slot, None)
        self._parked.discard(slot)
        self._stamp.pop(slot, None)
        self._sync_tables()

    # -- LRU swap-to-host / preemption ---------------------------------
    def touch(self, slot: int) -> None:
        self._clock += 1
        self._stamp[slot] = self._clock

    def park(self, slot: int) -> None:
        """Mark an in-use slot preemptible (its request is idle: paused
        stream, awaiting client, ...)."""
        assert slot in self._in_use
        self._parked.add(slot)

    def unpark(self, slot: int) -> None:
        self._parked.discard(slot)

    def swap_out(self, slot: int) -> None:
        """Swap a slot's KV rows to host and free its pages.  The dense
        row view (model + tree, target + draft — including any dense
        state leaves, which a preempting occupant would overwrite) is the
        swap image."""
        assert slot in self._in_use and slot not in self._swapped
        rows = [paging.densify(tf.slice_cache_rows(c, slot, 1))
                for c in self._stacked]
        self._swapped[slot] = jax.tree_util.tree_map(np.asarray, rows)
        nm = self.pages.blocks_of("model", slot)
        nt = self.pages.blocks_of("tree", slot)
        self._swap_blocks[slot] = (nm, nt)
        self.pages.release_slot(slot)
        self.pages.swaps += 1
        self._sync_tables()

    def swap_in(self, slot: int) -> bool:
        """Restore a swapped-out slot: re-allocate its block counts
        (physical ids may differ — the table indirection makes that
        invisible) and scatter the host rows back.  False if the pools
        cannot fit it yet."""
        assert slot in self._swapped
        nm, nt = self._swap_blocks[slot]
        if self.pages.model.n_free < nm or self.pages.tree.n_free < nt:
            return False
        ok = self.pages.ensure("model", slot, nm * self.page)
        ok = ok and self.pages.ensure("tree", slot, nt * self.page)
        assert ok
        self._sync_tables()
        rows = jax.tree_util.tree_map(jnp.asarray, self._swapped.pop(slot))
        del self._swap_blocks[slot]
        self._stacked = [tf.update_cache_rows(full, row, start=slot)
                         for full, row in zip(self._stacked, rows)]
        self.touch(slot)
        return True

    def swap_out_lru(self) -> Optional[int]:
        """Evict the least-recently-touched parked slot (admission's
        make-room path).  None when nothing is preemptible."""
        victims = [s for s in self._parked if s not in self._swapped]
        if not victims:
            return None
        slot = min(victims, key=lambda s: self._stamp.get(s, 0))
        self.swap_out(slot)
        self.pages.preemptions += 1
        return slot


@dataclasses.dataclass
class SchedulerStats:
    """Per-uid lifecycle timestamps (in global pipeline timesteps) plus an
    occupancy trace — the no-starvation / no-double-allocation invariants
    in tests/test_serving_db.py are asserted against these."""
    submitted_t: Dict[int, int] = dataclasses.field(default_factory=dict)
    admitted_t: Dict[int, int] = dataclasses.field(default_factory=dict)
    finished_t: Dict[int, int] = dataclasses.field(default_factory=dict)
    occupancy: List[int] = dataclasses.field(default_factory=list)

    def queue_delay(self, uid: int) -> int:
        return self.admitted_t[uid] - self.submitted_t[uid]


class DynamicBatchScheduler:
    """Priority/deadline-aware admission of arrived requests onto free KV
    slots (default priorities submitted in arrival order degenerate to
    exact FIFO; see the module docstring for the equal-priority aging
    preference).

    ``aging`` is the anti-starvation bound: every ``aging`` timesteps a
    queued request waits, its effective priority rises one level, so a
    bounded priority spread implies a bounded queue delay no matter how
    much higher-priority traffic keeps arriving."""

    def __init__(self, arena: SlotPool, *, aging: int = 8):
        assert aging >= 1
        self.arena = arena
        self.aging = aging
        # (submission seq, request) — the seq is the FIFO tie-break and is
        # carried alongside the request (not keyed on object identity, so
        # re-submitting the same Request object is well-defined)
        self._entries: List[Tuple[int, object]] = []
        self._seq = 0
        self.stats = SchedulerStats()

    def submit(self, req) -> None:
        self._entries.append((self._seq, req))
        self._seq += 1
        self.stats.submitted_t[req.uid] = getattr(req, "arrival_t", 0)

    @property
    def queue(self) -> List:
        """Queued requests in submission order (read-only view)."""
        return [r for _, r in self._entries]

    @property
    def pending(self) -> int:
        return len(self._entries)

    def next_arrival(self) -> Optional[int]:
        """Earliest arrival_t among queued requests (None if queue empty)."""
        if not self._entries:
            return None
        return min(getattr(r, "arrival_t", 0) for _, r in self._entries)

    def effective_priority(self, req, now: int) -> int:
        """priority + waited // aging (+1 inside the deadline window)."""
        eff = getattr(req, "priority", 0)
        eff += max(0, now - getattr(req, "arrival_t", 0)) // self.aging
        deadline = getattr(req, "deadline_t", None)
        if deadline is not None and deadline - now <= self.aging:
            eff += 1
        return eff

    def _pop_best_entry(self, now: int):
        """Highest effective priority among arrived requests; ties go to
        the earliest submission (exact FIFO when priorities are equal)."""
        arrived = [(seq, r) for seq, r in self._entries
                   if getattr(r, "arrival_t", 0) <= now]
        if not arrived:
            return None
        entry = max(arrived,
                    key=lambda e: (self.effective_priority(e[1], now),
                                   -e[0]))
        self._entries.remove(entry)
        return entry

    def _pop_best(self, now: int):
        entry = self._pop_best_entry(now)
        return entry[1] if entry is not None else None

    def admit(self, now: int) -> List[Tuple[object, int]]:
        """Admit arrived requests (best-effective-priority first) while
        slots are free.  Returns [(request, slot)] for this timestep's
        joins.

        Page-aware arenas add a fit-check: a request whose page horizon
        does not fit first tries to make room by preempting (LRU
        swap-to-host) parked slots; failing that it is requeued with its
        original submission seq, so aging keeps raising its effective
        priority while it waits for pages (the anti-starvation bound
        holds under page pressure exactly as under slot pressure)."""
        admitted: List[Tuple[object, int]] = []
        while self.arena.n_free:
            entry = self._pop_best_entry(now)
            if entry is None:
                break
            seq, req = entry
            fits = getattr(self.arena, "fits", None)
            if fits is not None and not fits(req):
                swap_lru = getattr(self.arena, "swap_out_lru", None)
                while (swap_lru is not None and not fits(req)
                       and swap_lru() is not None):
                    pass
                if not fits(req):
                    self._entries.append(entry)   # requeue, seq preserved
                    break
            slot = self.arena.alloc()
            bind = getattr(self.arena, "bind", None)
            if bind is not None:
                bind(slot, req)
            self.stats.admitted_t[req.uid] = now
            admitted.append((req, slot))
        return admitted

    def retire(self, uid: int, slot: int, now: int, caches=None) -> None:
        """Release a finished request's slot (optionally recycling its
        cache buffers) so the next refill can claim it."""
        if caches is not None:
            self.arena.store(slot, caches)
        self.arena.free(slot)
        self.stats.finished_t[uid] = now
