"""KV arena + admission/eviction scheduler for SpecPipe-DB.

The paper's dynamic batching keeps the pipeline full of *different*
requests: whenever one finishes, the next queued request joins at its
prefill and decodes alongside the rest.  Three pieces implement that here:

  * ``SlotPool`` — bare slot accounting (free list + in-use set).  The
    compute backend owns the actual cache storage (see
    ``serving.executor``): the local backend's ``KVArena`` extends the
    pool with slot-stacked cache pytrees, while the sharded backend keeps
    stage-layout arenas of its own and uses the pool unadorned.
  * ``KVArena`` — slot-stacked cache arenas (target + draft model caches
    and the two tree caches, each ONE pytree with a leading slot axis) so
    the fused per-timestep tree-verify dispatch reads every in-flight
    request from one buffer; per-slot row views serve admission prefill
    and retire.  Slots are recycled across requests without zeroing:
    every attention mask is bounded by the new occupant's ``model_len`` /
    ancestor mask, and recurrent (ssm/rglru) state is re-seeded from zero
    at prefill, so a previous occupant's stale rows and state never leak
    (the equivalence tests pin this).
  * ``DynamicBatchScheduler`` — priority/deadline-aware arrival queue
    with per-request ``arrival_t`` (in pipeline timesteps), admission
    onto free slots each timestep (join-on-prefill), and
    retire-on-completion (eos or token budget) which frees the slot for
    the next refill.

Admission policy (priority + aging): each ``admit(now)`` considers every
*arrived* request and admits the one with the highest effective priority

    eff(req, now) = req.priority
                    + (now - req.arrival_t) // aging        (anti-starvation)
                    + 1 if req.deadline_t is within ``aging`` timesteps

with ties broken by submission order.  All-default-priority traffic
submitted in arrival order — the engine's case, and everything the PR-1/
PR-2 equivalence tests exercise — degenerates to exact FIFO.  The aging
term applies uniformly, so among equal priorities a request that has
already waited ``aging`` timesteps longer than a peer is preferred
(FIFO-by-wait rather than FIFO-by-submission when submissions arrive out
of arrival order).  Aging bounds starvation: a request waiting
``aging * Δpriority`` timesteps outranks any fresher request ``Δpriority``
levels above it, so queue delay is bounded for any bounded priority range
(tests/test_scheduler_priority.py asserts the reorder, the bound, and the
equal-priority aging preference).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax

from repro.models import transformer as tf

# Row write-back donates the full arena buffer so XLA can update the slot
# rows in place (on backends without donation this degrades to a copy —
# same result, just not O(1)).  ``start`` is static: one compile per slot.
_store_rows = jax.jit(tf.update_cache_rows, static_argnames=("start",),
                      donate_argnums=(0,))


class SlotPool:
    """Free-list accounting for ``slots`` recyclable KV slots.

    Storage-agnostic on purpose: the scheduler admits/retires against this
    interface, and each ``PipelineExecutor`` backend attaches whatever
    cache layout it needs (slot-stacked pytrees locally, stage-sharded
    arenas on the pipeline deployment)."""

    def __init__(self, slots: int):
        assert slots >= 1
        self.slots = slots
        self._free: List[int] = list(range(slots - 1, -1, -1))  # pop -> 0..
        self._in_use: set = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return len(self._in_use)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("KVArena exhausted: no free slot")
        slot = self._free.pop()
        if slot in self._in_use:
            raise RuntimeError(f"KV slot {slot} double-allocated")
        self._in_use.add(slot)
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._in_use:
            raise RuntimeError(f"KV slot {slot} freed but not in use")
        self._in_use.remove(slot)
        self._free.append(slot)


class KVArena(SlotPool):
    """Slot-stacked KV cache arenas, allocated lazily and recycled across
    requests.

    All four cache pytrees carry a leading *slot* axis (buffers of the
    repeated-unit "stack" layout carry it right after their reps dim) —
    the layout the fused SpecPipe-DB dispatch and the batched per-row
    commit read/write in place.  ``caches(slot)`` / ``store(slot, ...)``
    expose per-slot row views for admission prefill and retire.
    """

    def __init__(self, target, draft, *, slots: int, max_len: int,
                 tree_capacity: int):
        super().__init__(slots)
        self.target, self.draft = target, draft
        self.max_len, self.tree_capacity = max_len, tree_capacity
        self._stacked: Optional[list] = None

    def bytes_per_slot(self) -> int:
        """KV bytes one slot pins across all four arenas (model + tree,
        target + draft), computed from abstract shapes — no allocation.
        This is the admission currency of the int8 serving path: the
        quantized layout (int8 rows + one fp32 scale per kv-head row)
        roughly quarters this, so the same byte budget admits ~4x the
        slots (the CI gate requires >=1.9x)."""
        total = 0
        for fn, cap in ((self.target.init_cache, self.max_len),
                        (self.draft.init_cache, self.max_len),
                        (self.target.init_tree_caches, self.tree_capacity),
                        (self.draft.init_tree_caches, self.tree_capacity)):
            shapes = jax.eval_shape(lambda f=fn, c=cap: f(1, c))
            total += sum(leaf.size * leaf.dtype.itemsize
                         for leaf in jax.tree_util.tree_leaves(shapes))
        return total

    def _ensure(self) -> None:
        if self._stacked is None:
            self._stacked = [
                self.target.init_cache(self.slots, self.max_len),
                self.draft.init_cache(self.slots, self.max_len),
                self.target.init_tree_caches(self.slots, self.tree_capacity),
                self.draft.init_tree_caches(self.slots, self.tree_capacity)]

    def alloc(self) -> int:
        slot = super().alloc()
        self._ensure()
        return slot

    def caches(self, slot: int) -> tuple:
        """Per-slot row views (t_cache, d_cache, t_tree, d_tree), each a
        batch-1 cache pytree sliced out of the stacked arena."""
        assert slot in self._in_use, f"slot {slot} not allocated"
        return tuple(tf.slice_cache_rows(c, slot, 1) for c in self._stacked)

    def store(self, slot: int, caches: tuple) -> None:
        """Write a request's (t_cache, d_cache, t_tree, d_tree) row views
        back into the stacked arena so the next occupant reuses the slot
        (stale rows are masked, never zeroed)."""
        assert slot in self._in_use, f"slot {slot} not allocated"
        self._stacked = [_store_rows(full, row, start=slot)
                         for full, row in zip(self._stacked, caches)]

    # -- fused-path access (whole-arena pytrees) ------------------------
    @property
    def stacked(self) -> tuple:
        """(t_cache, d_cache, t_tree, d_tree), slot axis leading."""
        self._ensure()
        return tuple(self._stacked)

    def set_model_caches(self, t_cache, d_cache) -> None:
        self._stacked[0], self._stacked[1] = t_cache, d_cache

    def set_tree_caches(self, t_tree, d_tree) -> None:
        self._stacked[2], self._stacked[3] = t_tree, d_tree


@dataclasses.dataclass
class SchedulerStats:
    """Per-uid lifecycle timestamps (in global pipeline timesteps) plus an
    occupancy trace — the no-starvation / no-double-allocation invariants
    in tests/test_serving_db.py are asserted against these."""
    submitted_t: Dict[int, int] = dataclasses.field(default_factory=dict)
    admitted_t: Dict[int, int] = dataclasses.field(default_factory=dict)
    finished_t: Dict[int, int] = dataclasses.field(default_factory=dict)
    occupancy: List[int] = dataclasses.field(default_factory=list)

    def queue_delay(self, uid: int) -> int:
        return self.admitted_t[uid] - self.submitted_t[uid]


class DynamicBatchScheduler:
    """Priority/deadline-aware admission of arrived requests onto free KV
    slots (default priorities submitted in arrival order degenerate to
    exact FIFO; see the module docstring for the equal-priority aging
    preference).

    ``aging`` is the anti-starvation bound: every ``aging`` timesteps a
    queued request waits, its effective priority rises one level, so a
    bounded priority spread implies a bounded queue delay no matter how
    much higher-priority traffic keeps arriving."""

    def __init__(self, arena: SlotPool, *, aging: int = 8):
        assert aging >= 1
        self.arena = arena
        self.aging = aging
        # (submission seq, request) — the seq is the FIFO tie-break and is
        # carried alongside the request (not keyed on object identity, so
        # re-submitting the same Request object is well-defined)
        self._entries: List[Tuple[int, object]] = []
        self._seq = 0
        self.stats = SchedulerStats()

    def submit(self, req) -> None:
        self._entries.append((self._seq, req))
        self._seq += 1
        self.stats.submitted_t[req.uid] = getattr(req, "arrival_t", 0)

    @property
    def queue(self) -> List:
        """Queued requests in submission order (read-only view)."""
        return [r for _, r in self._entries]

    @property
    def pending(self) -> int:
        return len(self._entries)

    def next_arrival(self) -> Optional[int]:
        """Earliest arrival_t among queued requests (None if queue empty)."""
        if not self._entries:
            return None
        return min(getattr(r, "arrival_t", 0) for _, r in self._entries)

    def effective_priority(self, req, now: int) -> int:
        """priority + waited // aging (+1 inside the deadline window)."""
        eff = getattr(req, "priority", 0)
        eff += max(0, now - getattr(req, "arrival_t", 0)) // self.aging
        deadline = getattr(req, "deadline_t", None)
        if deadline is not None and deadline - now <= self.aging:
            eff += 1
        return eff

    def _pop_best(self, now: int):
        """Highest effective priority among arrived requests; ties go to
        the earliest submission (exact FIFO when priorities are equal)."""
        arrived = [(seq, r) for seq, r in self._entries
                   if getattr(r, "arrival_t", 0) <= now]
        if not arrived:
            return None
        seq, best = max(arrived,
                        key=lambda e: (self.effective_priority(e[1], now),
                                       -e[0]))
        self._entries.remove((seq, best))
        return best

    def admit(self, now: int) -> List[Tuple[object, int]]:
        """Admit arrived requests (best-effective-priority first) while
        slots are free.  Returns [(request, slot)] for this timestep's
        joins."""
        admitted: List[Tuple[object, int]] = []
        while self.arena.n_free:
            req = self._pop_best(now)
            if req is None:
                break
            slot = self.arena.alloc()
            self.stats.admitted_t[req.uid] = now
            admitted.append((req, slot))
        return admitted

    def retire(self, uid: int, slot: int, now: int, caches=None) -> None:
        """Release a finished request's slot (optionally recycling its
        cache buffers) so the next refill can claim it."""
        if caches is not None:
            self.arena.store(slot, caches)
        self.arena.free(slot)
        self.stats.finished_t[uid] = now
