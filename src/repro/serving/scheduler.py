"""KV arena + admission/eviction scheduler for SpecPipe-DB.

The paper's dynamic batching keeps the pipeline full of *different*
requests: whenever one finishes, the next queued request joins at its
prefill and decodes alongside the rest.  Two pieces implement that here:

  * ``KVArena`` — slot-stacked cache arenas (target + draft model caches
    and the two tree caches, each ONE pytree with a leading slot axis) so
    the fused per-timestep tree-verify dispatch reads every in-flight
    request from one buffer; per-slot row views serve admission prefill
    and retire.  Slots are recycled across requests without zeroing:
    every attention mask is bounded by the new occupant's ``model_len`` /
    ancestor mask, and recurrent (ssm/rglru) state is re-seeded from zero
    at prefill, so a previous occupant's stale rows and state never leak
    (the equivalence tests pin this).
  * ``DynamicBatchScheduler`` — FIFO arrival queue with per-request
    ``arrival_t`` (in pipeline timesteps), admission onto free slots each
    timestep (join-on-prefill), and retire-on-completion (eos or token
    budget) which frees the slot for the next refill.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

import jax

from repro.models import transformer as tf

# Row write-back donates the full arena buffer so XLA can update the slot
# rows in place (on backends without donation this degrades to a copy —
# same result, just not O(1)).  ``start`` is static: one compile per slot.
_store_rows = jax.jit(tf.update_cache_rows, static_argnames=("start",),
                      donate_argnums=(0,))


class KVArena:
    """Slot-stacked KV cache arenas, allocated lazily and recycled across
    requests.

    All four cache pytrees carry a leading *slot* axis (buffers of the
    repeated-unit "stack" layout carry it right after their reps dim) —
    the layout the fused SpecPipe-DB dispatch and the batched per-row
    commit read/write in place.  ``caches(slot)`` / ``store(slot, ...)``
    expose per-slot row views for admission prefill and retire.
    """

    def __init__(self, target, draft, *, slots: int, max_len: int,
                 tree_capacity: int):
        assert slots >= 1
        self.target, self.draft = target, draft
        self.slots, self.max_len, self.tree_capacity = \
            slots, max_len, tree_capacity
        self._free: List[int] = list(range(slots - 1, -1, -1))  # pop -> 0..
        self._in_use: set = set()
        self._stacked: Optional[list] = None

    def _ensure(self) -> None:
        if self._stacked is None:
            self._stacked = [
                self.target.init_cache(self.slots, self.max_len),
                self.draft.init_cache(self.slots, self.max_len),
                self.target.init_tree_caches(self.slots, self.tree_capacity),
                self.draft.init_tree_caches(self.slots, self.tree_capacity)]

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return len(self._in_use)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("KVArena exhausted: no free slot")
        slot = self._free.pop()
        if slot in self._in_use:
            raise RuntimeError(f"KV slot {slot} double-allocated")
        self._in_use.add(slot)
        self._ensure()
        return slot

    def caches(self, slot: int) -> tuple:
        """Per-slot row views (t_cache, d_cache, t_tree, d_tree), each a
        batch-1 cache pytree sliced out of the stacked arena."""
        assert slot in self._in_use, f"slot {slot} not allocated"
        return tuple(tf.slice_cache_rows(c, slot, 1) for c in self._stacked)

    def store(self, slot: int, caches: tuple) -> None:
        """Write a request's (t_cache, d_cache, t_tree, d_tree) row views
        back into the stacked arena so the next occupant reuses the slot
        (stale rows are masked, never zeroed)."""
        assert slot in self._in_use, f"slot {slot} not allocated"
        self._stacked = [_store_rows(full, row, start=slot)
                         for full, row in zip(self._stacked, caches)]

    # -- fused-path access (whole-arena pytrees) ------------------------
    @property
    def stacked(self) -> tuple:
        """(t_cache, d_cache, t_tree, d_tree), slot axis leading."""
        self._ensure()
        return tuple(self._stacked)

    def set_model_caches(self, t_cache, d_cache) -> None:
        self._stacked[0], self._stacked[1] = t_cache, d_cache

    def set_tree_caches(self, t_tree, d_tree) -> None:
        self._stacked[2], self._stacked[3] = t_tree, d_tree

    def free(self, slot: int) -> None:
        if slot not in self._in_use:
            raise RuntimeError(f"KV slot {slot} freed but not in use")
        self._in_use.remove(slot)
        self._free.append(slot)


@dataclasses.dataclass
class SchedulerStats:
    """Per-uid lifecycle timestamps (in global pipeline timesteps) plus an
    occupancy trace — the no-starvation / no-double-allocation invariants
    in tests/test_serving_db.py are asserted against these."""
    submitted_t: Dict[int, int] = dataclasses.field(default_factory=dict)
    admitted_t: Dict[int, int] = dataclasses.field(default_factory=dict)
    finished_t: Dict[int, int] = dataclasses.field(default_factory=dict)
    occupancy: List[int] = dataclasses.field(default_factory=list)

    def queue_delay(self, uid: int) -> int:
        return self.admitted_t[uid] - self.submitted_t[uid]


class DynamicBatchScheduler:
    """FIFO admission of arrived requests onto free KV slots."""

    def __init__(self, arena: KVArena):
        self.arena = arena
        self.queue: Deque = collections.deque()
        self.stats = SchedulerStats()

    def submit(self, req) -> None:
        self.queue.append(req)
        self.stats.submitted_t[req.uid] = getattr(req, "arrival_t", 0)

    @property
    def pending(self) -> int:
        return len(self.queue)

    def next_arrival(self) -> Optional[int]:
        """Earliest arrival_t among queued requests (None if queue empty)."""
        if not self.queue:
            return None
        return min(getattr(r, "arrival_t", 0) for r in self.queue)

    def admit(self, now: int) -> List[Tuple[object, int]]:
        """Admit arrived requests (FIFO) while slots are free.  Returns
        [(request, slot)] for this timestep's joins."""
        admitted: List[Tuple[object, int]] = []
        while self.arena.n_free:
            req = next((r for r in self.queue
                        if getattr(r, "arrival_t", 0) <= now), None)
            if req is None:
                break
            self.queue.remove(req)
            slot = self.arena.alloc()
            self.stats.admitted_t[req.uid] = now
            admitted.append((req, slot))
        return admitted

    def retire(self, uid: int, slot: int, now: int, caches=None) -> None:
        """Release a finished request's slot (optionally recycling its
        cache buffers) so the next refill can claim it."""
        if caches is not None:
            self.arena.store(slot, caches)
        self.arena.free(slot)
        self.stats.finished_t[uid] = now
