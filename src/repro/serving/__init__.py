"""Serving layer: the SpecPipe-DB continuous-batching engine, the
``PipelineExecutor`` compute backends (local fused / sharded flush /
overlapped / async free-running) and the KV-arena schedulers.
"""
from repro.serving.dynbatch import (DBStats, SpecPipeDBEngine,
                                    generate_with_executor)
from repro.serving.engine import Request, Result, ServingEngine
from repro.serving.executor import (AsyncExecutorError,
                                    AsyncPipelineExecutor,
                                    DeferredLogits, DeferredPrefill,
                                    LocalFusedExecutor,
                                    OverlappedShardedExecutor,
                                    PipelineExecutor,
                                    ShardedPipelineExecutor)
from repro.serving.scheduler import (DynamicBatchScheduler, KVArena,
                                     PagedKVArena, SchedulerStats, SlotPool)

__all__ = ["AsyncExecutorError", "AsyncPipelineExecutor", "DBStats",
           "DeferredLogits", "DeferredPrefill",
           "DynamicBatchScheduler", "KVArena",
           "LocalFusedExecutor", "OverlappedShardedExecutor",
           "PagedKVArena", "PipelineExecutor", "Request", "Result",
           "SchedulerStats", "ServingEngine", "ShardedPipelineExecutor",
           "SlotPool", "SpecPipeDBEngine", "generate_with_executor"]
