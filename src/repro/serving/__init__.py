from repro.serving.engine import Request, Result, ServingEngine

__all__ = ["Request", "Result", "ServingEngine"]
