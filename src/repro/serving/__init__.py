from repro.serving.dynbatch import DBStats, SpecPipeDBEngine
from repro.serving.engine import Request, Result, ServingEngine
from repro.serving.scheduler import (DynamicBatchScheduler, KVArena,
                                     SchedulerStats)

__all__ = ["DBStats", "DynamicBatchScheduler", "KVArena", "Request",
           "Result", "SchedulerStats", "ServingEngine", "SpecPipeDBEngine"]
