from repro.serving.dynbatch import (DBStats, SpecPipeDBEngine,
                                    generate_with_executor)
from repro.serving.engine import Request, Result, ServingEngine
from repro.serving.executor import (DeferredLogits, DeferredPrefill,
                                    LocalFusedExecutor,
                                    OverlappedShardedExecutor,
                                    PipelineExecutor,
                                    ShardedPipelineExecutor)
from repro.serving.scheduler import (DynamicBatchScheduler, KVArena,
                                     PagedKVArena, SchedulerStats, SlotPool)

__all__ = ["DBStats", "DeferredLogits", "DeferredPrefill",
           "DynamicBatchScheduler", "KVArena",
           "LocalFusedExecutor", "OverlappedShardedExecutor",
           "PagedKVArena", "PipelineExecutor", "Request", "Result",
           "SchedulerStats", "ServingEngine", "ShardedPipelineExecutor",
           "SlotPool", "SpecPipeDBEngine", "generate_with_executor"]
