"""SpecPipe-DB: continuous-batching multi-request PipeDec engine.

The single-request engine (``core.pipedec``) gives the lowest latency but
leaves the pipeline idle whenever one task stalls; the paper's DB mode
keeps several requests' speculative token trees in flight at once — their
tree layers share every pipeline timestep (stacked along the batch axis in
each stage) and finished requests are replaced from the queue without
draining the pipeline (§ dynamic batching; 1.64–2.08× vLLM throughput in
the paper's Table).

Executor seam: the engine is the logical scheduler only — per-timestep
batched compute (fused tree-verify, batched commit, prune remap, admission
prefill) runs through a pluggable ``serving.executor.PipelineExecutor``.
``LocalFusedExecutor`` (default) is PR-2's fused single-device path: ONE
batched ``tree_verify`` per model per timestep over the slot-stacked
``KVArena``, power-of-two slot-count bucketing, batched exit commit.
``ShardedPipelineExecutor`` runs the same dispatches on the paper's
pipelined deployment — the target stack partitioned over an
``n_stages``-device mesh with the per-row metadata riding the ``ppermute``
activation ring (``launch.pipeline``), flushing each entry through all
stages so logits stay available at entry.  ``OverlappedShardedExecutor``
is the steady-state schedule on the same deployment: the ring persists
and stays full, the engine issues exactly ONE ring tick per executed
global timestep, each ``Flight`` carries a *deferred* logits future the
tick resolves at ``exit_t``, and misses/retirements kill the slot's
in-flight layers in-ring (pruning propagation).
``AsyncPipelineExecutor`` drops the host lockstep behind the same seam:
free-running per-stage actor threads pull ring layers from bounded inbox
queues and apply the very same per-stage step functions
(``launch.pipeline.make_stage_fns``), a disaggregated draft actor
speculates on its own device, and kill messages cancel stale in-flight
layers at whatever stage they sit.  Outputs are
bit-identical across all backends (and to the single-request engine)
because only *where and when* the verify logits materialise changes,
never *what* is computed — the same argument the paper makes for
losslessness; tests/test_serving_db.py and tests/test_executor_sharded.py
pin it.  Wall-clock is priced in ``core.sim.specpipe_db_*`` /
``specpipe_db_sharded_*`` (the overlapped schedule is the ``flush=False``
curve, measured).

Per-request *decisions* (flight bookkeeping, token selection with
per-request ``SamplingParams``, tree expand/prune, index remaps) run
through the same ``PipeDecEngine`` phase methods (gather-entry /
apply-fused / exit-commit) the single-request engine uses — that engine is
literally the B=1 case of this code — so each request's operation trace is
identical to running it alone.

Scheduling per global timestep:
  1. refill — admit arrived requests (priority/aging order, FIFO when
     priorities tie) onto free KV slots, running their prefill
     (join-on-prefill) through the executor into their arena rows.  On
     the overlapped backend the prefill rides the ring instead
     (``executor.begin_prefill``): the prompt enters the next tick's
     prefill lane — zero extra dispatches, the ring never idles — and
     the request parks as *joining* until the lane exits
     ``n_stages - 1`` ticks later, when its ``DecodeState`` is seeded
     from the resolved ``DeferredPrefill`` logits;
  2. advance — gather every active request's entry, run the fused verify,
     then expansion and (batched-commit) exit per slot;
  3. retire — requests that hit eos or their token budget release their
     slot (retire-on-eos) for the next refill.

Streaming: ``run(on_token=...)`` emits ``(uid, token, timestep)`` the
timestep each token is committed (the admission timestep for the prefill
token) instead of only at retire; the streamed prefix always equals the
final ``Result.tokens``.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dynbatch import TreeBatch
from repro.core.pipedec import (DecodeState, EntryInputs, GenStats,
                                PipeDecConfig, PipeDecEngine)
from repro.core.speculative import ModelBundle
from repro.serving.executor import LocalFusedExecutor, PipelineExecutor
from repro.serving.scheduler import DynamicBatchScheduler, KVArena


@dataclasses.dataclass
class _Active:
    req: object
    state: DecodeState
    t0: float
    emitted: int = 0          # tokens already streamed via on_token


@dataclasses.dataclass
class _Joining:
    """A request whose admission prefill is riding the ring (overlapped
    backend with prefill-in-ring): the slot is allocated and the padded
    prompt advances one stage per tick inside the normal tick dispatch;
    once the ``DeferredPrefill`` future resolves (``n_stages - 1`` ticks
    after entry) the request's ``DecodeState`` is seeded from the
    resolved logits and it joins ``active``."""
    req: object
    key: jax.Array
    handle: object            # DeferredPrefill
    t0: float


@dataclasses.dataclass
class DBStats:
    """Aggregate engine statistics for one ``run()``.

    ``timesteps`` counts *executed* shared pipeline timesteps (idle gaps
    between sparse arrivals are fast-forwarded, not counted), so
    ``tokens_per_timestep`` prices what the pipeline does while busy and
    aligns 1:1 with the ``occupancy`` trace.  ``verify_dispatches`` traces
    the number of fused tree-verify calls per model per timestep (0 when
    no slot had a pending entry, otherwise exactly 1 — the fusion the
    equivalence test asserts via the executor's ``calls`` hook).
    ``tick_dispatches`` traces the overlapped backend's ring ticks per
    executed timestep — exactly 1 every timestep (the ring must advance
    even when no entry is pending); empty on the flush/local backends.
    ``accepted`` / ``proposed`` count speculative verify decisions per
    uid (a hit accepts the drafted node, a miss falls back to the target
    token); their totals give the run's aggregate ``acceptance_rate`` —
    the regression currency of the int8 serving path.
    ``separate_prefill_dispatches`` counts admissions that ran a
    standalone ``executor.prefill`` dispatch instead of riding the ring's
    (chunked) prefill lane — exactly 0 on the overlapped backend at ANY
    prompt length unless the lane is disabled.  ``page_counters`` traces
    the paged arena's pool counters per executed timestep (blocks in
    use/total/peak, fragmentation %, swaps, preemptions, copy-on-expand
    events); empty on dense arenas.
    """
    timesteps: int = 0
    total_commits: int = 0
    per_request: Dict[int, GenStats] = dataclasses.field(default_factory=dict)
    occupancy: List[int] = dataclasses.field(default_factory=list)
    verify_dispatches: List[int] = dataclasses.field(default_factory=list)
    tick_dispatches: List[int] = dataclasses.field(default_factory=list)
    accepted: Dict[int, int] = dataclasses.field(default_factory=dict)
    proposed: Dict[int, int] = dataclasses.field(default_factory=dict)
    total_accepted: int = 0
    total_proposed: int = 0
    separate_prefill_dispatches: int = 0
    page_counters: List[Dict] = dataclasses.field(default_factory=list)

    @property
    def tokens_per_timestep(self) -> float:
        return self.total_commits / self.timesteps if self.timesteps else 0.0

    @property
    def peak_occupancy(self) -> int:
        return max(self.occupancy) if self.occupancy else 0

    @property
    def acceptance_rate(self) -> float:
        """Aggregate accepted/proposed over every retired request."""
        return (self.total_accepted / self.total_proposed
                if self.total_proposed else 0.0)

    def acceptance_of(self, uid: int) -> float:
        prop = self.proposed.get(uid, 0)
        return self.accepted.get(uid, 0) / prop if prop else 0.0

    def record_acceptance(self, uid: int, st: GenStats) -> None:
        """Fold one request's verify decisions into the per-uid and
        aggregate counters (called at retire)."""
        self.accepted[uid] = st.hits
        self.proposed[uid] = st.hits + st.misses
        self.total_accepted += st.hits
        self.total_proposed += st.hits + st.misses


class SpecPipeDBEngine:
    """Dynamic-batching PipeDec: submit ``Request``s, then ``run()``."""

    def __init__(self, target: ModelBundle, draft: ModelBundle,
                 pcfg: Optional[PipeDecConfig] = None, *,
                 max_len: int = 512, max_slots: int = 4,
                 eos_token: Optional[int] = None, fused: bool = True,
                 executor: Optional[PipelineExecutor] = None):
        """``executor`` selects the compute backend (default:
        ``LocalFusedExecutor``); ``fused=False`` falls back to the looped
        per-slot dispatch (two ``tree_verify`` calls per request per
        timestep) — kept as the reference the fused-vs-looped equivalence
        test pins outputs against (local backend only)."""
        self.fused = fused
        self.pcfg = pcfg or PipeDecConfig()
        self.inner = PipeDecEngine(target, draft, self.pcfg, max_len=max_len)
        if executor is None:
            executor = LocalFusedExecutor(
                target, draft, slots=max_slots, max_len=max_len,
                tree_capacity=self.inner.tree_buffer_capacity,
                capacity=self.pcfg.capacity)
        assert executor.slots == max_slots, \
            "executor slot count must match max_slots"
        self.executor = executor
        self.arena = executor.arena
        assert fused or isinstance(self.arena, KVArena), \
            "looped (fused=False) mode needs the local KVArena backend"
        self.overlapped = bool(getattr(executor, "overlapped", False))
        if self.overlapped:
            assert fused, "the overlapped schedule is fused by construction"
            assert executor.n_stages == self.pcfg.n_stages, \
                ("overlapped executor: the mesh stage count must equal "
                 "PipeDecConfig.n_stages — the ring IS the flight "
                 "bookkeeping, so the fill latencies must agree")
        self.sched = DynamicBatchScheduler(self.arena)
        self.trees = TreeBatch(max_slots, self.pcfg.capacity)
        self.max_slots = max_slots
        self.eos_token = eos_token
        self.stats = DBStats()

    def submit(self, req) -> None:
        """Queue a request (``arrival_t`` is in global pipeline timesteps;
        requests join once arrived AND a KV slot is free, highest
        effective priority first)."""
        self.sched.submit(req)

    # ------------------------------------------------------------------
    def _timestep_guard(self) -> int:
        # prefill-in-ring adds an n_stages pipeline-fill delay between a
        # request's admission and its first entry — budget it per request,
        # plus one tick per extra prompt chunk when the prompt streams
        # through the lane over several ticks (chunked prefill)
        cap = getattr(self.executor, "prefill_cap", 0)
        chunks = lambda r: (
            max(-(-int(np.asarray(r.prompt).size) // cap), 1) - 1
            if cap else 0)
        per_req = sum(
            r.max_new_tokens * (self.pcfg.n_stages + 2) + 17
            + self.pcfg.n_stages + 1 + chunks(r)
            for r in self.sched.queue)
        arrivals = max((getattr(r, "arrival_t", 0)
                        for r in self.sched.queue), default=0)
        return 64 + arrivals + per_req

    # -- fused phase 1: stacked entry rows shared by all fused backends --
    def _entry_rows(self, active: Dict[int, _Active], pending: List[int]):
        """Stack every pending slot's entry layer (via the TreeBatch's
        vmapped deepest-layer view — no per-slot gather) into full-slot
        arrays.  Returns (tokens, positions, masks, model_len, write_idx,
        row_on, node_idx_b); non-pending rows are masked and only ever
        write into their own slack region."""
        p, tcap = self.pcfg, self.inner.tree_buffer_capacity
        nb = self.max_slots
        w = p.width

        row_on = np.zeros((nb,), bool)
        for slot in pending:
            row_on[slot] = True
        on = jnp.asarray(row_on)

        # stacked entry views of ALL slot rows (stale/non-pending rows are
        # masked below and only ever write into their own slack region)
        toks_b, idx_b, valid_b, mask_b = self.trees.deepest_layers(w)
        valid_b = valid_b & on[:, None]
        depth_b = jnp.take_along_axis(self.trees.stacked.depth, idx_b,
                                      axis=1)

        mlen_rows = np.zeros((nb,), np.int32)
        for slot in pending:
            mlen_rows[slot] = active[slot].state.model_len
        mlen = jnp.asarray(mlen_rows)

        # padded rows of a pending layer sit at model_len (depth 0), exactly
        # like the single-request gather; fully-masked slots sit at 0
        depths = jnp.where(valid_b, depth_b, 0)
        positions = jnp.where(on[:, None], mlen[:, None] + depths,
                              0).astype(jnp.int32)
        masks = jnp.pad(mask_b, ((0, 0), (0, 0),
                                 (0, tcap - mask_b.shape[-1])))
        masks = masks & valid_b[:, :, None]
        tokens = jnp.where(valid_b, toks_b, 0)
        # masked rows park their (never-attended) writes in the slack
        # region [capacity, capacity + w) of their OWN slot's tree buffer
        wi = jnp.where(on, self.trees.stacked.layer_start,
                       p.capacity).astype(jnp.int32)
        mlen = jnp.where(on, mlen, 0)

        # one host sync for every slot's node indices (the only entry
        # metadata the bookkeeping needs)
        node_idx_b = np.where(np.asarray(valid_b), np.asarray(idx_b),
                              -1).astype(np.int32)
        return tokens, positions, masks, mlen, wi, row_on, node_idx_b

    def _apply_entries(self, active: Dict[int, _Active],
                       pending: List[int], rows, v_of, d_all) -> None:
        """Scatter one dispatch's results back through ``apply_entry``:
        ``v_of(slot)`` supplies the slot's target verify logits — a row
        of the fused logits (flush/local) or a ``DeferredLogits`` future
        (overlapped)."""
        tokens, positions, masks, _, wi, _, node_idx_b = rows
        for slot in pending:
            entry = EntryInputs(tokens=tokens[slot],
                                positions=positions[slot],
                                mask=masks[slot], write_index=wi[slot],
                                node_idx=node_idx_b[slot])
            self.inner.apply_entry(active[slot].state, entry,
                                   v_of(slot), d_all[slot])

    def _fused_entry(self, active: Dict[int, _Active],
                     pending: List[int]) -> None:
        """Hand the executor ONE bucketed verify per model over the
        stacked entry rows and scatter the logits back through
        ``apply_entry``."""
        rows = self._entry_rows(active, pending)
        tokens, positions, masks, mlen, wi, row_on, _ = rows
        v_all, d_all = self.executor.verify_rows(tokens, positions, masks,
                                                 mlen, wi, row_on)
        self._apply_entries(active, pending, rows,
                            lambda slot: v_all[slot], d_all)

    # -- shared per-timestep phases ------------------------------------
    def _bump(self, active: Dict[int, _Active],
              stepping: List[int]) -> List[int]:
        for slot in stepping:
            st = active[slot].state
            st.t += 1
            st.stats.timesteps = st.t
            st.tree = self.trees.get_row(slot)
        return [s for s in stepping if active[s].state.pending]

    def _pick_exits(self, active: Dict[int, _Active],
                    stepping: List[int]) -> Dict[int, tuple]:
        picks = {}
        for slot in stepping:
            ev = self.inner.exit_pick(active[slot].state)
            if ev is not None:
                picks[slot] = ev
        return picks

    def _commit_exits(self, active: Dict[int, _Active], picks) -> None:
        """ONE batched two-level cache sync over every exiting slot."""
        if not picks:
            return
        mask_rows = np.zeros((self.max_slots,), bool)
        mlen_rows = np.zeros((self.max_slots,), np.int32)
        for slot in picks:
            mask_rows[slot] = True
            mlen_rows[slot] = active[slot].state.model_len
        self.executor.commit_rows(jnp.asarray(mlen_rows),
                                  jnp.asarray(mask_rows))

    def _apply_exits(self, active: Dict[int, _Active], stepping: List[int],
                     picks, *, kill_stale: bool = False) -> None:
        """Per-slot exit bookkeeping (token select, prune, flight remap),
        then ONE batched tree prune/remap over every pruned slot
        (``executor.remap_rows``; identity rows for the rest).  With
        ``kill_stale`` (overlapped backend) a miss additionally kills the
        slot's in-flight ring layers — the pruning-propagation stage."""
        remaps: Dict[int, np.ndarray] = {}
        for slot in stepping:
            st = active[slot].state
            commits = 0
            if slot in picks:
                fl, root_row = picks[slot]
                misses0 = st.stats.misses
                commits = self.inner.exit_apply(
                    st, fl, root_row,
                    commit_caches=lambda _st: None,  # batched above
                    remap_caches=lambda _st, imap, s=slot:
                        remaps.__setitem__(s, imap))
                if kill_stale and st.stats.misses > misses0:
                    self.executor.kill(slot)
            st.stats.commits_per_step.append(commits)
            self.trees.set_row(slot, st.tree)
            st.tree = None
        if remaps:
            imaps = np.tile(np.arange(self.pcfg.capacity, dtype=np.int32),
                            (self.max_slots, 1))
            row_mask = np.zeros((self.max_slots,), bool)
            for slot, imap in remaps.items():
                imaps[slot] = np.asarray(imap, np.int32)
                row_mask[slot] = True
            self.executor.remap_rows(imaps, row_mask)

    # ------------------------------------------------------------------
    def _advance_fused(self, active: Dict[int, _Active],
                       stepping: List[int]) -> None:
        """One shared pipeline timestep over all stepping slots: gather
        entries → ONE fused verify per model → per-slot expansion →
        batched commit → batched prune/remap."""
        # phase 1: stacked gather-entry, ONE fused verify per model (the
        # pending flag alone decides participation — the entry inputs come
        # from the stacked TreeBatch views, not a per-slot gather)
        pending = self._bump(active, stepping)
        if pending:
            self._fused_entry(active, pending)
        self.stats.verify_dispatches.append(1 if pending else 0)

        # expansion per slot (tree ops only; may defer at the caps)
        for slot in stepping:
            self.inner.maybe_expand(active[slot].state)

        # phase 2: exit — batched commit, then batched prune/remap
        picks = self._pick_exits(active, stepping)
        self._commit_exits(active, picks)
        self._apply_exits(active, stepping, picks)

    # ------------------------------------------------------------------
    def _advance_overlapped(self, active: Dict[int, _Active],
                            stepping: List[int]) -> None:
        """One steady-state timestep: ONE ring tick interleaves the entry
        for timestep t with the exit for timestep t - (n_stages - 1).

        The tick always dispatches (the in-flight layers must advance a
        stage whether or not anything enters); entering slots receive
        ``DeferredLogits`` futures that this same tick resolves for the
        layers exiting NOW, so ``exit_apply`` consumes logits delivered
        at exit time.  Misses/retires kill the slot's in-flight layers
        in-ring; commits and prune maps are queued as the next tick's
        ctrl message, trailing the in-flight layers stage by stage."""
        pending = self._bump(active, stepping)
        if pending:
            rows = self._entry_rows(active, pending)
        else:
            rows = (*self.executor.dead_entry,
                    np.zeros((self.max_slots,), bool), None)
        tokens, positions, masks, mlen, wi, row_on, _ = rows

        # phase 1: ONE ring tick — entry for t in, exit for
        # t - (n_stages - 1) out
        d_all, handles = self.executor.tick_rows(tokens, positions, masks,
                                                 mlen, wi, row_on)
        self.stats.verify_dispatches.append(1 if pending else 0)
        self.stats.tick_dispatches.append(1)
        self._apply_entries(active, pending, rows,
                            lambda slot: handles[slot], d_all)

        for slot in stepping:
            self.inner.maybe_expand(active[slot].state)

        # phase 2: exit — this tick's resolved futures; cache sync rides
        # the NEXT tick's ctrl (draft applies immediately)
        picks = self._pick_exits(active, stepping)
        self._commit_exits(active, picks)
        self._apply_exits(active, stepping, picks, kill_stale=True)

    # ------------------------------------------------------------------
    def _stream(self, active: Dict[int, _Active], now: int,
                on_token: Optional[Callable]) -> None:
        """Emit every not-yet-streamed committed token as
        ``on_token(uid, token, timestep)`` (bounded by the request's
        token budget, mirroring ``DecodeState.output``)."""
        if on_token is None:
            return
        for a in active.values():
            limit = 1 + a.state.max_new_tokens
            fresh = a.state.committed[a.emitted:limit]
            for tok in fresh:
                on_token(a.req.uid, int(tok), now)
            a.emitted += len(fresh)

    # ------------------------------------------------------------------
    def run(self, key: Optional[jax.Array] = None,
            on_token: Optional[Callable] = None):
        """Drive the shared pipeline schedule until queue and slots drain.
        Returns {uid: Result} (same shape as ``ServingEngine.run``).
        ``on_token(uid, token, timestep)`` streams tokens at commit time."""
        from repro.serving.engine import Result

        base_key = key if key is not None else jax.random.PRNGKey(0)
        self.stats = DBStats()  # per-run aggregates (scheduler stats persist)
        results: Dict[int, Result] = {}
        active: Dict[int, _Active] = {}
        joining: Dict[int, _Joining] = {}
        ring_prefill = self.overlapped and \
            getattr(self.executor, "prefill_cap", 0) > 0
        guard = self._timestep_guard()
        now = 0

        while self.sched.pending or active or joining:
            if not active and not joining:
                # pipeline drained; fast-forward to the next arrival
                nxt = self.sched.next_arrival()
                if nxt is not None and nxt > now:
                    now = nxt

            # 0. join: requests whose in-ring admission prefill resolved
            # (its last tick exited the prompt's final hidden state) seed
            # their DecodeState from the resolved logits and go active —
            # the same init_state path, with the prefill already done
            for slot in [s for s in sorted(joining)
                         if joining[s].handle.ready]:
                j = joining.pop(slot)
                st = self.inner.init_state(
                    j.req.prompt, j.req.max_new_tokens, key=j.key,
                    eos=self.eos_token,
                    sampling=getattr(j.req, "sampling", None),
                    prefill_fn=lambda _p, h=j.handle: h.resolve())
                self.trees.adopt_row(slot, st.tree)
                st.tree = None
                active[slot] = _Active(j.req, st, j.t0)

            # 1. refill: join-on-prefill for arrived requests.  On the
            # overlapped backend the prefill enters the ring inside the
            # NEXT tick dispatch (prefill-in-ring: no separate dispatch,
            # no idle timestep) and the request parks in ``joining``
            # until its prompt exits the pipeline; other backends (and
            # prompts longer than the ring's prefill lane) prefill
            # through the executor immediately
            for req, slot in self.sched.admit(now):
                rkey = jax.random.fold_in(base_key, req.uid)
                sampling = getattr(req, "sampling", None)
                if ring_prefill:
                    h = self.executor.begin_prefill(slot, req.prompt)
                    if h is not None:
                        joining[slot] = _Joining(req, rkey, h,
                                                 time.perf_counter())
                        continue
                if self.fused:
                    self.stats.separate_prefill_dispatches += 1
                    st = self.inner.init_state(
                        req.prompt, req.max_new_tokens, key=rkey,
                        eos=self.eos_token, sampling=sampling,
                        prefill_fn=functools.partial(
                            self.executor.prefill, slot))
                else:
                    st = self.inner.init_state(
                        req.prompt, req.max_new_tokens, key=rkey,
                        caches=self.arena.caches(slot), eos=self.eos_token,
                        sampling=sampling)
                self.trees.adopt_row(slot, st.tree)
                st.tree = None  # canonical copy lives in the TreeBatch
                active[slot] = _Active(req, st, time.perf_counter())
            self._stream(active, now, on_token)   # prefill (first) tokens

            # 2. advance: every active request shares this timestep
            now += 1
            self.stats.timesteps += 1
            stepping = [s for s in sorted(active)
                        if not active[s].state.done]
            if self.overlapped:
                self._advance_overlapped(active, stepping)
            elif self.fused:
                self._advance_fused(active, stepping)
            else:
                for slot in stepping:
                    st = active[slot].state
                    st.tree = self.trees.get_row(slot)
                    self.inner.step(st)
                    self.trees.set_row(slot, st.tree)
                    st.tree = None
            self._stream(active, now, on_token)   # this timestep's commits

            # 3. retire: free slots for the next refill (fused mode: the
            # slot's caches already live in the executor's arena)
            for slot in [s for s, a in active.items() if a.state.done]:
                a = active.pop(slot)
                st = a.state
                results[a.req.uid] = Result(
                    a.req.uid, st.output(),
                    time.perf_counter() - a.t0, st.stats)
                self.stats.per_request[a.req.uid] = st.stats
                self.stats.total_commits += st.stats.commits
                self.stats.record_acceptance(a.req.uid, st.stats)
                self.trees.release_row(slot)
                if self.overlapped:
                    # kill the retired request's in-flight ring layers and
                    # cancel its queued ctrl — the slot is being recycled
                    self.executor.kill(slot, drop_ctrl=True)
                self.sched.retire(
                    a.req.uid, slot, now,
                    caches=None if self.fused else st.caches())

            occ = len(active)
            self.stats.occupancy.append(occ)
            self.sched.stats.occupancy.append(occ)
            pages = getattr(self.arena, "pages", None)
            if pages is not None:
                self.stats.page_counters.append(pages.counters())
            if now > guard:
                raise RuntimeError(
                    f"SpecPipeDBEngine exceeded timestep guard ({guard}); "
                    f"{len(active)} active, {self.sched.pending} queued")
        if self.overlapped:
            # every live flight resolved during the run (retires killed the
            # rest), so this is a no-op safety valve that leaves the
            # executor's ring clean for the next run
            self.executor.drain()
        return results


def generate_with_executor(target: ModelBundle, draft: ModelBundle,
                           pcfg: PipeDecConfig, prompt, max_new_tokens: int,
                           *, executor: Optional[PipelineExecutor] = None,
                           max_len: int = 512,
                           eos: Optional[int] = None,
                           key: Optional[jax.Array] = None,
                           sampling=None):
    """The B=1 PipeDec path on a pluggable compute backend: one request
    through a single-slot ``SpecPipeDBEngine`` (the single-request engine
    is literally the B=1 case of the DB schedule, so the output token
    sequence bit-matches ``PipeDecEngine.generate`` under greedy
    decoding).  Returns (tokens, GenStats)."""
    from repro.serving.engine import Request

    eng = SpecPipeDBEngine(target, draft, pcfg, max_len=max_len,
                           max_slots=1, eos_token=eos, executor=executor)
    eng.submit(Request(0, np.asarray(prompt), max_new_tokens,
                       sampling=sampling))
    res = eng.run(key=key)[0]
    return res.tokens, res.stats
