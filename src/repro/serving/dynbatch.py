"""SpecPipe-DB: continuous-batching multi-request PipeDec engine.

The single-request engine (``core.pipedec``) gives the lowest latency but
leaves the pipeline idle whenever one task stalls; the paper's DB mode
keeps several requests' speculative token trees in flight at once — their
tree layers share every pipeline timestep (stacked along the batch axis in
each stage) and finished requests are replaced from the queue without
draining the pipeline (§ dynamic batching; 1.64–2.08× vLLM throughput in
the paper's Table).

Logical model (wall-clock is priced in ``core.sim.specpipe_db_*``): one
*global* timestep advances every active request by one ``PipeDecEngine``
timestep — entry + proposal, then exit + commit — using per-request state
(``DecodeState``), trees stacked in a ``core.dynbatch.TreeBatch``, and KV
arenas handed out by ``serving.scheduler.KVArena``.  Each request's
operation trace is identical to running it alone through
``PipeDecEngine.generate``, so DB output is bit-equal per request
(tests/test_serving_db.py pins this); only *when* layers run changes, never
*what* is computed — the same argument the paper makes for losslessness.

Scheduling per global timestep:
  1. refill — admit arrived requests (FIFO) onto free KV slots, running
     their prefill (join-on-prefill);
  2. advance — step every active request's entry/exit phases;
  3. retire — requests that hit eos or their token budget release their
     slot (retire-on-eos) for the next refill.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax

from repro.core.dynbatch import TreeBatch
from repro.core.pipedec import (DecodeState, GenStats, PipeDecConfig,
                                PipeDecEngine)
from repro.core.speculative import ModelBundle
from repro.serving.scheduler import DynamicBatchScheduler, KVArena


@dataclasses.dataclass
class _Active:
    req: object
    state: DecodeState
    t0: float


@dataclasses.dataclass
class DBStats:
    """Aggregate engine statistics for one ``run()``.

    ``timesteps`` counts *executed* shared pipeline timesteps (idle gaps
    between sparse arrivals are fast-forwarded, not counted), so
    ``tokens_per_timestep`` prices what the pipeline does while busy and
    aligns 1:1 with the ``occupancy`` trace.
    """
    timesteps: int = 0
    total_commits: int = 0
    per_request: Dict[int, GenStats] = dataclasses.field(default_factory=dict)
    occupancy: List[int] = dataclasses.field(default_factory=list)

    @property
    def tokens_per_timestep(self) -> float:
        return self.total_commits / self.timesteps if self.timesteps else 0.0

    @property
    def peak_occupancy(self) -> int:
        return max(self.occupancy) if self.occupancy else 0


class SpecPipeDBEngine:
    """Dynamic-batching PipeDec: submit ``Request``s, then ``run()``."""

    def __init__(self, target: ModelBundle, draft: ModelBundle,
                 pcfg: Optional[PipeDecConfig] = None, *,
                 max_len: int = 512, max_slots: int = 4,
                 eos_token: Optional[int] = None):
        self.pcfg = pcfg or PipeDecConfig()
        self.inner = PipeDecEngine(target, draft, self.pcfg, max_len=max_len)
        self.arena = KVArena(
            target, draft, slots=max_slots, max_len=max_len,
            tree_capacity=self.inner.tree_buffer_capacity)
        self.sched = DynamicBatchScheduler(self.arena)
        self.trees = TreeBatch(max_slots, self.pcfg.capacity)
        self.max_slots = max_slots
        self.eos_token = eos_token
        self.stats = DBStats()

    def submit(self, req) -> None:
        """Queue a request (``arrival_t`` is in global pipeline timesteps;
        requests join once arrived AND a KV slot is free)."""
        self.sched.submit(req)

    # ------------------------------------------------------------------
    def _timestep_guard(self) -> int:
        per_req = sum(
            r.max_new_tokens * (self.pcfg.n_stages + 2) + 17
            for r in self.sched.queue)
        arrivals = max((getattr(r, "arrival_t", 0)
                        for r in self.sched.queue), default=0)
        return 64 + arrivals + per_req

    def run(self, key: Optional[jax.Array] = None):
        """Drive the shared pipeline schedule until queue and slots drain.
        Returns {uid: Result} (same shape as ``ServingEngine.run``)."""
        from repro.serving.engine import Result

        base_key = key if key is not None else jax.random.PRNGKey(0)
        self.stats = DBStats()  # per-run aggregates (scheduler stats persist)
        results: Dict[int, Result] = {}
        active: Dict[int, _Active] = {}
        guard = self._timestep_guard()
        now = 0

        while self.sched.pending or active:
            if not active:
                # pipeline drained; fast-forward to the next arrival
                nxt = self.sched.next_arrival()
                if nxt is not None and nxt > now:
                    now = nxt

            # 1. refill: join-on-prefill for arrived requests
            for req, slot in self.sched.admit(now):
                rkey = jax.random.fold_in(base_key, req.uid)
                st = self.inner.init_state(
                    req.prompt, req.max_new_tokens, key=rkey,
                    caches=self.arena.caches(slot), eos=self.eos_token)
                self.trees.adopt_row(slot, st.tree)
                st.tree = None  # canonical copy lives in the TreeBatch
                active[slot] = _Active(req, st, time.perf_counter())

            # 2. advance: every active request shares this timestep
            now += 1
            self.stats.timesteps += 1
            for slot in sorted(active):
                st = active[slot].state
                if st.done:   # finished at admission (eos-on-first, 0 budget)
                    continue
                st.tree = self.trees.get_row(slot)
                self.inner.step(st)
                self.trees.set_row(slot, st.tree)
                st.tree = None

            # 3. retire: free slots for the next refill
            for slot in [s for s, a in active.items() if a.state.done]:
                a = active.pop(slot)
                st = a.state
                results[a.req.uid] = Result(
                    a.req.uid, st.output(),
                    time.perf_counter() - a.t0, st.stats)
                self.stats.per_request[a.req.uid] = st.stats
                self.stats.total_commits += st.stats.commits
                self.trees.release_row(slot)
                self.sched.retire(a.req.uid, slot, now, caches=st.caches())

            occ = len(active)
            self.stats.occupancy.append(occ)
            self.sched.stats.occupancy.append(occ)
            if now > guard:
                raise RuntimeError(
                    f"SpecPipeDBEngine exceeded timestep guard ({guard}); "
                    f"{len(active)} active, {self.sched.pending} queued")
        return results
