"""Pluggable compute backends for SpecPipe-DB — the executor seam.

The logical scheduler (``serving.dynbatch.SpecPipeDBEngine`` multiplexing
``core.pipedec.PipeDecEngine`` state machines) decides *what* every request
computes; a ``PipelineExecutor`` decides *where and how* the per-timestep
batched work runs.  The seam is exactly the three fused dispatches a global
timestep needs, plus admission prefill:

  * ``verify_rows``  — ONE batched tree-verify per model over every active
    slot's deepest tree layer (per-row ``model_len`` / ``tree_write_index``
    / ``tree_mask [B, n, Tcap]``);
  * ``commit_rows``  — the batched two-level cache sync at exit (tree-row 0
    of every exiting slot migrates into its model cache at ``model_len``);
  * ``remap_row``    — post-prune tree-cache compaction of one slot;
  * ``prefill``      — join-on-prefill of an admitted request into its slot.

The executor owns the cache storage (the engine's states carry no cache
pytrees) and the power-of-two slot-count bucketing policy, so every
backend stays recompile-free: a dispatch covers the smallest power-of-two
prefix of slot rows spanning every active slot — at most log2(slots)+1
shapes per model.

Backends:

  * ``LocalFusedExecutor`` — PR-2's fused single-device path unchanged:
    slot-stacked ``KVArena`` pytrees, ``ModelBundle.tree_verify_rows`` /
    ``commit_rows`` dispatches.
  * ``ShardedPipelineExecutor`` — the paper's pipelined deployment: the
    target's layer stack is partitioned over an ``n_stages``-device mesh
    (``launch.pipeline``), stage caches carry a leading slot axis
    mirroring the KV arena, and each timestep's verify is ONE compiled
    dispatch that flushes the batched entry layer around the ``ppermute``
    activation ring (``launch.pipeline.make_pipeline_verify``).  The
    draft runs replicated next to stage 0 (it proposes the next layer the
    same timestep, so it cannot ride the ring).  Because the flush keeps
    verify logits available at the entry timestep, the logical schedule —
    and therefore every request's token output — is bit-identical to the
    local backend; steady-state overlap is the wall-clock model
    (``core.sim.specpipe_db_sharded_*``).

Both backends expose ``calls`` (a Counter) as the dispatch-count hook: the
equivalence tests assert ``calls["verify_rows"]`` == one batched dispatch
per global timestep with pending entries.
"""
from __future__ import annotations

import collections
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.speculative import ModelBundle, remap_tree_caches
from repro.launch import pipeline as pl
from repro.models import transformer as tf
from repro.models.layers import embed
from repro.serving.scheduler import KVArena, SlotPool


class PipelineExecutor:
    """Backend interface + the shared slot-count bucketing policy.

    Subclasses implement ``prefill`` / ``verify_rows`` / ``commit_rows`` /
    ``remap_row`` against their own cache storage and expose ``arena``
    (a ``SlotPool``) for the scheduler's slot accounting."""

    slots: int
    arena: SlotPool

    def __init__(self, slots: int):
        self.slots = slots
        self.calls = collections.Counter()

    def _bucket(self, rows: int) -> int:
        """Smallest power-of-two prefix of slot rows spanning every row
        that must participate (capped at ``slots``)."""
        b = 1
        while b < rows:
            b *= 2
        return min(b, self.slots)

    # -- interface -----------------------------------------------------
    def prefill(self, slot: int, prompt):
        """Fill both models' caches for ``slot`` from a [1, len] prompt;
        returns the target's last-position logits [1, V]."""
        raise NotImplementedError

    def verify_rows(self, tokens, positions, masks, model_len, write_idx,
                    row_on):
        """ONE fused tree-verify per model over the bucketed prefix of
        slot rows.  All inputs span the full slot axis ([slots, ...]);
        returns (target logits [nb, w, V], draft logits [nb, w, V])."""
        raise NotImplementedError

    def commit_rows(self, model_len, commit_mask) -> None:
        """Batched two-level cache sync: every row with ``commit_mask``
        True migrates its tree-buffer row 0 into its model cache at its
        own ``model_len``; masked rows stay bit-unchanged."""
        raise NotImplementedError

    def remap_row(self, slot: int, index_map) -> None:
        """Post-prune tree-cache compaction on one slot's rows."""
        raise NotImplementedError


class LocalFusedExecutor(PipelineExecutor):
    """PR-2's fused single-device path behind the executor seam: the
    slot-stacked ``KVArena`` is the storage, ``ModelBundle``'s jitted
    ``tree_verify_rows`` / ``commit_rows`` closures are the dispatches."""

    def __init__(self, target: ModelBundle, draft: ModelBundle, *,
                 slots: int, max_len: int, tree_capacity: int,
                 capacity: int):
        super().__init__(slots)
        self.target, self.draft = target, draft
        self.capacity = capacity
        self.arena = KVArena(target, draft, slots=slots, max_len=max_len,
                             tree_capacity=tree_capacity)

    def prefill(self, slot: int, prompt):
        t_cache, d_cache, t_tree, d_tree = self.arena.caches(slot)
        t_logits, t_cache = self.target.prefill(prompt, t_cache)
        _, d_cache = self.draft.prefill(prompt, d_cache)
        self.arena.store(slot, (t_cache, d_cache, t_tree, d_tree))
        return t_logits

    def verify_rows(self, tokens, positions, masks, model_len, write_idx,
                    row_on):
        nb = self._bucket(int(np.max(np.nonzero(np.asarray(row_on))[0])) + 1)
        sl = lambda a: a[:nb]
        t_cache, d_cache, t_tree, d_tree = self.arena.stacked
        v_all, t_tree = self.target.tree_verify_rows(
            sl(tokens), sl(positions), sl(masks), t_cache, sl(model_len),
            t_tree, sl(write_idx), bucket=nb)
        d_all, d_tree = self.draft.tree_verify_rows(
            sl(tokens), sl(positions), sl(masks), d_cache, sl(model_len),
            d_tree, sl(write_idx), bucket=nb)
        self.arena.set_tree_caches(t_tree, d_tree)
        self.calls["verify_rows"] += 1
        return v_all, d_all

    def commit_rows(self, model_len, commit_mask) -> None:
        node0 = jnp.zeros((self.slots,), jnp.int32)  # row 0 is the root
        t_cache, d_cache, t_tree, d_tree = self.arena.stacked
        t_cache = self.target.commit_rows(t_cache, t_tree, node0, model_len,
                                          commit_mask)
        d_cache = self.draft.commit_rows(d_cache, d_tree, node0, model_len,
                                         commit_mask)
        self.arena.set_model_caches(t_cache, d_cache)
        self.calls["commit_rows"] += 1

    def remap_row(self, slot: int, index_map) -> None:
        _, _, t_tree, d_tree = self.arena.stacked
        t_row = remap_tree_caches(tf.slice_cache_rows(t_tree, slot, 1),
                                  index_map, self.capacity)
        d_row = remap_tree_caches(tf.slice_cache_rows(d_tree, slot, 1),
                                  index_map, self.capacity)
        self.arena.set_tree_caches(
            tf.update_cache_rows(t_tree, t_row, slot),
            tf.update_cache_rows(d_tree, d_row, slot))


def _sharded_verify_impl(params, stage_p, stage_valid, model_kv, tree_kv,
                         node_tokens, node_positions, tree_mask, write_idx,
                         model_len, row_on, *, bucket, cfg, verify_pass):
    """ONE compiled dispatch: embed the bucketed entry rows, flush them
    through every pipeline stage (``make_pipeline_verify``), unembed the
    exiting activations, scatter the updated tree-cache rows back.
    ``params`` carries only the embed/final-norm/unembed leaves (the layer
    stack already rides in ``stage_p``)."""
    sl = lambda a: a[:bucket]
    rows = lambda c: jax.tree.map(lambda t: t[:, :bucket], c)
    mkv_b = [rows(c) for c in model_kv]
    tkv_b = [rows(c) for c in tree_kv]
    entry = {
        "act": embed(params["embed"], sl(node_tokens)),
        "positions": sl(node_positions),
        "mask": sl(tree_mask),
        "write_idx": sl(write_idx),
        "model_len": sl(model_len),
        "valid": sl(row_on),
    }
    exit_act, _, tkv_b = verify_pass(stage_p, stage_valid, mkv_b, tkv_b,
                                     entry)
    logits = tf._logits(params, cfg, exit_act)
    new_tree_kv = [
        jax.tree.map(
            lambda full, upd: jax.lax.dynamic_update_slice_in_dim(
                full, upd.astype(full.dtype), 0, axis=1), full_c, upd_c)
        for full_c, upd_c in zip(tree_kv, tkv_b)]
    return logits, new_tree_kv


class ShardedPipelineExecutor(PipelineExecutor):
    """SpecPipe-DB on the sharded ``launch.pipeline`` deployment.

    The target's uniform layer stack is partitioned over the mesh's
    "model" axis (``n_stages`` devices, ``stage_params`` layout); its
    model + tree KV live in stage-layout arenas — lists (per in-stage
    layer) of [S, slots, rows, ...] buffers, the leading slot dim
    mirroring the slot-stacked ``KVArena``.  Each global timestep issues
    exactly ONE sharded dispatch (``calls["pipeline_verify"]``): the
    batched entry layer rides the ``ppermute`` activation ring through
    all stages with its per-row metadata frozen at entry, and the exiting
    hidden states are unembedded into the verify logits.  The draft model
    (small, replicated) verifies/proposes through the same local fused
    dispatch the ``LocalFusedExecutor`` uses.
    """

    def __init__(self, target: ModelBundle, draft: ModelBundle, *,
                 slots: int, max_len: int, tree_capacity: int,
                 capacity: int, n_stages: Optional[int] = None, mesh=None,
                 dtype=jnp.float32):
        super().__init__(slots)
        self.target, self.draft = target, draft
        self.capacity, self.max_len = capacity, max_len
        width = tree_capacity - capacity
        assert width >= 1, "tree_capacity must include the width-w slack"
        if mesh is None:
            n = n_stages or len(jax.devices())
            mesh = jax.make_mesh((1, n), ("data", "model"))
        self.mesh = mesh
        self.n_stages = mesh.shape["model"]
        assert n_stages is None or n_stages == self.n_stages, \
            "n_stages must equal the mesh's 'model' axis size"
        self.plcfg = pl.PipelineConfig(
            n_stages=self.n_stages, width=width, tree_capacity=capacity,
            max_len=max_len)
        self.lps, self._padded = pl.stage_layout(target.cfg, self.n_stages)
        self.stage_p, self.stage_valid = pl.stage_params(
            target.cfg, target.params, self.n_stages)
        self.model_kv, self.tree_kv = pl.init_stage_caches(
            target.cfg, self.plcfg, dtype, batch=slots)
        self._d_cache = draft.init_cache(slots, max_len)
        self._d_tree = draft.init_tree_caches(slots, tree_capacity)
        self.arena = SlotPool(slots)

        # only the embed table + final norm + unembed head ride the
        # per-timestep dispatch — the layer stack is already duplicated
        # into the stage-sharded ``stage_p`` layout
        self._head_params = {
            k: target.params[k] for k in ("embed", "final_norm", "lm_head")
            if k in target.params}
        verify_pass = pl.make_pipeline_verify(target.cfg, self.plcfg, mesh,
                                              dtype)
        self._verify = jax.jit(functools.partial(
            _sharded_verify_impl, cfg=target.cfg, verify_pass=verify_pass),
            static_argnames=("bucket",))
        self._commit = jax.jit(functools.partial(self._commit_impl,
                                                 cfg=target.cfg))

    # -- target stage-arena plumbing ------------------------------------
    @staticmethod
    def _commit_impl(model_kv, tree_kv, node_idx, model_len, commit_mask,
                     *, cfg):
        return [tf.commit_tree_nodes(cfg, mkv, tkv, node_idx, model_len,
                                     commit_mask)
                for mkv, tkv in zip(model_kv, tree_kv)]

    def _scatter_prefill(self, stacked_cache, slot: int) -> None:
        """Scatter a freshly prefilled stacked-layout model cache
        ([reps, 1, rows, ...] per unit sub-layer) into the stage arena at
        ``slot`` — layer ``s*lps + l`` lands in stage ``s``, in-stage
        index ``l`` (the ``stage_params`` layout)."""
        reps = tf.layout(self.target.cfg)[1]
        pad = self._padded - reps

        def scatter(l):
            def f(dst, src):
                src = src[:, 0]                       # [reps, rows, ...]
                if pad:
                    src = jnp.concatenate(
                        [src, jnp.zeros((pad, *src.shape[1:]), src.dtype)],
                        0)
                src = src.reshape(self.n_stages, self.lps,
                                  *src.shape[1:])[:, l]  # [S, rows, ...]
                return jax.lax.dynamic_update_slice_in_dim(
                    dst, src[:, None].astype(dst.dtype), slot, axis=1)
            return jax.tree.map(f, self.model_kv[l], stacked_cache)

        self.model_kv = [scatter(l) for l in range(self.lps)]

    # -- interface ------------------------------------------------------
    def prefill(self, slot: int, prompt):
        t_cache = self.target.init_cache(1, self.max_len)
        t_logits, t_cache = self.target.prefill(prompt, t_cache)
        # the pure-stack arch has exactly one attention sub-layer per unit
        self._scatter_prefill(t_cache["stack"][0], slot)
        d_row = tf.slice_cache_rows(self._d_cache, slot, 1)
        _, d_row = self.draft.prefill(prompt, d_row)
        self._d_cache = tf.update_cache_rows(self._d_cache, d_row, slot)
        return t_logits

    def verify_rows(self, tokens, positions, masks, model_len, write_idx,
                    row_on):
        nb = self._bucket(int(np.max(np.nonzero(np.asarray(row_on))[0])) + 1)
        v_all, self.tree_kv = self._verify(
            self._head_params, self.stage_p, self.stage_valid,
            self.model_kv, self.tree_kv, tokens, positions, masks,
            write_idx, model_len, jnp.asarray(np.asarray(row_on)),
            bucket=nb)
        sl = lambda a: a[:nb]
        d_all, self._d_tree = self.draft.tree_verify_rows(
            sl(tokens), sl(positions), sl(masks), self._d_cache,
            sl(model_len), self._d_tree, sl(write_idx), bucket=nb)
        self.calls["verify_rows"] += 1
        self.calls["pipeline_verify"] += 1
        return v_all, d_all

    def commit_rows(self, model_len, commit_mask) -> None:
        node0 = jnp.zeros((self.slots,), jnp.int32)
        self.model_kv = self._commit(self.model_kv, self.tree_kv, node0,
                                     model_len, commit_mask)
        self._d_cache = self.draft.commit_rows(
            self._d_cache, self._d_tree, node0, model_len, commit_mask)
        self.calls["commit_rows"] += 1

    def remap_row(self, slot: int, index_map) -> None:
        def one(c):
            row = jax.tree.map(lambda t: t[:, slot:slot + 1], c)
            row = remap_tree_caches(row, index_map, self.capacity)
            return jax.tree.map(
                lambda full, r: full.at[:, slot:slot + 1].set(
                    r.astype(full.dtype)), c, row)

        self.tree_kv = [one(c) for c in self.tree_kv]
        d_row = remap_tree_caches(
            tf.slice_cache_rows(self._d_tree, slot, 1), index_map,
            self.capacity)
        self._d_tree = tf.update_cache_rows(self._d_tree, d_row, slot)
