"""Pluggable compute backends for SpecPipe-DB — the executor seam.

The logical scheduler (``serving.dynbatch.SpecPipeDBEngine`` multiplexing
``core.pipedec.PipeDecEngine`` state machines) decides *what* every request
computes; a ``PipelineExecutor`` decides *where and how* the per-timestep
batched work runs.  The seam is exactly the three fused dispatches a global
timestep needs, plus admission prefill:

  * ``verify_rows``  — ONE batched tree-verify per model over every active
    slot's deepest tree layer (per-row ``model_len`` / ``tree_write_index``
    / ``tree_mask [B, n, Tcap]``);
  * ``commit_rows``  — the batched two-level cache sync at exit (tree-row 0
    of every exiting slot migrates into its model cache at ``model_len``);
  * ``remap_row``    — post-prune tree-cache compaction of one slot;
  * ``prefill``      — join-on-prefill of an admitted request into its slot.

The executor owns the cache storage (the engine's states carry no cache
pytrees) and the power-of-two slot-count bucketing policy, so every
backend stays recompile-free: a dispatch covers the smallest power-of-two
prefix of slot rows spanning every active slot — at most log2(slots)+1
shapes per model.

Backends:

  * ``LocalFusedExecutor`` — PR-2's fused single-device path unchanged:
    slot-stacked ``KVArena`` pytrees, ``ModelBundle.tree_verify_rows`` /
    ``commit_rows`` dispatches.
  * ``ShardedPipelineExecutor`` — the paper's pipelined deployment, FLUSH
    schedule: the target's layer stack is partitioned over an
    ``n_stages``-device mesh (``launch.pipeline``), stage caches carry a
    leading slot axis mirroring the KV arena, and each timestep's verify
    is ONE compiled dispatch that flushes the batched entry layer around
    the ``ppermute`` activation ring (``n_stages`` hops;
    ``launch.pipeline.make_pipeline_verify``).  The draft runs replicated
    next to stage 0 (it proposes the next layer the same timestep, so it
    cannot ride the ring).  Because the flush keeps verify logits
    available at the entry timestep, the logical schedule — and therefore
    every request's token output — is bit-identical to the local backend.
  * ``OverlappedShardedExecutor`` — the same deployment in the paper's
    steady-state wall-clock regime: the ring *persists* across timesteps
    and stays full, so each global timestep is ONE tick (one stage-hop)
    instead of an ``n_stages``-hop flush — the ``flush=False`` pricing of
    ``core.sim.specpipe_db_sharded_*``, measured.  Verify logits only
    exist when a layer exits (``exit_t = t + n_stages - 1``), so
    ``verify_rows``/``tick_rows`` return *deferred* ``DeferredLogits``
    futures that the engine stores in its ``Flight``s and resolves at
    exit; exit commits and prune compactions enter the ring as a ctrl
    message trailing the in-flight layers (pruning propagation), misses
    and retirements ``kill`` the slot's in-flight layers in-ring and bump
    its tree version.  Committed tokens are bit-identical to the flush
    backend — only *when* logits materialise changes, never what is
    computed.

  * ``AsyncPipelineExecutor`` — the same schedule with the host lockstep
    BROKEN: every stage is a free-running actor thread on its own device
    pulling ring layers from a bounded inbox, applying the per-stage
    step factored out of the lockstep tick
    (``launch.pipeline.make_stage_fns``), and pushing to the next
    stage's inbox — a fast stage never waits on a slow one and the
    per-stage queue depth is uneven.  The draft is *disaggregated* onto
    a dedicated actor that speculates against the committed prefix in
    engine push order, feeding the dynamic token tree ahead of
    verification; kill/version messages short-circuit stale in-flight
    layers at whatever stage they sit instead of riding a full
    revolution.  Per-slot FIFO message order reproduces the lockstep
    schedule's per-stage arrival order exactly, so greedy outputs stay
    bit-identical — only WHEN each stage runs changes.

All backends expose ``calls`` (a Counter) as the dispatch-count hook: the
equivalence tests assert ``calls["verify_rows"]`` == one batched dispatch
per global timestep with pending entries (flush/local), and
``calls["pipeline_tick"]`` == one ring tick per executed global timestep
(overlapped); the async backend counts entry/ctrl *messages* and
per-stage steps instead (``calls["entry_msgs"]`` / ``calls["ctrl_msgs"]``
/ ``calls["stage_steps"]``).
"""
from __future__ import annotations

import collections
import functools
import queue
import threading
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.speculative import ModelBundle, remap_tree_caches
from repro.launch import pipeline as pl
from repro.models import paging
from repro.models import transformer as tf
from repro.models.layers import embed
from repro.serving.scheduler import KVArena, PagedKVArena, SlotPool


def _full_table(slots: int, rows: int, page: int):
    """Fully-backed identity block table: slot ``b``'s logical block ``j``
    is physical block ``1 + b * mb + j`` (block 0 stays the null block).
    The sharded backends page their stage/draft arenas statically — the
    dynamic allocation/swap policies live in ``scheduler.PagedKVArena``
    behind the local backend."""
    mb = paging.n_blocks(rows, page)
    return jnp.asarray(
        1 + np.arange(slots * mb, dtype=np.int32).reshape(slots, mb))


def _paginate_full(cache, table, page: int):
    """Convert every KV leaf of a cache pytree (the
    ``CACHE_LEN_AXIS_FROM_END`` names, incl. int8 scales) to a
    fully-backed ``models.paging.Paged`` buffer sharing ``table``;
    recurrent state and other non-length leaves stay dense."""
    def conv(path, leaf):
        if leaf is None:
            return None
        name = getattr(path[-1], "key", None) if path else None
        if name not in tf.CACHE_LEN_AXIS_FROM_END:
            return leaf
        n_pre = tf.cache_len_axis(name, leaf) - 1
        return paging.make_paged(leaf, table, page, n_pre)

    return jax.tree_util.tree_map_with_path(
        conv, cache, is_leaf=lambda x: x is None)


class PipelineExecutor:
    """Backend interface + the shared slot-count bucketing policy.

    Subclasses implement ``prefill`` / ``verify_rows`` / ``commit_rows`` /
    ``remap_row`` against their own cache storage and expose ``arena``
    (a ``SlotPool``) for the scheduler's slot accounting."""

    slots: int
    arena: SlotPool

    def __init__(self, slots: int):
        self.slots = slots
        self.calls = collections.Counter()

    def _bucket(self, rows: int) -> int:
        """Smallest power-of-two prefix of slot rows spanning every row
        that must participate (capped at ``slots``)."""
        b = 1
        while b < rows:
            b *= 2
        return min(b, self.slots)

    # -- interface -----------------------------------------------------
    def prefill(self, slot: int, prompt):
        """Fill both models' caches for ``slot`` from a [1, len] prompt;
        returns the target's last-position logits [1, V]."""
        raise NotImplementedError

    def verify_rows(self, tokens, positions, masks, model_len, write_idx,
                    row_on):
        """ONE fused tree-verify per model over the bucketed prefix of
        slot rows.  All inputs span the full slot axis ([slots, ...]);
        returns (target logits [nb, w, V], draft logits [nb, w, V])."""
        raise NotImplementedError

    def commit_rows(self, model_len, commit_mask) -> None:
        """Batched two-level cache sync: every row with ``commit_mask``
        True migrates its tree-buffer row 0 into its model cache at its
        own ``model_len``; masked rows stay bit-unchanged."""
        raise NotImplementedError

    def remap_row(self, slot: int, index_map) -> None:
        """Post-prune tree-cache compaction on one slot's rows."""
        raise NotImplementedError

    def _draft_verify(self, tokens, positions, masks, model_len,
                      write_idx, row_on):
        """ONE bucketed draft tree-verify over the entering slot rows
        (shared by every backend: the draft proposes the next layer the
        same timestep, slot-stacked beside stage 0).  Returns the draft
        logits and the updated draft tree caches."""
        nb = self._bucket(int(np.max(np.nonzero(np.asarray(row_on))[0])) + 1)
        sl = lambda a: a[:nb]
        d_all, d_tree = self.draft.tree_verify_rows(
            sl(tokens), sl(positions), sl(masks), self._draft_cache(),
            sl(model_len), self._draft_tree(), sl(write_idx), bucket=nb)
        self.calls["verify_rows"] += 1
        return d_all, d_tree

    def _draft_cache(self):
        raise NotImplementedError

    def _draft_tree(self):
        raise NotImplementedError

    def remap_rows(self, index_maps, row_mask) -> None:
        """Batched exit-phase prune/remap: slot ``b``'s tree caches are
        compacted with ``index_maps[b]`` wherever ``row_mask[b]``
        (``index_maps`` rows for unmasked slots must be identity).  This
        base implementation loops ``remap_row`` over the masked slots —
        kept as the equivalence reference; backends override it with ONE
        batched gather per model (``tf.remap_tree_cache_rows``)."""
        for slot in np.nonzero(np.asarray(row_mask))[0]:
            self.remap_row(int(slot), index_maps[int(slot)])


class LocalFusedExecutor(PipelineExecutor):
    """PR-2's fused single-device path behind the executor seam: the
    slot-stacked ``KVArena`` is the storage, ``ModelBundle``'s jitted
    ``tree_verify_rows`` / ``commit_rows`` closures are the dispatches.

    ``paged=True`` swaps the arena for a ``PagedKVArena``: every KV leaf
    becomes a block pool + per-slot table (``models.paging``), the
    scheduler allocates/swaps/preempts blocks, and the jitted dispatches
    are unchanged — they densify the bucketed views at entry and scatter
    the updated tree rows back through the tables at exit."""

    def __init__(self, target: ModelBundle, draft: ModelBundle, *,
                 slots: int, max_len: int, tree_capacity: int,
                 capacity: int, paged: bool = False, page: int = 16,
                 model_blocks: Optional[int] = None,
                 tree_blocks: Optional[int] = None,
                 lazy_tree: bool = False):
        super().__init__(slots)
        self.target, self.draft = target, draft
        self.capacity = capacity
        self.paged = bool(paged)
        if self.paged:
            self.arena = PagedKVArena(
                target, draft, slots=slots, max_len=max_len,
                tree_capacity=tree_capacity, page=page,
                model_blocks=model_blocks, tree_blocks=tree_blocks,
                lazy_tree=lazy_tree)
        else:
            self.arena = KVArena(target, draft, slots=slots,
                                 max_len=max_len,
                                 tree_capacity=tree_capacity)

    def prefill(self, slot: int, prompt):
        t_cache, d_cache, t_tree, d_tree = self.arena.caches(slot)
        t_logits, t_cache = self.target.prefill(prompt, t_cache)
        _, d_cache = self.draft.prefill(prompt, d_cache)
        self.arena.store(slot, (t_cache, d_cache, t_tree, d_tree))
        return t_logits

    def _draft_cache(self):
        return self.arena.stacked[1]

    def _draft_tree(self):
        return self.arena.stacked[3]

    def verify_rows(self, tokens, positions, masks, model_len, write_idx,
                    row_on):
        nb = self._bucket(int(np.max(np.nonzero(np.asarray(row_on))[0])) + 1)
        sl = lambda a: a[:nb]
        t_cache, _, t_tree, _ = self.arena.stacked
        v_all, t_tree = self.target.tree_verify_rows(
            sl(tokens), sl(positions), sl(masks), t_cache, sl(model_len),
            t_tree, sl(write_idx), bucket=nb)
        d_all, d_tree = self._draft_verify(tokens, positions, masks,
                                           model_len, write_idx, row_on)
        self.arena.set_tree_caches(t_tree, d_tree)
        return v_all, d_all

    def commit_rows(self, model_len, commit_mask) -> None:
        node0 = jnp.zeros((self.slots,), jnp.int32)  # row 0 is the root
        t_cache, d_cache, t_tree, d_tree = self.arena.stacked
        t_cache = self.target.commit_rows(t_cache, t_tree, node0, model_len,
                                          commit_mask)
        d_cache = self.draft.commit_rows(d_cache, d_tree, node0, model_len,
                                         commit_mask)
        self.arena.set_model_caches(t_cache, d_cache)
        self.calls["commit_rows"] += 1

    def remap_row(self, slot: int, index_map) -> None:
        _, _, t_tree, d_tree = self.arena.stacked
        t_row = remap_tree_caches(tf.slice_cache_rows(t_tree, slot, 1),
                                  index_map, self.capacity)
        d_row = remap_tree_caches(tf.slice_cache_rows(d_tree, slot, 1),
                                  index_map, self.capacity)
        self.arena.set_tree_caches(
            tf.update_cache_rows(t_tree, t_row, slot),
            tf.update_cache_rows(d_tree, d_row, slot))

    def remap_rows(self, index_maps, row_mask) -> None:
        """ONE batched gather per model over the slot-stacked arena
        (identity rows leave unmasked slots bit-unchanged)."""
        if not np.any(np.asarray(row_mask)):
            return
        _, _, t_tree, d_tree = self.arena.stacked
        imaps = jnp.asarray(np.asarray(index_maps), jnp.int32)
        self.arena.set_tree_caches(_remap_rows_jit(t_tree, imaps),
                                   _remap_rows_jit(d_tree, imaps))
        self.calls["remap_rows"] += 1


# one compiled batched remap shared by every backend (retraces per cache
# pytree structure, i.e. once per model)
_remap_rows_jit = jax.jit(tf.remap_tree_cache_rows)


def _sharded_verify_impl(params, stage_p, stage_valid, model_kv, tree_kv,
                         node_tokens, node_positions, tree_mask, write_idx,
                         model_len, row_on, *, bucket, cfg, verify_pass):
    """ONE compiled dispatch: embed the bucketed entry rows, flush them
    through every pipeline stage (``make_pipeline_verify``), unembed the
    exiting activations, scatter the updated tree-cache rows back.
    ``params`` carries only the embed/final-norm/unembed leaves (the layer
    stack already rides in ``stage_p``).

    Paged stage arenas gather their bucketed dense views HERE — inside
    this one compiled dispatch but outside the shard_map'd flush (a
    ``Paged`` leaf's pool/table axes do not line up with the tree-mapped
    ``P(stage_axis)`` specs) — and the updated tree rows scatter back
    through the block tables at exit."""
    sl = lambda a: a[:bucket]

    def rows(c):
        return jax.tree_util.tree_map(
            lambda t: (paging.slice_slots(t, 0, bucket)
                       if paging.is_paged(t) else
                       None if t is None else t[:, :bucket]),
            c, is_leaf=lambda x: x is None or paging.is_paged(x))

    mkv_v = [rows(c) for c in model_kv]
    tkv_v = [rows(c) for c in tree_kv]
    entry = {
        "act": embed(params["embed"], sl(node_tokens)),
        "positions": sl(node_positions),
        "mask": sl(tree_mask),
        "write_idx": sl(write_idx),
        "model_len": sl(model_len),
        "valid": sl(row_on),
    }
    exit_act, _, tkv_b = verify_pass(
        stage_p, stage_valid, [paging.densify(c) for c in mkv_v],
        [paging.densify(c) for c in tkv_v], entry)
    logits = tf._logits(params, cfg, exit_act)

    def put_back(full_c, view_c, upd_c):
        def f(full, view, upd):
            if full is None:
                return None
            if paging.is_paged(full):
                return paging.adopt_pool(full, paging.from_dense(view, upd))
            return jax.lax.dynamic_update_slice_in_dim(
                full, upd.astype(full.dtype), 0, axis=1)
        return jax.tree_util.tree_map(
            f, full_c, view_c, upd_c,
            is_leaf=lambda x: x is None or paging.is_paged(x))

    new_tree_kv = [put_back(f, v, u)
                   for f, v, u in zip(tree_kv, tkv_v, tkv_b)]
    return logits, new_tree_kv


class ShardedPipelineExecutor(PipelineExecutor):
    """SpecPipe-DB on the sharded ``launch.pipeline`` deployment.

    The target's uniform layer stack is partitioned over the mesh's
    "model" axis (``n_stages`` devices, ``stage_params`` layout); its
    model + tree KV live in stage-layout arenas — lists (per in-stage
    layer) of [S, slots, rows, ...] buffers, the leading slot dim
    mirroring the slot-stacked ``KVArena``.  Each global timestep issues
    exactly ONE sharded dispatch (``calls["pipeline_verify"]``): the
    batched entry layer rides the ``ppermute`` activation ring through
    all stages with its per-row metadata frozen at entry, and the exiting
    hidden states are unembedded into the verify logits.  The draft model
    (small, replicated) verifies/proposes through the same local fused
    dispatch the ``LocalFusedExecutor`` uses.
    """

    def __init__(self, target: ModelBundle, draft: ModelBundle, *,
                 slots: int, max_len: int, tree_capacity: int,
                 capacity: int, n_stages: Optional[int] = None, mesh=None,
                 dtype=jnp.float32, paged: bool = False, page: int = 16):
        super().__init__(slots)
        self.target, self.draft = target, draft
        self.capacity, self.max_len = capacity, max_len
        self.dtype = dtype
        self.paged, self.page = bool(paged), int(page)
        width = tree_capacity - capacity
        assert width >= 1, "tree_capacity must include the width-w slack"
        if mesh is None:
            n = n_stages or len(jax.devices())
            mesh = jax.make_mesh((1, n), ("data", "model"))
        self.mesh = mesh
        self.n_stages = mesh.shape["model"]
        assert n_stages is None or n_stages == self.n_stages, \
            "n_stages must equal the mesh's 'model' axis size"
        self.plcfg = pl.PipelineConfig(
            n_stages=self.n_stages, width=width, tree_capacity=capacity,
            max_len=max_len)
        self.lps, self._padded = pl.stage_layout(target.cfg, self.n_stages)
        self.stage_p, self.stage_valid = pl.stage_params(
            target.cfg, target.params, self.n_stages)
        self.model_kv, self.tree_kv = pl.init_stage_caches(
            target.cfg, self.plcfg, dtype, batch=slots)
        self._d_cache = draft.init_cache(slots, max_len)
        self._d_tree = draft.init_tree_caches(slots, tree_capacity)
        if self.paged:
            # the sharded backends page their arenas *statically*: every
            # slot is fully backed through an identity table (the dynamic
            # block allocation/swap policies live behind the local
            # backend's PagedKVArena), so the sharded paths exercise the
            # same pool/table indirection end to end with unchanged
            # schedules.  One table per row geometry, shared by every
            # leaf of that geometry across stage layers + the draft.
            mt = _full_table(slots, max_len, self.page)
            tt = _full_table(slots, tree_capacity, self.page)
            self.model_kv = [_paginate_full(c, mt, self.page)
                             for c in self.model_kv]
            self.tree_kv = [_paginate_full(c, tt, self.page)
                            for c in self.tree_kv]
            self._d_cache = _paginate_full(self._d_cache, mt, self.page)
            self._d_tree = _paginate_full(self._d_tree, tt, self.page)
        self.arena = SlotPool(slots)

        # only the embed table + final norm + unembed head ride the
        # per-timestep dispatch — the layer stack is already duplicated
        # into the stage-sharded ``stage_p`` layout
        self._head_params = {
            k: target.params[k] for k in ("embed", "final_norm", "lm_head")
            if k in target.params}
        verify_pass = pl.make_pipeline_verify(target.cfg, self.plcfg, mesh,
                                              dtype)
        self._verify = jax.jit(functools.partial(
            _sharded_verify_impl, cfg=target.cfg, verify_pass=verify_pass),
            static_argnames=("bucket",))
        self._commit = jax.jit(functools.partial(self._commit_impl,
                                                 cfg=target.cfg))

    def _draft_cache(self):
        return self._d_cache

    def _draft_tree(self):
        return self._d_tree

    # -- target stage-arena plumbing ------------------------------------
    @staticmethod
    def _commit_impl(model_kv, tree_kv, node_idx, model_len, commit_mask,
                     *, cfg):
        return [tf.commit_tree_nodes(cfg, mkv, tkv, node_idx, model_len,
                                     commit_mask)
                for mkv, tkv in zip(model_kv, tree_kv)]

    def _scatter_prefill(self, stacked_cache, slot: int) -> None:
        """Scatter a freshly prefilled stacked-layout model cache
        ([reps, 1, rows, ...] per unit sub-layer) into the stage arena at
        ``slot`` — layer ``s*lps + l`` lands in stage ``s``, in-stage
        index ``l`` (the ``stage_params`` layout)."""
        reps = tf.layout(self.target.cfg)[1]
        pad = self._padded - reps

        def scatter(l):
            def f(dst, src):
                if dst is None:
                    return None
                src = src[:, 0]                       # [reps, rows, ...]
                if pad:
                    src = jnp.concatenate(
                        [src, jnp.zeros((pad, *src.shape[1:]), src.dtype)],
                        0)
                src = src.reshape(self.n_stages, self.lps,
                                  *src.shape[1:])[:, l]  # [S, rows, ...]
                if paging.is_paged(dst):
                    return paging.write_slot_rows(dst, src[:, None], slot)
                return jax.lax.dynamic_update_slice_in_dim(
                    dst, src[:, None].astype(dst.dtype), slot, axis=1)
            return jax.tree_util.tree_map(
                f, self.model_kv[l], stacked_cache,
                is_leaf=lambda x: x is None or paging.is_paged(x))

        self.model_kv = [scatter(l) for l in range(self.lps)]

    # -- interface ------------------------------------------------------
    def prefill(self, slot: int, prompt):
        t_cache = self.target.init_cache(1, self.max_len)
        t_logits, t_cache = self.target.prefill(prompt, t_cache)
        # the pure-stack arch has exactly one attention sub-layer per unit
        self._scatter_prefill(t_cache["stack"][0], slot)
        d_view = tf.slice_cache_rows(self._d_cache, slot, 1)
        _, d_row = self.draft.prefill(prompt, paging.densify(d_view))
        if paging.any_paged(d_view):
            d_row = paging.repaginate(d_view, d_row)
        self._d_cache = tf.update_cache_rows(self._d_cache, d_row, slot)
        return t_logits

    def verify_rows(self, tokens, positions, masks, model_len, write_idx,
                    row_on):
        nb = self._bucket(int(np.max(np.nonzero(np.asarray(row_on))[0])) + 1)
        v_all, self.tree_kv = self._verify(
            self._head_params, self.stage_p, self.stage_valid,
            self.model_kv, self.tree_kv, tokens, positions, masks,
            write_idx, model_len, jnp.asarray(np.asarray(row_on)),
            bucket=nb)
        d_all, self._d_tree = self._draft_verify(tokens, positions, masks,
                                                 model_len, write_idx,
                                                 row_on)
        self.calls["pipeline_verify"] += 1
        return v_all, d_all

    def commit_rows(self, model_len, commit_mask) -> None:
        node0 = jnp.zeros((self.slots,), jnp.int32)
        self.model_kv = self._commit(self.model_kv, self.tree_kv, node0,
                                     model_len, commit_mask)
        self._d_cache = self.draft.commit_rows(
            self._d_cache, self._d_tree, node0, model_len, commit_mask)
        self.calls["commit_rows"] += 1

    def remap_row(self, slot: int, index_map) -> None:
        is_leaf = lambda x: x is None or paging.is_paged(x)

        def one(c):
            row = jax.tree_util.tree_map(
                lambda t: (paging.slice_slots(t, slot, 1)
                           if paging.is_paged(t) else
                           None if t is None else t[:, slot:slot + 1]),
                c, is_leaf=is_leaf)
            row = remap_tree_caches(row, index_map, self.capacity)

            def put(full, r):
                if full is None:
                    return None
                if paging.is_paged(full):
                    # the remapped view's pool IS the updated arena
                    return paging.adopt_pool(full, r)
                return full.at[:, slot:slot + 1].set(r.astype(full.dtype))

            return jax.tree_util.tree_map(put, c, row, is_leaf=is_leaf)

        self.tree_kv = [one(c) for c in self.tree_kv]
        self._d_tree = self._draft_remap_row(slot, index_map)

    def _draft_remap_row(self, slot: int, index_map):
        d_row = remap_tree_caches(
            tf.slice_cache_rows(self._d_tree, slot, 1), index_map,
            self.capacity)
        return tf.update_cache_rows(self._d_tree, d_row, slot)

    def remap_rows(self, index_maps, row_mask) -> None:
        """ONE batched gather per model: the stage-layout tree arenas
        ([S, slots, rows, ...] leaves) and the replicated draft's
        slot-stacked tree cache compact every pruned slot together."""
        if not np.any(np.asarray(row_mask)):
            return
        imaps = jnp.asarray(np.asarray(index_maps), jnp.int32)
        self.tree_kv = _remap_rows_jit(self.tree_kv, imaps)
        self._d_tree = _remap_rows_jit(self._d_tree, imaps)
        self.calls["remap_rows"] += 1


def _overlap_tick_impl(params, d_params, stage_p, stage_valid, model_kv,
                       tree_kv, ring, d_cache, node_tokens, node_positions,
                       tree_mask, write_idx, model_len, entry_on,
                       entry_version, p_tokens, p_len, p_on, p_off,
                       ctrl_commit, ctrl_len, ctrl_imap, ctrl_clear,
                       ctrl_active, kill, *, cfg, d_cfg, tick, prefill_cap):
    """ONE steady-state ring tick: ingest the batched entry layer into
    stage 0, apply the (gated) pruning-propagation ctrl at whichever
    stage it reached this tick, advance every in-flight layer — and the
    prefill lane — one stage, and unembed the exiting activations into
    verify logits.  ``params`` carries only the embed/final-norm/unembed
    leaves (the layer stack already rides in ``stage_p``).

    Admission prefill rides the SAME dispatch: ONE prompt chunk (up to
    ``prefill_cap`` tokens, written at per-slot cache offset ``p_off``)
    enters the ring's prefill lane and the replicated draft's matching
    chunk prefill runs here beside the sharded tick (gated on "any
    prefill entering"), so admitting a request of ANY prompt length
    costs zero extra dispatches — long prompts stream chunk by chunk
    over consecutive ticks.  The whole pytree state (``model_kv``/
    ``tree_kv``/``ring``/``d_cache``) is donated by the caller so XLA
    updates the buffers in place.

    Paged arenas gather dense views here — inside this one compiled
    dispatch but outside the shard_map'd tick (``Paged`` pool/table axes
    do not line up with the tree-mapped stage specs) — and scatter every
    updated row back through the block tables before returning."""
    paged_t = paging.any_paged(model_kv)
    if paged_t:
        mkv_v, tkv_v = model_kv, tree_kv
        model_kv = [paging.densify(c) for c in model_kv]
        tree_kv = [paging.densify(c) for c in tree_kv]
    paged_d = paging.any_paged(d_cache)
    if paged_d:
        dc_v = d_cache
        d_cache = paging.densify(d_cache)
    entry = {
        "act": embed(params["embed"], node_tokens),
        "positions": node_positions,
        "mask": tree_mask,
        "write_idx": write_idx,
        "model_len": model_len,
        "valid": entry_on,
        "version": entry_version,
    }
    ctrl = {"commit": ctrl_commit, "commit_len": ctrl_len,
            "index_map": ctrl_imap, "clear": ctrl_clear,
            "active": ctrl_active}
    pentry = None
    if prefill_cap:
        pentry = {"act": embed(params["embed"], p_tokens), "len": p_len,
                  "on": p_on, "off": p_off}
    model_kv, tree_kv, ring, exit_out = tick(
        stage_p, stage_valid, model_kv, tree_kv, ring, entry, kill, ctrl,
        pentry)
    logits = tf._logits(params, cfg, exit_out["act"])
    p_logits = p_valid = None
    if prefill_cap:
        # unembed the prefill exit only on the (rare) ticks one actually
        # exits — p_last is garbage otherwise and the [B,d]x[d,V] matmul
        # would be pure steady-state waste
        p_valid = exit_out["p_valid"]
        p_logits = jax.lax.cond(
            jnp.any(p_valid),
            lambda x: tf._logits(params, cfg, x),
            lambda x: jnp.zeros(
                (x.shape[0], cfg.vocab_size), x.dtype),
            exit_out["p_last"])
        # the replicated draft prefills the entering prompt chunks inside
        # this same compiled dispatch (its caches are slot-stacked, so
        # one batched chunk pass covers every joining slot; the chunk
        # writes land at each slot's own ``p_off`` offset, rows beyond
        # the prompt length are never attended, and non-entering slots
        # keep their buffers bit-unchanged)
        d_cache = jax.lax.cond(
            jnp.any(p_on),
            lambda dc: tf.where_cache_rows(
                p_on,
                tf.prefill_chunk(d_params, d_cfg, p_tokens, dc, p_off)[1],
                dc),
            lambda dc: dc,
            d_cache)
    if paged_t:
        model_kv = [paging.repaginate(v, c)
                    for v, c in zip(mkv_v, model_kv)]
        tree_kv = [paging.repaginate(v, c) for v, c in zip(tkv_v, tree_kv)]
    if paged_d:
        d_cache = paging.repaginate(dc_v, d_cache)
    return (model_kv, tree_kv, ring, d_cache, logits, exit_out["valid"],
            exit_out["version"], p_logits, p_valid)


class DeferredLogits:
    """Future for one slot's verify logits ([w, V]).

    Issued by ``OverlappedShardedExecutor`` at a layer's entry, stored in
    the engine's ``Flight.logits``, and resolved by the ring tick of the
    layer's exit timestep (``exit_t = entry_t + n_stages - 1``); a kill
    (miss / retire) marks every outstanding future of the slot dead, so a
    stale flight can never commit."""

    __slots__ = ("slot", "version", "_value", "dead")

    def __init__(self, slot: int, version: int):
        self.slot, self.version = slot, version
        self._value, self.dead = None, False

    def resolve(self):
        if self.dead:
            raise RuntimeError(
                f"stale flight: slot {self.slot} tree version "
                f"{self.version} was pruned/retired while in flight")
        if self._value is None:
            raise RuntimeError(
                f"slot {self.slot} flight consumed before its exit tick")
        return self._value


class DeferredPrefill:
    """Future for one slot's admission-prefill logits ([1, V]).

    Issued by ``OverlappedShardedExecutor.begin_prefill`` when the
    request's prompt enters the ring's prefill lane; resolved by the
    tick of the lane's exit timestep (``entry_t + n_stages - 1``), at
    which point the engine finishes the request's ``init_state`` with
    the resolved last-position logits.  A ``kill`` of the slot while the
    prompt is still riding marks the future dead — it will never
    resolve and must not be consumed."""

    __slots__ = ("slot", "_value", "dead")

    def __init__(self, slot: int):
        self.slot, self._value, self.dead = slot, None, False

    @property
    def ready(self) -> bool:
        return self._value is not None

    def resolve(self):
        if self.dead:
            raise RuntimeError(
                f"stale prefill: slot {self.slot} was killed while its "
                f"prompt was in flight")
        if self._value is None:
            raise RuntimeError(
                f"slot {self.slot} prefill consumed before its exit tick")
        return self._value


class OverlappedShardedExecutor(ShardedPipelineExecutor):
    """Steady-state overlapped schedule on the sharded deployment: ONE
    ring tick per global timestep with the ring always full — and kept
    as cheap as the hardware allows (gated ctrl, donated buffers,
    prefill-in-ring).

    Differences from the flush parent, all at the seam:

      * ``tick_rows`` (and ``verify_rows``) dispatch ONE
        ``make_pipedec_tick`` per timestep on a *persistent* ring and
        return ``DeferredLogits`` futures — the target's verify logits
        for an entering layer materialise only at its exit tick.  The
        ring/stage-cache/draft-cache pytrees are *donated* through the
        jitted tick (``donate=True``) so XLA updates them in place
        instead of copying them in and out every tick.
      * ``commit_rows`` / ``remap_row(s)`` queue the target-side cache
        mutation as the next tick's ctrl message (it must trail the
        in-flight layers stage by stage — pruning propagation); the
        replicated draft applies immediately, exactly as on the flush
        backend.  The ctrl channel is *gated* (``gate_ctrl=True``): the
        executor raises the per-tick ``active`` predicate only when exit
        ctrl was actually queued, so the all-identity message that rides
        most ticks costs each stage a predicate check instead of a full
        commit-scatter + prune-gather (``calls["ctrl_active_ticks"]`` /
        ``calls["pipeline_tick"]`` is the measured ctrl-active rate).
      * ``begin_prefill(slot, prompt)`` (``prefill_cap > 0``) overlaps
        admission prefill with the ring: the prompt is split into
        ``prefill_cap``-token chunks that enter the tick's prefill lane
        on consecutive ticks as a special layer kind (version-bumped
        slot, dead tree exit), each chunk writing the stage caches at
        its own per-slot offset (``p_off`` ring metadata), and BOTH
        models' chunk prefills ride the same compiled dispatch — the
        target stage by stage around the ring, the replicated draft
        beside it — so admission at ANY prompt length issues no
        separate prefill dispatch and never idles the ring.  Returns a
        ``DeferredPrefill`` future resolved at the FINAL chunk's exit
        tick; ``None`` only when the lane is disabled
        (``prefill_cap == 0``).
      * ``kill(slot)`` invalidates the slot's in-flight layers in-ring
        (miss / retire) and bumps its tree version; ``drain()`` advances
        the ring with dead entries until every outstanding future
        (verify and prefill) has resolved (shutdown/test helper — the
        per-timestep ticks already resolve every live flight).

    All three cost levers preserve bit-identity: gating only skips
    messages that are the identity, donation only changes buffer
    aliasing, and the in-ring prefill computes the same per-layer math as
    the separate dispatch (pad rows are causally invisible).  The engine
    must tick every executed timestep (entries or not) and its
    ``PipeDecConfig.n_stages`` must equal the mesh's stage count — the
    ring IS the flight bookkeeping, so the fill latencies must agree.
    """

    overlapped = True

    def __init__(self, target: ModelBundle, draft: ModelBundle, *,
                 slots: int, max_len: int, tree_capacity: int,
                 capacity: int, n_stages: Optional[int] = None, mesh=None,
                 dtype=jnp.float32, gate_ctrl: bool = True,
                 donate: bool = True, prefill_cap: int = 64,
                 paged: bool = False, page: int = 16):
        super().__init__(target, draft, slots=slots, max_len=max_len,
                         tree_capacity=tree_capacity, capacity=capacity,
                         n_stages=n_stages, mesh=mesh, dtype=dtype,
                         paged=paged, page=page)
        self.gate_ctrl, self.donate = bool(gate_ctrl), bool(donate)
        if self.paged:
            # paged leaves share ONE block-table array per row geometry
            # across stage layers + the draft — XLA rejects donating the
            # same buffer twice, so the paged tick runs undonated
            self.donate = False
        # the draft is attention-family by construction (it tree-verifies
        # through the same per-row API), so its padded in-tick prefill is
        # causally invisible beyond each prompt's length — a recurrent
        # draft could not ride here (pad tokens would enter its state),
        # but such a draft cannot tree-verify at all
        self.prefill_cap = min(int(prefill_cap), max_len)
        if any(b.prefix_embeds is not None or b.enc_out is not None
               or b.window_override >= 0 for b in (target, draft)):
            # the in-ring prefill embeds raw prompt tokens only —
            # ModelBundle prefill semantics (prefix_embeds, enc_out,
            # window_override) must go through the parent's
            # separate-dispatch prefill, which bakes them in
            self.prefill_cap = 0
        self._ring = pl.init_ring(target.cfg, self.plcfg, dtype=self.dtype,
                                  batch=slots, ctrl=True,
                                  prefill_cap=self.prefill_cap)
        tick = pl.make_pipedec_tick(target.cfg, self.plcfg, self.mesh,
                                    prefill_cap=self.prefill_cap)
        impl = functools.partial(
            _overlap_tick_impl, cfg=target.cfg, d_cfg=draft.cfg, tick=tick,
            prefill_cap=self.prefill_cap)
        # donate the persistent state pytrees (model_kv, tree_kv, ring,
        # d_cache) so XLA aliases them through the tick in place
        self._tick = jax.jit(
            impl, donate_argnums=(4, 5, 6, 7) if self.donate else ())
        # per-slot tree version counters + outstanding-flight futures
        self._versions = np.zeros((slots,), np.int32)
        self._handles = [collections.deque() for _ in range(slots)]
        self._p_handles: dict = {}
        # chunked prefill bookkeeping: queued (chunk, offset) pairs not
        # yet entered, and outstanding lane exits per slot — the
        # DeferredPrefill resolves when the LAST chunk exits
        self._p_queue: dict = {}
        self._p_exits: dict = {}
        self._identity_imap = np.tile(
            np.arange(capacity, dtype=np.int32), (slots, 1))
        self._kill_mask = np.zeros((slots,), bool)
        self._reset_ctrl()
        self._reset_prefill()
        w = self.plcfg.width
        tcap = capacity + w
        self.dead_entry = (
            jnp.zeros((slots, w), jnp.int32),        # tokens
            jnp.zeros((slots, w), jnp.int32),        # positions
            jnp.zeros((slots, w, tcap), bool),       # masks
            jnp.zeros((slots,), jnp.int32),          # model_len
            jnp.full((slots,), capacity, jnp.int32),  # write_idx (parked)
        )

    def _reset_ctrl(self) -> None:
        self._ctrl_commit = np.zeros((self.slots,), bool)
        self._ctrl_len = np.zeros((self.slots,), np.int32)
        self._ctrl_imap = self._identity_imap.copy()
        self._ctrl_clear = np.zeros((self.slots,), bool)
        self._ctrl_active = False

    def _reset_prefill(self) -> None:
        cap = max(self.prefill_cap, 1)
        self._p_tokens = np.zeros((self.slots, cap), np.int32)
        self._p_len = np.zeros((self.slots,), np.int32)
        self._p_on = np.zeros((self.slots,), bool)
        self._p_off = np.zeros((self.slots,), np.int32)

    def _stage_chunk(self, slot: int, chunk, off: int) -> None:
        """Load one prompt chunk into the slot's prefill-lane entry row
        for the next tick (tokens + per-slot cache offset)."""
        self._p_tokens[slot] = 0
        self._p_tokens[slot, :len(chunk)] = chunk
        self._p_len[slot] = len(chunk)
        self._p_off[slot] = off
        self._p_on[slot] = True

    # -- prefill-in-ring ------------------------------------------------
    def begin_prefill(self, slot: int, prompt):
        """Queue ``slot``'s admission prefill into the ring: the prompt
        is split into ``prefill_cap``-token chunks that enter the
        prefill lane on consecutive ticks (each chunk written at its
        own cache offset), so prompts of ANY length stream through the
        ring with zero separate prefill dispatches.  Both models'
        chunk prefills run inside each tick's single dispatch.  Returns
        a ``DeferredPrefill`` future resolved at the FINAL chunk's exit
        tick, or ``None`` only when the lane is disabled
        (``prefill_cap == 0`` — caller falls back to the
        separate-dispatch ``prefill``)."""
        pr = np.asarray(prompt).reshape(-1).astype(np.int32)
        if not self.prefill_cap:
            return None
        if self._handles[slot] or slot in self._p_handles:
            raise RuntimeError(
                f"slot {slot} still has outstanding futures at admission")
        cap = self.prefill_cap
        chunks = [(pr[i:i + cap], i)
                  for i in range(0, len(pr), cap)] or [(pr, 0)]
        self._versions[slot] += 1        # version-bumped slot
        self._stage_chunk(slot, *chunks[0])
        if chunks[1:]:
            self._p_queue[slot] = collections.deque(chunks[1:])
        self._p_exits[slot] = len(chunks)
        h = DeferredPrefill(slot)
        self._p_handles[slot] = h
        self.calls["prefill_in_ring"] += 1
        self.calls["prefill_chunks"] += len(chunks)
        return h

    # -- the per-timestep ring tick -------------------------------------
    def _dispatch_tick(self, tokens, positions, masks, model_len,
                       write_idx, row_on, counter: str) -> None:
        """Run one compiled ring tick (consuming any queued ctrl, kill
        and prefill entries) and resolve the futures of every layer —
        and every prefill — that exited."""
        ctrl_active = self._ctrl_active or not self.gate_ctrl
        (self.model_kv, self.tree_kv, self._ring, self._d_cache,
         exit_logits, exit_valid, exit_version, p_logits,
         p_valid) = self._tick(
            self._head_params, self.draft.params, self.stage_p,
            self.stage_valid, self.model_kv, self.tree_kv, self._ring,
            self._d_cache, tokens, positions, masks, write_idx, model_len,
            jnp.asarray(np.asarray(row_on)), jnp.asarray(self._versions),
            jnp.asarray(self._p_tokens), jnp.asarray(self._p_len),
            jnp.asarray(self._p_on), jnp.asarray(self._p_off),
            jnp.asarray(self._ctrl_commit), jnp.asarray(self._ctrl_len),
            jnp.asarray(self._ctrl_imap), jnp.asarray(self._ctrl_clear),
            jnp.asarray(ctrl_active), jnp.asarray(self._kill_mask))
        if ctrl_active and counter == "pipeline_tick":
            # drain ticks are counted separately — the ctrl-active rate
            # (ctrl_active_ticks / pipeline_tick) prices steady state only
            self.calls["ctrl_active_ticks"] += 1
        self._reset_ctrl()
        self._reset_prefill()
        self._kill_mask[:] = False
        # the lane is free again — feed each streaming prompt's next
        # queued chunk so it enters with the NEXT tick (chunk c+1 reaches
        # every stage exactly one tick behind chunk c's writes there)
        for slot in list(self._p_queue):
            q = self._p_queue[slot]
            self._stage_chunk(slot, *q.popleft())
            if not q:
                del self._p_queue[slot]
        self.calls[counter] += 1

        ev, evers = np.asarray(exit_valid), np.asarray(exit_version)
        for slot in np.nonzero(ev)[0]:
            q = self._handles[int(slot)]
            if not q:
                raise RuntimeError(
                    f"ring exit for slot {slot} with no outstanding flight")
            h = q.popleft()
            if h.version != int(evers[slot]):
                raise RuntimeError(
                    f"tree-version mismatch at ring exit: slot {slot} "
                    f"entered at version {h.version}, exited carrying "
                    f"{int(evers[slot])}")
            h._value = exit_logits[slot]

        if self.prefill_cap:
            for slot in np.nonzero(np.asarray(p_valid))[0]:
                s = int(slot)
                if s not in self._p_exits:
                    raise RuntimeError(
                        f"prefill exit for slot {s} with no "
                        f"outstanding prefill future")
                self._p_exits[s] -= 1
                if self._p_exits[s] == 0:
                    # the FINAL chunk's exit carries the prompt's
                    # last-position logits — earlier chunk exits only
                    # mark ring progress
                    del self._p_exits[s]
                    self._p_handles.pop(s)._value = p_logits[s:s + 1]

    def tick_rows(self, tokens, positions, masks, model_len, write_idx,
                  row_on):
        """ONE ring tick for this global timestep.

        ``row_on`` marks the slot rows entering a new tree layer; all
        other metadata rows are dead and ride masked.  Returns
        ``(d_all, handles)``: ``handles`` maps each entering slot to the
        ``DeferredLogits`` future of its exit tick, ``d_all`` is the
        draft's proposal logits over the bucketed entering rows (``None``
        when nothing enters — the tick still runs, advancing the ring).
        """
        row_on_np = np.asarray(row_on)
        handles = {}
        for slot in np.nonzero(row_on_np)[0]:
            h = DeferredLogits(int(slot), int(self._versions[slot]))
            self._handles[int(slot)].append(h)
            handles[int(slot)] = h

        self._dispatch_tick(tokens, positions, masks, model_len,
                            write_idx, row_on_np, "pipeline_tick")

        d_all = None
        if row_on_np.any():
            d_all, self._d_tree = self._draft_verify(
                tokens, positions, masks, model_len, write_idx, row_on_np)
        return d_all, handles

    # -- PipelineExecutor seam ------------------------------------------
    def verify_rows(self, tokens, positions, masks, model_len, write_idx,
                    row_on):
        """Standard seam, overlapped semantics: returns (handles, d_all)
        where ``handles`` are deferred futures instead of logits."""
        d_all, handles = self.tick_rows(tokens, positions, masks,
                                        model_len, write_idx, row_on)
        return handles, d_all

    def commit_rows(self, model_len, commit_mask) -> None:
        """Queue the target-side exit commit as the next tick's ctrl
        message (it must trail the in-flight layers through the ring);
        the replicated draft commits immediately, like the flush
        backend."""
        mask = np.asarray(commit_mask)
        ml = np.asarray(model_len).astype(np.int32)
        self._ctrl_commit |= mask
        self._ctrl_len = np.where(mask, ml, self._ctrl_len)
        if mask.any():
            self._ctrl_active = True
        node0 = jnp.zeros((self.slots,), jnp.int32)
        self._d_cache = self.draft.commit_rows(
            self._d_cache, self._d_tree, node0, model_len, commit_mask)
        self.calls["commit_rows"] += 1

    def remap_row(self, slot: int, index_map) -> None:
        self._ctrl_imap[slot] = np.asarray(index_map, np.int32)
        self._ctrl_active = True
        self._d_tree = self._draft_remap_row(slot, index_map)

    def remap_rows(self, index_maps, row_mask) -> None:
        rm = np.asarray(row_mask)
        if not rm.any():
            return
        imaps = np.asarray(index_maps, np.int32)
        self._ctrl_imap = np.where(rm[:, None], imaps, self._ctrl_imap)
        self._ctrl_active = True
        self._d_tree = _remap_rows_jit(self._d_tree,
                                       jnp.asarray(imaps, jnp.int32))
        self.calls["remap_rows"] += 1

    # -- pruning propagation: miss / retire -----------------------------
    def kill(self, slot: int, *, drop_ctrl: bool = False) -> None:
        """Invalidate the slot's in-flight ring layers (miss / retire):
        the kill enters with the next tick, stale layers stop writing
        their stage tree-cache rows and exit dead, and the slot's tree
        version advances so no stale future can ever resolve.
        ``drop_ctrl=True`` (retire) also cancels the slot's queued ctrl
        AND neutralises its ctrl messages still riding the ring (via the
        next tick's ``clear`` mask) — the slot is being recycled, and a
        retired occupant's in-flight commits/prunes must never write
        into the next occupant's freshly prefilled caches.  A miss keeps
        both: the missed request's earlier commits stay valid and must
        finish propagating stage by stage."""
        self._versions[slot] += 1
        self._kill_mask[slot] = True
        for h in self._handles[slot]:
            h.dead = True
        self._handles[slot].clear()
        # a prefill still riding (or queued) for the slot dies with it:
        # the tick masks the lane via ``kill``, so its future would
        # otherwise never resolve and drain() could never finish
        ph = self._p_handles.pop(slot, None)
        if ph is not None:
            ph.dead = True
        if self.prefill_cap:
            self._p_on[slot] = False
            self._p_len[slot] = 0
            self._p_off[slot] = 0
            self._p_tokens[slot] = 0
            self._p_queue.pop(slot, None)
            self._p_exits.pop(slot, None)
        if drop_ctrl:
            self._ctrl_commit[slot] = False
            self._ctrl_len[slot] = 0
            self._ctrl_imap[slot] = self._identity_imap[slot]
            self._ctrl_clear[slot] = True
        self.calls["kill"] += 1

    def drain(self) -> int:
        """Advance the ring with dead entries until every outstanding
        future — verify AND prefill — has resolved (at most
        ``n_stages - 1`` ticks, plus one tick per still-queued prompt
        chunk of a streaming prefill).  The engine's per-timestep ticks
        already resolve every live flight, so this is a shutdown/test
        helper, counted separately from the steady-state dispatches."""
        tokens, positions, masks, model_len, write_idx = self.dead_entry
        row_on = np.zeros((self.slots,), bool)
        limit = self.n_stages + max(
            [len(q) for q in self._p_queue.values()], default=0)
        n = 0
        while any(self._handles) or self._p_handles:
            assert n < limit, "ring failed to drain"
            self._dispatch_tick(tokens, positions, masks, model_len,
                                write_idx, row_on, "drain_tick")
            n += 1
        return n


# ---------------------------------------------------------------------------
# Async free-running stages + disaggregated draft
# ---------------------------------------------------------------------------

class AsyncExecutorError(RuntimeError):
    """A stage/draft actor raised (original traceback attached), or the
    host timed out waiting on the async pipe.  Raised on the HOST thread
    by every blocking executor operation so a failed actor can never
    hang the engine — ``sharded_check`` converts it into a
    ``SHARDED_CHECK fail`` status line."""


class _Abort(Exception):
    """Internal: another actor already failed; unwind this one quietly."""


class _AsyncDeferredLogits(DeferredLogits):
    """A ``DeferredLogits`` whose resolve *pumps* the exit queue: the
    async pipe delivers exits whenever the last stage finishes, so the
    engine blocks here (bounded, error-propagating) until this flight's
    exit has been consumed."""

    __slots__ = ("_ex",)

    def __init__(self, slot: int, version: int, ex):
        super().__init__(slot, version)
        self._ex = ex

    def resolve(self):
        while self._value is None and not self.dead:
            self._ex._pump()
        return super().resolve()


class _DraftVerifyResult:
    """Future for one timestep's batched draft proposal logits
    ([bucket, w, V]), filled by the draft actor.  ``__getitem__`` hands
    the engine a per-slot ``resolve()``-able row (the lazy counterpart
    of slicing the eager array), which ``PipeDecEngine.maybe_expand``
    resolves right before expanding the tree."""

    __slots__ = ("_ex", "_event", "_value")

    def __init__(self, ex):
        self._ex = ex
        self._event = threading.Event()
        self._value = None

    def __getitem__(self, slot: int):
        return _DeferredDraftRow(self, int(slot))

    def wait(self):
        deadline = time.monotonic() + self._ex.timeout_s
        while not self._event.wait(0.05):
            self._ex._check_errors()
            if time.monotonic() > deadline:
                raise AsyncExecutorError(
                    f"timed out after {self._ex.timeout_s}s waiting for "
                    f"the draft actor's verify")
        return self._value


class _DeferredDraftRow:
    """One slot's row of a pending draft verify ([w, V] once resolved)."""

    __slots__ = ("_all", "slot")

    def __init__(self, all_, slot: int):
        self._all, self.slot = all_, slot

    def resolve(self):
        return self._all.wait()[self.slot]


class AsyncPipelineExecutor(PipelineExecutor):
    """Free-running per-stage actors + a disaggregated draft actor — the
    host lockstep of the overlapped schedule, broken.

    Every stage ``k`` is a daemon thread pinned to its own device that
    pulls messages from a bounded inbox, applies its compiled per-stage
    step (``launch.pipeline.make_stage_fns`` — the SAME math the
    lockstep tick composes inside its ``shard_map`` body), and pushes to
    stage ``k+1``'s inbox; the last stage unembeds exits into an
    unbounded exit queue the engine thread consumes.  A fast stage never
    waits on a slow one, and per-stage queue depth is uneven
    (``stage_counters`` records occupancy/idle per stage).  The draft
    model lives on a dedicated actor with its own device and cache
    ownership: verify/commit/remap/prefill jobs are applied in engine
    push order, so speculation runs continuously ahead of the target's
    in-flight verifications (``draft_lead()`` is the gauge).

    Message protocol (all slot-batched, one message per engine timestep
    lane):

      * ``layer`` — the entering tree layer: tokens + per-row metadata +
        a per-slot tree-version snapshot.  Stage 0 embeds; each stage
        recomputes the row's liveness (``snapshot == current version``)
        at *processing* time, so a ``kill`` short-circuits a stale layer
        at whatever stage it currently sits (the stale rows stop writing
        immediately) instead of riding a full revolution.
      * ``ctrl`` — pruning propagation: exit-commit + prune index map
        with a ctrl-version snapshot; pushed BEFORE the next entry so
        per-stage FIFO order equals the lockstep schedule's per-stage
        arrival order (ctrl trails every pre-prune layer, leads every
        post-prune one).  A retire (``kill(drop_ctrl=True)``) bumps the
        ctrl version, neutralising the slot's in-flight ctrl wherever it
        sits; a miss does NOT (its earlier commits must finish
        propagating).
      * ``scatter`` — admission prefill: the host prefills the target on
        its own device (the async backend uses the separate-dispatch
        prefill; ``prefill_cap == 0``) and the per-stage cache rows ride
        the pipe as one message, landing at each stage AFTER the
        retired occupant's (suppressed) stale messages — FIFO gives the
        recycle ordering for free.

    Bit-identity argument: each stage processes one global message
    sequence FIFO, which reproduces the lockstep schedule's per-stage
    arrival order exactly; the per-stage compute is the same factored
    function on the same batched rows; and stale-layer writes that the
    version race suppresses earlier (or later) than the lockstep kill
    mask would only ever land in rows a live tree rewrites before
    attending.  Greedy tokens therefore match the lockstep executors
    bit for bit — pinned by ``sharded_check --async`` in CI.

    Failure semantics: an actor exception is recorded, flips a shared
    ``failed`` event (unwinding the other actors), and re-raises on the
    host thread as ``AsyncExecutorError`` from every blocking call
    within ``timeout_s`` — the pipe fails loudly, never hangs.
    ``shutdown()`` drains, stops and joins all actor threads
    (idempotent; the executor restarts lazily on next use).
    """

    overlapped = True     # engine drives the deferred-logits schedule
    prefill_cap = 0       # admission uses the separate-dispatch prefill

    def __init__(self, target: ModelBundle, draft: ModelBundle, *,
                 slots: int, max_len: int, tree_capacity: int,
                 capacity: int, n_stages: Optional[int] = None,
                 dtype=jnp.float32, inbox_depth: int = 8,
                 timeout_s: float = 180.0, devices=None):
        super().__init__(slots)
        self.target, self.draft = target, draft
        self.capacity, self.max_len = capacity, max_len
        self.dtype = dtype
        self.timeout_s = float(timeout_s)
        self.inbox_depth = int(inbox_depth)
        width = tree_capacity - capacity
        assert width >= 1, "tree_capacity must include the width-w slack"
        self.n_stages = int(n_stages or len(jax.devices()))
        self.plcfg = pl.PipelineConfig(
            n_stages=self.n_stages, width=width, tree_capacity=capacity,
            max_len=max_len)
        self.lps, self._padded = pl.stage_layout(target.cfg, self.n_stages)
        devs = list(devices) if devices is not None else jax.devices()
        # one stage per device (round-robin when the host has fewer
        # devices than stages); the draft actor takes the next device
        self._devices = [devs[k % len(devs)] for k in range(self.n_stages)]
        self._draft_device = devs[self.n_stages % len(devs)]
        self.arena = SlotPool(slots)

        is_leaf = lambda x: x is None

        def put_stage(tree, k):
            return jax.tree_util.tree_map(
                lambda t: None if t is None else
                jax.device_put(t[k], self._devices[k]),
                tree, is_leaf=is_leaf)

        layers, valid = pl.stage_params(target.cfg, target.params,
                                        self.n_stages)
        model_kv, tree_kv = pl.init_stage_caches(target.cfg, self.plcfg,
                                                 dtype, batch=slots)
        valid = np.asarray(valid)
        # per-stage actor state: param slices + cache slices committed to
        # the stage's device (each list entry owned by ONE actor thread)
        self._sp = [[put_stage(layers[l], k) for l in range(self.lps)]
                    for k in range(self.n_stages)]
        self._sv = [valid[k] for k in range(self.n_stages)]
        self._kv = [[put_stage(model_kv[l], k) for l in range(self.lps)]
                    for k in range(self.n_stages)]
        self._tkv = [[put_stage(tree_kv[l], k) for l in range(self.lps)]
                     for k in range(self.n_stages)]
        # draft state, owned by the draft actor
        self._d_cache = jax.device_put(draft.init_cache(slots, max_len),
                                       self._draft_device)
        self._d_tree = jax.device_put(
            draft.init_tree_caches(slots, tree_capacity),
            self._draft_device)

        head = {k: target.params[k]
                for k in ("embed", "final_norm", "lm_head")
                if k in target.params}
        self._embed_p = jax.device_put(head["embed"], self._devices[0])
        self._head_last = jax.device_put(head, self._devices[-1])

        stage_apply, stage_ctrl, _ = pl.make_stage_fns(target.cfg,
                                                       self.plcfg)
        cfg = target.cfg
        self._apply_j = jax.jit(stage_apply)
        self._ctrl_j = jax.jit(stage_ctrl)
        self._embed_j = jax.jit(embed)
        self._logits_j = jax.jit(lambda p, x: tf._logits(p, cfg, x))
        self._scatter_j = jax.jit(self._scatter_stage_impl)

        # per-slot versions: layer staleness (bumped on EVERY kill) vs
        # ctrl staleness (bumped only on drop_ctrl retires — a miss must
        # let the missed slot's in-flight commits finish propagating)
        self._versions = np.zeros((slots,), np.int64)
        self._ctrl_versions = np.zeros((slots,), np.int64)
        self._handles = [collections.deque() for _ in range(slots)]
        self._identity_imap = np.tile(
            np.arange(capacity, dtype=np.int32), (slots, 1))
        self._reset_ctrl()
        w = self.plcfg.width
        tcap = capacity + w
        self.dead_entry = (
            jnp.zeros((slots, w), jnp.int32),        # tokens
            jnp.zeros((slots, w), jnp.int32),        # positions
            jnp.zeros((slots, w, tcap), bool),       # masks
            jnp.zeros((slots,), jnp.int32),          # model_len
            jnp.full((slots,), capacity, jnp.int32),  # write_idx (parked)
        )

        # actor plumbing (threads start lazily on first use)
        self._inboxes = [queue.Queue(maxsize=self.inbox_depth)
                         for _ in range(self.n_stages)]
        self._exit_q: queue.Queue = queue.Queue()
        self._draft_q: queue.Queue = queue.Queue()
        self._errors: list = []
        self._failed = threading.Event()
        self._gate = threading.Event()   # test hook: pause()/resume()
        self._gate.set()
        self._threads: list = []
        self._started = False
        self._seq = 0
        self._pushed = self._consumed = 0
        self._draft_pushed = self._draft_done = 0
        self._draft_verified = 0
        self._exit_layers_consumed = 0
        self._max_draft_lead = 0
        self._calls_lock = threading.Lock()
        self.stage_counters = [
            {"msgs": 0, "layers": 0, "stale_rows": 0, "ctrl_applied": 0,
             "ctrl_skipped": 0, "busy_s": 0.0, "idle_s": 0.0,
             "max_depth": 0}
            for _ in range(self.n_stages)]

    # -- small shared helpers -------------------------------------------
    def _scatter_stage_impl(self, kv, src_k, slot):
        """Write one prefilled request's rows for ONE stage: ``src_k``
        leaves are [lps, rows, ...] (this stage's slice of the stacked
        prefill), scattered into the stage's [slots, rows, ...] arena at
        ``slot``."""
        out = []
        for l in range(self.lps):
            out.append(jax.tree_util.tree_map(
                lambda dst, s, l=l: None if dst is None else
                jax.lax.dynamic_update_slice_in_dim(
                    dst, s[l][None].astype(dst.dtype), slot, axis=0),
                kv[l], src_k, is_leaf=lambda x: x is None))
        return out

    def _reset_ctrl(self) -> None:
        self._ctrl_commit = np.zeros((self.slots,), bool)
        self._ctrl_len = np.zeros((self.slots,), np.int32)
        self._ctrl_imap = self._identity_imap.copy()
        self._ctrl_active = False

    def _count(self, key: str, n: int = 1) -> None:
        with self._calls_lock:
            self.calls[key] += n

    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    # -- host-side error/timeout propagation ----------------------------
    def _check_errors(self) -> None:
        if self._errors:
            who, tb = self._errors[0]
            raise AsyncExecutorError(
                f"async pipeline actor '{who}' failed:\n{tb}")

    def _push(self, msg) -> None:
        """Feed stage 0's bounded inbox (bounded wait, error-raising)."""
        self._ensure_started()
        deadline = time.monotonic() + self.timeout_s
        while True:
            self._check_errors()
            try:
                self._inboxes[0].put(msg, timeout=0.1)
                break
            except queue.Full:
                if time.monotonic() > deadline:
                    raise AsyncExecutorError(
                        f"timed out after {self.timeout_s}s feeding the "
                        f"stage-0 inbox (pipe stalled)")
        self._pushed += 1

    def _pump(self) -> None:
        """Consume at least one message from the exit queue (bounded
        wait, error-raising) — the engine thread's only exit-consumption
        path, so handle bookkeeping is single-threaded."""
        deadline = time.monotonic() + self.timeout_s
        while True:
            self._check_errors()
            try:
                msg = self._exit_q.get(timeout=0.1)
            except queue.Empty:
                if time.monotonic() > deadline:
                    raise AsyncExecutorError(
                        f"timed out after {self.timeout_s}s waiting for "
                        f"a pipeline exit")
                continue
            self._consume_exit(msg)
            return

    def _pump_ready(self) -> None:
        """Drain whatever exits are already delivered (non-blocking)."""
        while True:
            try:
                msg = self._exit_q.get_nowait()
            except queue.Empty:
                return
            self._consume_exit(msg)

    def _consume_exit(self, msg) -> None:
        self._consumed += 1
        if msg[0] != "exit_layer":
            return                       # ctrl/scatter/stop pass-through
        _, _seq, logits, row_on, versions = msg
        self._exit_layers_consumed += 1
        for slot in np.nonzero(row_on)[0]:
            s = int(slot)
            if versions[s] != self._versions[s]:
                # run-ahead exit of a flight killed after it left the
                # last stage — its future is already dead; dropping the
                # stale logits is the async analogue of the lockstep
                # exit_valid mask
                self._count("stale_exits")
                continue
            q = self._handles[s]
            if not q:
                raise AsyncExecutorError(
                    f"ring exit for slot {s} with no outstanding flight")
            h = q.popleft()
            if h.version != int(versions[s]):
                raise AsyncExecutorError(
                    f"tree-version mismatch at ring exit: slot {s} "
                    f"entered at version {h.version}, exited carrying "
                    f"{int(versions[s])}")
            h._value = logits[s]

    # -- actor-side primitives (bounded, abort-aware) -------------------
    def _aget(self, q):
        while True:
            if self._failed.is_set():
                raise _Abort
            try:
                return q.get(timeout=0.2)
            except queue.Empty:
                continue

    def _aput(self, q, msg) -> None:
        while True:
            if self._failed.is_set():
                raise _Abort
            try:
                q.put(msg, timeout=0.2)
                return
            except queue.Full:
                continue

    def _wait_gate(self) -> None:
        while not self._gate.wait(0.2):
            if self._failed.is_set():
                raise _Abort

    def pause(self) -> None:
        """Test hook: hold every stage actor BEFORE its next message, so
        a test can stage messages + kills deterministically."""
        self._gate.clear()

    def resume(self) -> None:
        self._gate.set()

    # -- actor loops -----------------------------------------------------
    def _ensure_started(self) -> None:
        if self._started:
            return
        self._started = True
        self._threads = []
        for k in range(self.n_stages):
            t = threading.Thread(target=self._stage_loop, args=(k,),
                                 name=f"async-stage-{k}", daemon=True)
            t.start()
            self._threads.append(t)
        t = threading.Thread(target=self._draft_loop, name="async-draft",
                             daemon=True)
        t.start()
        self._threads.append(t)

    def _stage_loop(self, k: int) -> None:
        ctr = self.stage_counters[k]
        inbox = self._inboxes[k]
        out = (self._inboxes[k + 1] if k + 1 < self.n_stages
               else self._exit_q)
        try:
            while True:
                t_idle = time.perf_counter()
                msg = self._aget(inbox)
                ctr["idle_s"] += time.perf_counter() - t_idle
                ctr["max_depth"] = max(ctr["max_depth"],
                                       inbox.qsize() + 1)
                self._wait_gate()
                t0 = time.perf_counter()
                kind = msg[0]
                if kind == "stop":
                    self._aput(out, msg)
                    return
                if kind == "layer":
                    msg = self._stage_layer(k, ctr, msg)
                elif kind == "ctrl":
                    self._stage_ctrl_msg(k, ctr, msg)
                elif kind == "scatter":
                    self._stage_scatter(k, msg)
                ctr["msgs"] += 1
                ctr["busy_s"] += time.perf_counter() - t0
                self._aput(out, msg)
        except _Abort:
            pass
        except BaseException:
            self._errors.append((f"stage{k}", traceback.format_exc()))
            self._failed.set()

    def _stage_layer(self, k: int, ctr, msg):
        (_, seq, x, positions, masks, model_len, write_idx, row_on,
         versions) = msg
        # liveness at PROCESSING time: a kill bumps the slot's version,
        # so the stale layer stops writing at whatever stage it sits —
        # no revolution wait
        live = row_on & (versions == self._versions)
        stale = int(np.count_nonzero(row_on & ~live))
        if stale:
            ctr["stale_rows"] += stale
        if k == 0:
            x = self._embed_j(self._embed_p, x)   # x carries tokens here
        else:
            x = jax.device_put(x, self._devices[k])
        x, self._tkv[k] = self._apply_j(
            self._sp[k], self._sv[k], self._kv[k], self._tkv[k], x,
            positions, masks, write_idx, model_len, live)
        ctr["layers"] += 1
        self._count("stage_steps")
        if k == self.n_stages - 1:
            logits = self._logits_j(self._head_last, x)
            return ("exit_layer", seq, logits, row_on, versions)
        return ("layer", seq, x, positions, masks, model_len, write_idx,
                row_on, versions)

    def _stage_ctrl_msg(self, k: int, ctr, msg) -> None:
        _, _seq, commit_on, commit_len, imap, cvers = msg
        # ctrl liveness at processing time: only a retire bumps the ctrl
        # version (the lockstep `clear` mask), so a recycled slot's
        # trailing commits/prunes neutralise mid-flight while a missed
        # slot's keep propagating
        live = cvers == self._ctrl_versions
        commit_on = commit_on & live
        imap = np.where(live[:, None], imap, self._identity_imap)
        if not commit_on.any() and np.array_equal(imap,
                                                  self._identity_imap):
            ctr["ctrl_skipped"] += 1     # fully neutralised: the no-op
            return
        self._kv[k], self._tkv[k] = self._ctrl_j(
            self._kv[k], self._tkv[k], commit_on,
            np.where(live, commit_len, 0), imap)
        ctr["ctrl_applied"] += 1

    def _stage_scatter(self, k: int, msg) -> None:
        _, _seq, slot, src = msg
        src_k = jax.tree_util.tree_map(
            lambda t: None if t is None else t[k], src,
            is_leaf=lambda x: x is None)
        self._kv[k] = self._scatter_j(self._kv[k], src_k, np.int32(slot))

    def _draft_loop(self) -> None:
        try:
            while True:
                job = self._aget(self._draft_q)
                kind = job[0]
                if kind == "stop":
                    return
                if kind == "verify":
                    self._draft_verify_job(job)
                elif kind == "commit":
                    _, ml, mask = job
                    node0 = jnp.zeros((self.slots,), jnp.int32)
                    self._d_cache = self.draft.commit_rows(
                        self._d_cache, self._d_tree, node0, ml, mask)
                elif kind == "remap":
                    _, imaps = job
                    self._d_tree = _remap_rows_jit(
                        self._d_tree, jnp.asarray(imaps, jnp.int32))
                elif kind == "remap_row":
                    _, slot, imap = job
                    d_row = remap_tree_caches(
                        tf.slice_cache_rows(self._d_tree, slot, 1), imap,
                        self.capacity)
                    self._d_tree = tf.update_cache_rows(self._d_tree,
                                                        d_row, slot)
                elif kind == "prefill":
                    _, slot, prompt = job
                    d_view = tf.slice_cache_rows(self._d_cache, slot, 1)
                    _, d_row = self.draft.prefill(prompt, d_view)
                    self._d_cache = tf.update_cache_rows(self._d_cache,
                                                         d_row, slot)
                self._draft_done += 1
        except _Abort:
            pass
        except BaseException:
            self._errors.append(("draft", traceback.format_exc()))
            self._failed.set()

    def _draft_verify_job(self, job) -> None:
        _, tokens, positions, masks, model_len, write_idx, row_on, box \
            = job
        nb = self._bucket(int(np.max(np.nonzero(row_on)[0])) + 1)
        sl = lambda a: a[:nb]
        d_all, self._d_tree = self.draft.tree_verify_rows(
            sl(tokens), sl(positions), sl(masks), self._d_cache,
            sl(model_len), self._d_tree, sl(write_idx), bucket=nb)
        self._count("verify_rows")
        self._draft_verified += 1
        lead = self._draft_verified - self._exit_layers_consumed
        self._max_draft_lead = max(self._max_draft_lead, lead)
        box._value = d_all
        box._event.set()

    def _submit_draft(self, job) -> None:
        self._ensure_started()
        self._draft_q.put(job)
        self._draft_pushed += 1

    # -- PipelineExecutor seam ------------------------------------------
    def prefill(self, slot: int, prompt):
        """Separate-dispatch admission prefill (the async pipe has no
        prefill lane): the target prefills on the host's device and the
        per-stage cache rows ride the pipe as ONE scatter message —
        FIFO-ordered after the retired occupant's stale messages and
        before the new occupant's first entry; the draft prefill is a
        job on the draft actor, in the same engine push order."""
        t_cache = self.target.init_cache(1, self.max_len)
        t_logits, t_cache = self.target.prefill(prompt, t_cache)
        src = self._stage_src(t_cache["stack"][0])
        self._push(("scatter", self._next_seq(), int(slot), src))
        self._submit_draft(("prefill", int(slot),
                            np.asarray(prompt)))
        return t_logits

    def _stage_src(self, stacked_cache):
        """Host-side reshape of a freshly prefilled stacked model cache
        ([reps, 1, rows, ...] leaves) into per-stage slices
        ([S, lps, rows, ...]) for the scatter message."""
        reps = tf.layout(self.target.cfg)[1]
        pad = self._padded - reps

        def f(leaf):
            if leaf is None:
                return None
            src = np.asarray(leaf)[:, 0]             # [reps, rows, ...]
            if pad:
                src = np.concatenate(
                    [src, np.zeros((pad, *src.shape[1:]), src.dtype)], 0)
            return src.reshape(self.n_stages, self.lps, *src.shape[1:])

        return jax.tree_util.tree_map(f, stacked_cache,
                                      is_leaf=lambda x: x is None)

    def tick_rows(self, tokens, positions, masks, model_len, write_idx,
                  row_on):
        """One engine timestep: push the queued ctrl message (if any),
        then the entering layer message + the draft verify job.  Returns
        ``(d_all, handles)`` like the overlapped backend — ``handles``
        are blocking ``DeferredLogits``, ``d_all`` a lazy draft-verify
        future (``None`` when nothing enters).  Empty timesteps push
        NOTHING: the async pipe has no dead ticks to pay."""
        self._ensure_started()
        self._check_errors()
        self._pump_ready()
        row_on_np = np.asarray(row_on).astype(bool).copy()
        if self._ctrl_active:
            self._push(("ctrl", self._next_seq(),
                        self._ctrl_commit.copy(), self._ctrl_len.copy(),
                        self._ctrl_imap.copy(),
                        self._ctrl_versions.copy()))
            self._count("ctrl_msgs")
            self._reset_ctrl()
        handles = {}
        d_all = None
        if row_on_np.any():
            vers = self._versions.copy()
            for slot in np.nonzero(row_on_np)[0]:
                h = _AsyncDeferredLogits(int(slot), int(vers[slot]), self)
                self._handles[int(slot)].append(h)
                handles[int(slot)] = h
            tok = np.asarray(tokens, np.int32).copy()
            pos = np.asarray(positions, np.int32).copy()
            msk = np.asarray(masks, bool).copy()
            ml = np.asarray(model_len, np.int32).copy()
            wi = np.asarray(write_idx, np.int32).copy()
            self._push(("layer", self._next_seq(), tok, pos, msk, ml, wi,
                        row_on_np, vers))
            self._count("entry_msgs")
            d_all = _DraftVerifyResult(self)
            self._submit_draft(("verify", tok, pos, msk, ml, wi,
                                row_on_np, d_all))
        self._count("pipeline_tick")
        return d_all, handles

    def verify_rows(self, tokens, positions, masks, model_len, write_idx,
                    row_on):
        """Standard seam, async semantics: (handles, d_all) with
        blocking deferred futures."""
        d_all, handles = self.tick_rows(tokens, positions, masks,
                                        model_len, write_idx, row_on)
        return handles, d_all

    def commit_rows(self, model_len, commit_mask) -> None:
        """Queue the target-side exit commit into the next ctrl message
        (it must trail the in-flight layers stage by stage); the draft
        commit is a job on the draft actor in the same push order."""
        mask = np.asarray(commit_mask).copy()
        ml = np.asarray(model_len).astype(np.int32)
        self._ctrl_commit |= mask
        self._ctrl_len = np.where(mask, ml, self._ctrl_len)
        if mask.any():
            self._ctrl_active = True
        self._submit_draft(("commit", ml.copy(), mask))
        self._count("commit_rows")

    def remap_row(self, slot: int, index_map) -> None:
        imap = np.asarray(index_map, np.int32)
        self._ctrl_imap[slot] = imap
        self._ctrl_active = True
        self._submit_draft(("remap_row", int(slot), imap.copy()))

    def remap_rows(self, index_maps, row_mask) -> None:
        rm = np.asarray(row_mask)
        if not rm.any():
            return
        imaps = np.asarray(index_maps, np.int32)
        self._ctrl_imap = np.where(rm[:, None], imaps, self._ctrl_imap)
        self._ctrl_active = True
        self._submit_draft(("remap", imaps.copy()))
        self._count("remap_rows")

    def kill(self, slot: int, *, drop_ctrl: bool = False) -> None:
        """Invalidate the slot's in-flight layers WHEREVER they sit:
        bumping the version makes every stage's next liveness check
        suppress the stale rows immediately — the short-circuit the
        lockstep ring can only apply one tick at a time.  Outstanding
        futures die; ``drop_ctrl=True`` (retire) additionally cancels
        the slot's queued ctrl and neutralises its in-flight ctrl
        messages via the ctrl-version bump (a miss keeps them — its
        earlier commits must finish propagating)."""
        self._versions[slot] += 1
        for h in self._handles[slot]:
            h.dead = True
        self._handles[slot].clear()
        if drop_ctrl:
            self._ctrl_commit[slot] = False
            self._ctrl_len[slot] = 0
            self._ctrl_imap[slot] = self._identity_imap[slot]
            self._ctrl_versions[slot] += 1
        self._count("kill")

    def drain(self) -> int:
        """Block until every pushed message has come out the far end and
        the draft actor's job queue is empty (bounded, error-raising).
        Leaves the pipe idle and every future resolved."""
        if not self._started:
            return 0
        n = 0
        while self._consumed < self._pushed:
            self._pump()
            n += 1
        deadline = time.monotonic() + self.timeout_s
        while self._draft_done < self._draft_pushed:
            self._check_errors()
            if time.monotonic() > deadline:
                raise AsyncExecutorError(
                    f"timed out after {self.timeout_s}s draining the "
                    f"draft actor")
            time.sleep(0.002)
        if any(self._handles):
            raise AsyncExecutorError(
                "drained pipe left unresolved flights — exit/handle "
                "bookkeeping out of sync")
        self._count("drain")
        return n

    def shutdown(self) -> None:
        """Drain the pipe, stop the actors and join their threads
        (idempotent; a later use restarts the actors lazily).  After a
        failure the drain is skipped and the threads are released via
        the shared abort event."""
        if not self._started:
            return
        self._gate.set()
        if not self._errors:
            try:
                self.drain()
            except AsyncExecutorError:
                pass
        stop = ("stop", self._next_seq())
        for q in (self._inboxes[0], self._draft_q):
            try:
                q.put(stop, timeout=1.0)
            except queue.Full:
                self._failed.set()
        deadline = time.monotonic() + min(self.timeout_s, 30.0)
        while not self._failed.is_set():
            try:
                msg = self._exit_q.get(timeout=0.1)
            except queue.Empty:
                if self._errors or time.monotonic() > deadline:
                    break
                continue
            if msg[0] == "stop":
                break
            self._consume_exit(msg)
        self._failed.set()               # release any blocked actor
        for t in self._threads:
            t.join(timeout=10.0)
        alive = [t.name for t in self._threads if t.is_alive()]
        self._threads = []
        self._started = False
        self._failed = threading.Event()
        if alive:
            raise AsyncExecutorError(
                f"actor threads failed to join: {alive}")

    # -- introspection ---------------------------------------------------
    def draft_lead(self) -> int:
        """How many verify jobs the disaggregated draft has completed
        ahead of the target exits the engine has consumed — the
        speculation run-ahead depth."""
        return self._draft_verified - self._exit_layers_consumed

    def counters(self) -> dict:
        """Snapshot of the per-stage actor counters (msgs processed,
        layer steps, stale rows suppressed, ctrl applied/skipped, busy
        and idle seconds, max inbox depth) plus the draft-lead gauges
        and message totals — what the async demo prints."""
        return {
            "stages": [dict(c) for c in self.stage_counters],
            "draft_lead": self.draft_lead(),
            "max_draft_lead": self._max_draft_lead,
            "pushed": self._pushed,
            "consumed": self._consumed,
        }

    def _draft_cache(self):
        return self._d_cache

    def _draft_tree(self):
        return self._d_tree
