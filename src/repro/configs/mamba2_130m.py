"""mamba2-130m [arXiv:2405.21060] — attention-free SSD (state-space duality).

24L, d_model 768, d_inner 1536 (expand 2), 24 SSD heads (head_dim 64),
d_state 128, vocab 50280, no MLP (d_ff = 0), tied embeddings.
"""
from repro.models.config import ModelConfig, SSMConfig

FULL = ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=50280, tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    num_layers=2, d_model=128, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=512, tie_embeddings=True,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=16),
)
