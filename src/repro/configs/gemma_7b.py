"""gemma-7b [arXiv:2403.08295] — dense, GeGLU, head_dim=256, tied embeddings.

28L, d_model 3072, 16H (GQA kv=16), d_ff 24576, vocab 256000.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="gemma-7b", family="dense",
    num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16,
    head_dim=256, d_ff=24576, vocab_size=256000,
    mlp_variant="geglu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    head_dim=64, d_ff=512, vocab_size=512,
    mlp_variant="geglu", tie_embeddings=True,
)
