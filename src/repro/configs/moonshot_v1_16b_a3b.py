"""moonshot-v1-16b-a3b — Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B].

DeepSeek-V3-style MoE: 48L, d_model 2048, 16H (GQA kv=16), expert d_ff 1408,
vocab 163840, 64 routed experts top-6 + 2 shared, first layer dense
(dense d_ff 11264 per the model card; the assignment's d_ff=1408 is the
per-expert width).
"""
from repro.models.config import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=11264, vocab_size=163840,
    mlp_variant="swiglu",
    moe=MoEConfig(num_experts=64, experts_per_token=6, d_ff_expert=1408,
                  num_shared_experts=2, first_dense=1),
)

SMOKE = ModelConfig(
    name="moonshot-smoke", family="moe",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=352, vocab_size=512,
    mlp_variant="swiglu",
    moe=MoEConfig(num_experts=4, experts_per_token=2, d_ff_expert=88,
                  num_shared_experts=2, first_dense=1,
                  capacity_factor=4.0),
)
