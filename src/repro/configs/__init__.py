"""Registry of the ten assigned architectures (+ the paper's own pair).

Each module exposes ``FULL`` (the exact assigned config) and ``SMOKE``
(a reduced same-family variant: ≤2 layers / unit-pattern, d_model ≤ 512,
≤4 experts) used by the CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "moonshot_v1_16b_a3b",
    "qwen2_moe_a2_7b",
    "whisper_base",
    "gemma_7b",
    "internvl2_26b",
    "mamba2_130m",
    "qwen2_5_32b",
    "recurrentgemma_9b",
    "qwen1_5_32b",
    "deepseek_v2_236b",
]

# accept dashed/dotted public ids too
ALIASES = {
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "whisper-base": "whisper_base",
    "gemma-7b": "gemma_7b",
    "internvl2-26b": "internvl2_26b",
    "mamba2-130m": "mamba2_130m",
    "qwen2.5-32b": "qwen2_5_32b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "qwen1.5-32b": "qwen1_5_32b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    # the paper's own experiment pair (reduced-scale stand-ins)
    "pipedec-target": "pipedec_pair",
    "pipedec-draft": "pipedec_pair",
}


def _module(arch: str):
    name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{name}"), name


def get_config(arch: str, *, smoke: bool = False) -> ModelConfig:
    mod, name = _module(arch)
    if arch == "pipedec-draft":
        return mod.DRAFT_SMOKE if smoke else mod.DRAFT
    if arch == "pipedec-target":
        return mod.TARGET_SMOKE if smoke else mod.TARGET
    return mod.SMOKE if smoke else mod.FULL


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke=smoke) for a in ARCH_IDS}
