"""qwen2.5-32b [hf:Qwen/Qwen2.5-0.5B family] — dense, GQA kv=8, QKV bias.

64L, d_model 5120, 40H (GQA kv=8), d_ff 27648, vocab 152064.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=27648, vocab_size=152064,
    mlp_variant="swiglu", qkv_bias=True,
)

SMOKE = ModelConfig(
    name="qwen2.5-smoke", family="dense",
    num_layers=2, d_model=160, num_heads=5, num_kv_heads=1,
    d_ff=448, vocab_size=512,
    mlp_variant="swiglu", qkv_bias=True,
)
