"""qwen1.5-32b [hf:Qwen/Qwen1.5-0.5B family] — dense, near-MHA GQA, QKV bias.

64L, d_model 5120, 40H (GQA kv=40), d_ff 27392, vocab 152064.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen1.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
    d_ff=27392, vocab_size=152064,
    mlp_variant="swiglu", qkv_bias=True,
)

SMOKE = ModelConfig(
    name="qwen1.5-smoke", family="dense",
    num_layers=2, d_model=160, num_heads=5, num_kv_heads=5,
    d_ff=448, vocab_size=512,
    mlp_variant="swiglu", qkv_bias=True,
)
