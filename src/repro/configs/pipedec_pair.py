"""The paper's own experiment pair — reduced-scale stand-ins.

The paper accelerates LLaMA-3.1-70B (80L, d 8192, 64H kv8, ff 28672,
vocab 128256) with a LLaMA-3.2-1B draft (16L, d 2048, 32H kv8, ff 8192).
``TARGET``/``DRAFT`` keep the exact full-scale shapes for the dry-run;
``*_SMOKE`` are the laptop-scale pair used by the end-to-end PipeDec
examples/benchmarks (shared vocab, as speculative decoding requires).
"""
from repro.models.config import ModelConfig

TARGET = ModelConfig(
    name="llama3.1-70b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256, mlp_variant="swiglu",
)

DRAFT = ModelConfig(
    name="llama3.2-1b", family="dense",
    num_layers=16, d_model=2048, num_heads=32, num_kv_heads=8,
    d_ff=8192, vocab_size=128256, mlp_variant="swiglu", tie_embeddings=True,
)

TARGET_SMOKE = ModelConfig(
    name="pipedec-target-smoke", family="dense",
    num_layers=4, d_model=256, num_heads=8, num_kv_heads=2,
    d_ff=704, vocab_size=512, mlp_variant="swiglu",
)

DRAFT_SMOKE = ModelConfig(
    name="pipedec-draft-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=352, vocab_size=512, mlp_variant="swiglu", tie_embeddings=True,
)
