"""qwen2-moe-a2.7b — Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L, d_model 2048, 16H (GQA kv=16), expert d_ff 1408, vocab 151936,
60 routed experts top-4 + 4 shared (shared width 4x1408 = 5632).
"""
from repro.models.config import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=5632, vocab_size=151936,
    mlp_variant="swiglu", qkv_bias=True,
    moe=MoEConfig(num_experts=60, experts_per_token=4, d_ff_expert=1408,
                  num_shared_experts=4, first_dense=0),
)

SMOKE = ModelConfig(
    name="qwen2-moe-smoke", family="moe",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=352, vocab_size=512,
    mlp_variant="swiglu", qkv_bias=True,
    moe=MoEConfig(num_experts=4, experts_per_token=2, d_ff_expert=88,
                  num_shared_experts=2, first_dense=0,
                  capacity_factor=4.0),
)
