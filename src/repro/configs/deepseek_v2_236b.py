"""deepseek-v2-236b [arXiv:2405.04434] — MoE + MLA.

60L, d_model 5120, 128H MLA (kv_lora 512, q_lora 1536, nope 128 / rope 64,
v_head 128), expert d_ff 1536, vocab 102400, 160 routed top-6 + 2 shared,
first layer dense (dense d_ff 12288).
"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

FULL = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=12288, vocab_size=102400,
    mlp_variant="swiglu",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, experts_per_token=6, d_ff_expert=1536,
                  num_shared_experts=2, first_dense=1),
)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="moe",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512,
    mlp_variant="swiglu",
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                  qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(num_experts=4, experts_per_token=2, d_ff_expert=64,
                  num_shared_experts=2, first_dense=1,
                  capacity_factor=4.0),
)
