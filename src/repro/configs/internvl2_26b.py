"""internvl2-26b [arXiv:2404.16821] — VLM: InternViT-6B (stub) + InternLM2-20B.

Language backbone: 48L, d_model 6144, 48H (GQA kv=8), d_ff 16384,
vocab 92553.  The vision tower + MLP projector are stubbed; the LM consumes
256 prefix patch embeddings per image (448px / patch 14, pixel-shuffle 0.5).
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92553,
    mlp_variant="swiglu", prefix_tokens=256,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm",
    num_layers=2, d_model=192, num_heads=6, num_kv_heads=2,
    d_ff=384, vocab_size=512,
    mlp_variant="swiglu", prefix_tokens=8,
)
