"""whisper-base [arXiv:2212.04356] — enc-dec audio backbone.

6L encoder + 6L decoder, d_model 512, 8H, d_ff 2048, vocab 51865.
The mel+conv frontend is stubbed (input_specs provide frame embeddings of
shape [B, 1500, 512]); the encoder/decoder towers are fully implemented.
Positional scheme: RoPE on decoder self-attention (uniform with the rest of
the framework; Whisper's learned embeddings are a frontend detail).
"""
from repro.models.config import EncoderConfig, ModelConfig

FULL = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865,
    mlp_variant="gelu",
    encoder=EncoderConfig(num_layers=6, num_heads=8, d_ff=2048,
                          max_source_positions=1500),
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512,
    mlp_variant="gelu",
    encoder=EncoderConfig(num_layers=2, num_heads=4, d_ff=256,
                          max_source_positions=16),
)
