"""recurrentgemma-9b [arXiv:2402.19427] — Griffin hybrid: RG-LRU + local attn.

38L, d_model 4096, 16H local attention (MQA kv=1), d_ff 12288, vocab 256000,
block pattern recurrent:attention = 2:1 ("rra"), lru width 4096, window 2048.
38 = 12 full "rra" units + 2 trailing recurrent layers.
"""
from repro.models.config import ModelConfig, RGLRUConfig

FULL = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    head_dim=256, d_ff=12288, vocab_size=256000,
    mlp_variant="geglu", tie_embeddings=True,
    rglru=RGLRUConfig(lru_width=4096, d_conv=4, pattern="rra", window=2048),
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke", family="hybrid",
    num_layers=5, d_model=128, num_heads=4, num_kv_heads=1,
    head_dim=32, d_ff=256, vocab_size=512,
    mlp_variant="geglu", tie_embeddings=True,
    rglru=RGLRUConfig(lru_width=128, d_conv=4, pattern="rra", window=16),
)
