"""AdamW with decoupled weight decay, grad clipping and cosine schedule.

Implemented directly in JAX (no optax dependency); states are pytrees that
shard like the params (the dry-run reuses the param sharding rules for m/v,
which is exactly a ZeRO-free replicated-optimizer layout; ZeRO-style
sharding over "data" is a perf-iteration option).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000


def cosine_schedule(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    return cfg.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_init(params) -> dict:
    zeros = lambda t: jax.tree.map(
        lambda x: jnp.zeros_like(x, dtype=jnp.float32), t)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: AdamWConfig, params, grads, state
                 ) -> Tuple[Any, dict, dict]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    m = jax.tree.map(lambda m_, g: cfg.b1 * m_ + (1 - cfg.b1) * g,
                     state["m"], grads)
    v = jax.tree.map(lambda v_, g: cfg.b2 * v_ + (1 - cfg.b2) * g * g,
                     state["v"], grads)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = cosine_schedule(cfg, step)

    def upd(p, m_, v_):
        mhat = m_ / bc1
        vhat = v_ / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
