from repro.data.pipeline import (ByteCorpus, DataConfig, batch_iterator,
                                 synthetic_corpus)

__all__ = ["ByteCorpus", "DataConfig", "batch_iterator", "synthetic_corpus"]
