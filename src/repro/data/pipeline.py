"""Data pipeline: byte-level corpus, packing, batching, host sharding.

Tokenizer-free byte vocabulary (256 + specials) so examples/tests run fully
offline; a synthetic Markov corpus generator provides learnable structure
(so trained draft/target pairs exhibit realistic speculative acceptance).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np

BOS, EOS, PAD = 256, 257, 258
BYTE_VOCAB = 260  # 256 bytes + BOS/EOS/PAD + 1 spare


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 256
    batch_size: int = 8
    seed: int = 0


class ByteCorpus:
    """Packs raw bytes into fixed-length next-token-prediction examples."""

    def __init__(self, text: bytes, cfg: DataConfig):
        self.cfg = cfg
        ids = np.frombuffer(text, dtype=np.uint8).astype(np.int32)
        n = (len(ids) - 1) // cfg.seq_len * cfg.seq_len
        self.tokens = ids[: n + 1]

    def __len__(self) -> int:
        return (len(self.tokens) - 1) // self.cfg.seq_len

    def example(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        s = i * self.cfg.seq_len
        chunk = self.tokens[s: s + self.cfg.seq_len + 1]
        return chunk[:-1], chunk[1:]


def synthetic_corpus(n_bytes: int = 1 << 16, seed: int = 0,
                     order: int = 2, concentration: float = 0.05) -> bytes:
    """Markov-chain bytes over a small alphabet — compressible, learnable.

    Low ``concentration`` => near-deterministic transitions => small models
    trained on it agree strongly (the draft/target premise of speculative
    decoding at laptop scale)."""
    rng = np.random.default_rng(seed)
    alpha = np.frombuffer(b"abcdefgh ., \n", dtype=np.uint8)
    k = len(alpha)
    trans = rng.dirichlet(np.ones(k) * concentration, size=k ** order)
    out = np.zeros(n_bytes, np.uint8)
    state = 0
    for i in range(n_bytes):
        nxt = rng.choice(k, p=trans[state])
        out[i] = alpha[nxt]
        state = (state * k + nxt) % (k ** order)
    return out.tobytes()


def batch_iterator(corpus: ByteCorpus, *, epochs: int = 1, shuffle=True,
                   host_id: int = 0, host_count: int = 1
                   ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yields (tokens [B,S], labels [B,S]); host-sharded round robin."""
    cfg = corpus.cfg
    rng = np.random.default_rng(cfg.seed)
    n = len(corpus)
    for _ in range(epochs):
        order = rng.permutation(n) if shuffle else np.arange(n)
        order = order[host_id::host_count]
        for s in range(0, len(order) - cfg.batch_size + 1, cfg.batch_size):
            idx = order[s: s + cfg.batch_size]
            xs, ys = zip(*(corpus.example(i) for i in idx))
            yield np.stack(xs), np.stack(ys)
