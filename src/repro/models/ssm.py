"""Mamba-2 (SSD — state-space duality) block, arXiv:2405.21060.

TPU adaptation: the chunked SSD algorithm is the natural fit — intra-chunk
work is a masked ``[Q,Q]`` matmul (MXU-friendly), inter-chunk state carry is
a short ``lax.scan``; no ``[T, heads, hd, d_state]`` state materialisation.

Block layout (ngroups = 1):
    in_proj  -> z (d_inner), xBC (d_inner + 2·d_state), dt (n_heads)
    conv1d(width d_conv, depthwise) + silu over xBC
    SSD recurrence per head h (scalar A_h):
        S_t = exp(dt_t A_h) S_{t-1} + dt_t · x_t ⊗ B_t,   y_t = S_t C_t + D_h x_t
    y · silu(z) -> RMSNorm -> out_proj

Decode keeps ``(conv_state [B, d_conv-1, ch], ssd_state [B,H,hd,N])``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, init_rmsnorm, rmsnorm


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.num_heads(cfg.d_model)
    return s, di, nh, s.head_dim, s.d_state


def init_ssm(key, cfg: ModelConfig, dtype=jnp.float32):
    s, di, nh, hd, n = _dims(cfg)
    conv_ch = di + 2 * n
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (cfg.d_model, 2 * di + 2 * n + nh),
                              dtype=dtype),
        "conv_w": dense_init(ks[1], (s.d_conv, conv_ch), dtype=dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "norm": init_rmsnorm(di, dtype),
        "out_proj": dense_init(ks[2], (di, cfg.d_model), dtype=dtype),
    }


def _split_proj(cfg: ModelConfig, proj):
    _, di, nh, _, n = _dims(cfg)
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * n]
    dt = proj[..., di + di + 2 * n:]
    return z, xbc, dt


def _conv_full(params, xbc):
    """Depthwise causal conv over [B,S,ch] (zero left pad)."""
    w = params["conv_w"]  # [K, ch]
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + params["conv_b"])


def ssd_chunked(x, dt, A, B, C, D, *, chunk: int, initial_state=None):
    """Chunked SSD scan.

    x:  [b, T, H, hd]   (already conv'd/activated inner activations)
    dt: [b, T, H]       (softplus'd step sizes)
    A:  [H]             (negative scalars)
    B, C: [b, T, N]
    Returns (y [b,T,H,hd], final_state [b,H,hd,N]).
    """
    b, t, h, hd = x.shape
    n = B.shape[-1]
    q = chunk
    assert t % q == 0, (t, q)
    nc = t // q

    out_dtype = x.dtype
    # SSD state math in fp32 (long products of decays underflow in bf16)
    xr = x.reshape(b, nc, q, h, hd).astype(jnp.float32)
    dtr = dt.reshape(b, nc, q, h).astype(jnp.float32)
    Br = B.reshape(b, nc, q, n).astype(jnp.float32)
    Cr = C.reshape(b, nc, q, n).astype(jnp.float32)

    dta = dtr * A  # [b,nc,q,h] log-decay per step
    cum = jnp.cumsum(dta, axis=2)  # inclusive
    # decay matrix within chunk: L[i,j] = exp(cum_i - cum_j), j <= i
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [b,nc,q,q,h]
    mask = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])[None, None, :, :, None]
    L = jnp.where(mask, jnp.exp(li), 0.0)

    # intra-chunk: y[i] = sum_j (C_i·B_j) L[i,j] dt_j x_j
    cb = jnp.einsum("bcin,bcjn->bcij", Cr, Br)  # [b,nc,q,q]
    w = cb[..., None] * L  # [b,nc,q,q,h]
    y_intra = jnp.einsum("bcijh,bcjh,bcjhd->bcihd", w, dtr, xr)

    # chunk-boundary state contributions
    total = cum[:, :, -1, :]  # [b,nc,h] full-chunk log decay
    decay_out = jnp.exp(total[:, :, None, :] - cum)  # [b,nc,q,h] j -> chunk end
    # state injected by chunk c: sum_j decay_out_j dt_j x_j ⊗ B_j
    s_in = jnp.einsum("bcjh,bcjh,bcjhd,bcjn->bchdn", decay_out, dtr, xr, Br)

    if initial_state is None:
        initial_state = jnp.zeros((b, h, hd, n), jnp.float32)
    initial_state = initial_state.astype(jnp.float32)

    def step(state, inp):
        s_chunk, tot = inp  # [b,h,hd,n], [b,h]
        prev = state
        new = prev * jnp.exp(tot)[:, :, None, None] + s_chunk
        return new, prev  # emit state entering this chunk

    # scan over chunks
    s_in_t = jnp.moveaxis(s_in, 1, 0)
    tot_t = jnp.moveaxis(total, 1, 0)
    final, prev_states = jax.lax.scan(step, initial_state, (s_in_t, tot_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # [b,nc,h,hd,n]

    # inter-chunk: y[i] += C_i · exp(cum_i) S_prev
    decay_in = jnp.exp(cum)  # [b,nc,q,h]
    y_inter = jnp.einsum("bcin,bcih,bchdn->bcihd", Cr, decay_in, prev_states)

    y = (y_intra + y_inter).reshape(b, t, h, hd)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(out_dtype), final


def ssm_forward(params, cfg: ModelConfig, x_in, *, initial_state=None
                ) -> Tuple[jnp.ndarray, dict]:
    """Full-sequence SSD block. x_in: [B,S,d_model] ->
    (y, state {"conv", "ssd"}) — state is ready for ``ssm_decode``."""
    s, di, nh, hd, n = _dims(cfg)
    proj = x_in @ params["in_proj"]
    z, xbc, dt = _split_proj(cfg, proj)
    pre_conv = xbc
    xbc = _conv_full(params, xbc)
    xi = xbc[..., :di]
    B = xbc[..., di:di + n]
    C = xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    b, t, _ = x_in.shape
    q = min(s.chunk, t)
    # pad T to a chunk multiple
    pad = (-t) % q
    if pad:
        xi = jnp.pad(xi, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    y, state = ssd_chunked(xi.reshape(b, t + pad, nh, hd), dt, A, B, C,
                           params["D"], chunk=q, initial_state=initial_state)
    y = y[:, :t].reshape(b, t, di)
    y = y * jax.nn.silu(z)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    # conv window for a subsequent decode step: last d_conv-1 *pre-conv* inputs
    k = s.d_conv - 1
    if t >= k:
        conv_state = pre_conv[:, -k:]
    else:
        conv_state = jnp.pad(pre_conv, ((0, 0), (k - t, 0), (0, 0)))
    state = state.astype(initial_state.dtype if initial_state is not None
                         else x_in.dtype)
    return y @ params["out_proj"], {"conv": conv_state, "ssd": state}


# --------------------------------------------------------------------------
# decode (recurrent) path
# --------------------------------------------------------------------------
def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s, di, nh, hd, n = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, di + 2 * n), dtype),
        "ssd": jnp.zeros((batch, nh, hd, n), dtype),
    }


def ssm_decode(params, cfg: ModelConfig, x_in, state):
    """One-token step. x_in: [B,1,d_model] -> (y [B,1,d_model], state)."""
    s, di, nh, hd, n = _dims(cfg)
    proj = x_in[:, 0] @ params["in_proj"]  # [B, ...]
    z, xbc, dt = _split_proj(cfg, proj)
    # conv with cached window
    window = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)
    w = params["conv_w"]
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + params["conv_b"]
    xbc = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]

    xi = xbc[..., :di].reshape(-1, nh, hd)
    B = xbc[..., di:di + n]
    C = xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,H]
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A)  # [B,H]
    inject = jnp.einsum("bh,bhd,bn->bhdn", dt,
                        xi.astype(jnp.float32), B.astype(jnp.float32))
    new_ssd = state["ssd"].astype(jnp.float32) * decay[:, :, None, None] \
        + inject
    y = jnp.einsum("bhdn,bn->bhd", new_ssd, C.astype(jnp.float32)) \
        + xi.astype(jnp.float32) * params["D"][None, :, None]
    y = y.reshape(-1, di).astype(x_in.dtype) * jax.nn.silu(z)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    y = y @ params["out_proj"]
    return y[:, None, :], {"conv": new_conv,
                           "ssd": new_ssd.astype(state["ssd"].dtype)}
