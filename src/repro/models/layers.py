"""Shared building blocks: norms, RoPE, MLPs, embeddings.

Everything is functional: ``init_*`` builds a param pytree, the matching
forward takes ``(params, ...) -> array``.  Params are plain dicts so that
pjit sharding rules can be expressed by path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# initialisers
# --------------------------------------------------------------------------
def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """LeCun-normal over the input dimension (robust default)."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else 1
    scale = 1.0 / jnp.sqrt(jnp.maximum(fan_in, 1))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def init_rmsnorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    orig = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(orig)


def init_layernorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    orig = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    out = x * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(orig)


# --------------------------------------------------------------------------
# rotary position embeddings (computed in fp32)
# --------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    orig = x.dtype
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(orig)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, variant: str, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if variant in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), dtype=dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), dtype=dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), dtype=dtype),
    }


def matmul(x, w):
    """``x @ w`` for a plain array or a quantized ``{"q8", "scale"}``
    weight dict (int8 values, per-out-channel scales — dispatched through
    the fused dequant-matmul in ``kernels.ops``)."""
    if isinstance(w, dict) and "q8" in w:
        from repro.kernels import ops as kops
        return kops.quant_matmul(x, w)
    return x @ w


def mlp(params, x, variant: str):
    if variant == "swiglu":
        act = jax.nn.silu(matmul(x, params["w_gate"]))
        return matmul(act * matmul(x, params["w_up"]), params["w_down"])
    if variant == "geglu":
        act = jax.nn.gelu(matmul(x, params["w_gate"]), approximate=True)
        return matmul(act * matmul(x, params["w_up"]), params["w_down"])
    return matmul(jax.nn.gelu(matmul(x, params["w_up"]), approximate=True),
                  params["w_down"])


# --------------------------------------------------------------------------
# embeddings / head
# --------------------------------------------------------------------------
def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32):
    return {"table": embed_init(key, (vocab, d_model), dtype=dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x, tie_table=None):
    table = tie_table if tie_table is not None else params["table"]
    return x @ table.T
