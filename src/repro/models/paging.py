"""Block-paged KV storage (vLLM-class) for the serving arenas.

A paged buffer replaces a dense slot-stacked cache leaf

    dense: [*pre, B, L, *post]          (slot axis immediately before the
                                         length axis, as everywhere in
                                         ``models.transformer``)

with a physical row pool plus a per-slot block table:

    pages: [n_blocks * page, *pre, *post]   flat physical rows
    table: [B, ceil(L / page)] int32        logical block -> physical block

Physical block 0 is the reserved *null block*: every unallocated logical
block of every slot aliases it, so gathers of not-yet-allocated regions
are well-defined (they read don't-care rows that every attention mask —
``model_len`` bounds, ancestor masks — already excludes, exactly the
invariant that makes dense slot recycling safe) and masked writes can be
redirected into it.  The host-side free-block pool / allocation policy
lives in ``serving.scheduler`` (``PagePool``/``PageAllocator``); this
module is the pure device-side indirection: gather a dense view, scatter
rows back, slice/adopt slot views, per-row bounded writes.

Everything here is jit-traceable; ``Paged`` is a registered pytree whose
children are (pages, table) and whose static aux data is
(page, length, n_pre), so paged caches flow through the existing jitted
dispatches, donation, and ``jax.tree`` plumbing unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Paged:
    """One paged cache leaf: flat physical row pool + per-slot block table.

    ``pages``  [n_phys_rows, *row_shape]  — row r of physical block p is
               pool row ``p * page + r``; row_shape is the dense leaf's
               shape with the slot and length axes removed.
    ``table``  [B, n_logical_blocks] int32 — 0 (the null block) marks an
               unallocated logical block.
    ``page``   rows per block (power of two).
    ``length`` logical rows per slot (the dense leaf's length-axis size).
    ``n_pre``  dense axes before the slot axis (1 for stacked "reps"
               buffers and stage-stacked pipeline buffers, else 0).
    """
    pages: Any
    table: Any
    page: int
    length: int
    n_pre: int = 0

    def tree_flatten(self):
        return (self.pages, self.table), (self.page, self.length, self.n_pre)

    @classmethod
    def tree_unflatten(cls, aux, children):
        pages, table = children
        return cls(pages, table, *aux)

    @property
    def slots(self) -> int:
        return self.table.shape[0]

    @property
    def dtype(self):
        return self.pages.dtype

    def astype(self, dtype):
        return Paged(self.pages.astype(dtype), self.table, self.page,
                     self.length, self.n_pre)


def is_paged(x) -> bool:
    return isinstance(x, Paged)


def n_blocks(length: int, page: int) -> int:
    return -(-length // page)


def dense_shape(p: Paged) -> tuple:
    """The dense leaf shape this paged buffer stands in for."""
    row = p.pages.shape[1:]
    pre, post = row[:p.n_pre], row[p.n_pre:]
    return (*pre, p.slots, p.length, *post)


def make_paged(dense, table, page: int, n_pre: int = 0,
               *, null_block: bool = True) -> Paged:
    """Build a paged buffer from a dense leaf with an identity-style
    ``table`` [B, mb] (testing / migration helper).  ``null_block``
    prepends one physical null block (id 0) so the table ids can start
    at 1."""
    table = jnp.asarray(table, jnp.int32)
    b, mb = table.shape
    length = dense.shape[n_pre + 1]
    rows = jnp.moveaxis(dense, tuple(range(n_pre)),
                        tuple(range(2, 2 + n_pre)))        # [B, L, *row]
    row_shape = rows.shape[2:]
    pad = mb * page - length
    if pad:
        rows = jnp.pad(rows, ((0, 0), (0, pad)) + ((0, 0),) * len(row_shape))
    blocked = rows.reshape(b * mb, page, *row_shape)
    nb_total = int(jnp.max(table)) + 1 if table.size else 1
    pool = jnp.zeros((max(nb_total, 1) * page, *row_shape), dense.dtype)
    pool = pool.at[(table.reshape(-1)[:, None] * page
                    + jnp.arange(page)[None]).reshape(-1)].set(
        blocked.reshape(b * mb * page, *row_shape))
    return Paged(pool, table, page, length, n_pre)


def _row_ids(p: Paged):
    """Physical pool row id for every (slot, logical row): [B, L] int32."""
    ls = jnp.arange(p.length, dtype=jnp.int32)
    return p.table[:, ls // p.page] * p.page + (ls % p.page)[None]


def to_dense(p: Paged):
    """Gather the dense [*pre, B, L, *post] view (unallocated logical rows
    read the null block — don't-care values the masks exclude)."""
    g = p.pages[_row_ids(p)]                               # [B, L, *row]
    return jnp.moveaxis(g, tuple(range(2, 2 + p.n_pre)),
                        tuple(range(p.n_pre)))


def from_dense(p: Paged, dense) -> Paged:
    """Scatter a full dense view back into the pool through the table.
    Rows of unallocated logical blocks collapse onto the null block
    (duplicate scatter indices — last-writer-wins garbage that is never
    read meaningfully)."""
    rows = jnp.moveaxis(dense, tuple(range(p.n_pre)),
                        tuple(range(2, 2 + p.n_pre)))      # [B, L, *row]
    idx = _row_ids(p).reshape(-1)
    pages = p.pages.at[idx].set(
        rows.reshape(-1, *rows.shape[2:]).astype(p.pages.dtype))
    return Paged(pages, p.table, p.page, p.length, p.n_pre)


def slice_slots(p: Paged, start: int, size: int) -> Paged:
    """Slot-row view: the table is sliced, the pool is shared — bucketed
    dispatches get O(1) views instead of gather/copy."""
    return Paged(p.pages, jax.lax.slice_in_dim(p.table, start, start + size,
                                               axis=0),
                 p.page, p.length, p.n_pre)


def adopt_pool(full: Paged, part: Paged) -> Paged:
    """Merge a bucketed view's (functionally updated) pool back into the
    full paged buffer: the pool is shared storage, so the part's pages ARE
    the updated arena; only the full table is kept."""
    assert part.pages.shape == full.pages.shape, \
        "adopt_pool: bucketed view must share the full pool"
    return Paged(part.pages, full.table, full.page, full.length, full.n_pre)


def write_slot_rows(p: Paged, rows_dense, start: int) -> Paged:
    """Write dense rows for slots [start, start+size) (dense layout
    [*pre, size, L, *post]) into the pool through the table — the paged
    ``update_cache_rows``."""
    size = rows_dense.shape[p.n_pre]
    view = slice_slots(p, start, start + size - start)
    rows = jnp.moveaxis(rows_dense, tuple(range(p.n_pre)),
                        tuple(range(2, 2 + p.n_pre)))      # [size, L, *row]
    idx = _row_ids(view).reshape(-1)
    pages = p.pages.at[idx].set(
        rows.reshape(-1, *rows.shape[2:]).astype(p.pages.dtype))
    return Paged(pages, p.table, p.page, p.length, p.n_pre)


def write_len_rows(p: Paged, u, starts, *, on=None) -> Paged:
    """Per-slot contiguous length-row write: slot b's rows
    [starts[b], starts[b]+n) take ``u`` (dense layout [*pre, B, n, *post]).
    Out-of-range logical rows and rows of slots with ``on[b]`` False are
    redirected into the null block (physical row 0) — the paged
    ``_cache_write_rows`` with drop semantics at the buffer edge."""
    starts = jnp.asarray(starts, jnp.int32).reshape(-1)
    n = u.shape[p.n_pre + 1]
    ls = starts[:, None] + jnp.arange(n, dtype=jnp.int32)[None]  # [B, n]
    inb = ls < p.length
    lb = jnp.clip(ls, 0, p.length - 1)
    phys = p.table[jnp.arange(p.table.shape[0])[:, None], lb // p.page] \
        * p.page + (lb % p.page)
    phys = jnp.where(inb, phys, 0)
    if on is not None:
        on = jnp.asarray(on).reshape(-1, 1)
        phys = jnp.where(on, phys, 0)
    rows = jnp.moveaxis(u, tuple(range(p.n_pre)),
                        tuple(range(2, 2 + p.n_pre)))      # [B, n, *row]
    pages = p.pages.at[phys.reshape(-1)].set(
        rows.reshape(-1, *rows.shape[2:]).astype(p.pages.dtype))
    return Paged(pages, p.table, p.page, p.length, p.n_pre)


def take_len_rows(p: Paged, idx):
    """Per-slot length-row gather: rows [B, n, *pre, *post] moved back to
    the dense layout [*pre, B, n, *post]; ``idx`` [B, n] logical rows."""
    idx = jnp.asarray(idx, jnp.int32)
    lb = jnp.clip(idx, 0, p.length - 1)
    phys = p.table[jnp.arange(p.table.shape[0])[:, None], lb // p.page] \
        * p.page + (lb % p.page)
    g = p.pages[phys]                                      # [B, n, *row]
    return jnp.moveaxis(g, tuple(range(2, 2 + p.n_pre)),
                        tuple(range(p.n_pre)))


def where_slots(on, new: Paged, old: Paged) -> Paged:
    """Per-slot select between two paged buffers sharing one table: slot
    b's blocks take ``new`` where ``on[b]``.  Ownership is resolved at
    block granularity through the table (the null block's winner is
    arbitrary — its content is never read meaningfully)."""
    on = jnp.asarray(on).reshape(-1)
    nb_phys = new.pages.shape[0] // new.page
    mb = new.table.shape[1]
    owned = jnp.zeros((nb_phys,), bool).at[new.table.reshape(-1)].set(
        jnp.repeat(on, mb))
    sel = jnp.repeat(owned, new.page)
    sel = sel.reshape((-1,) + (1,) * (new.pages.ndim - 1))
    return Paged(jnp.where(sel, new.pages, old.pages), old.table,
                 old.page, old.length, old.n_pre)


def densify(tree):
    """Replace every Paged leaf of a cache pytree with its dense gather
    (entry side of a paged jitted dispatch)."""
    return jax.tree_util.tree_map(
        lambda x: to_dense(x) if is_paged(x) else x, tree,
        is_leaf=lambda x: x is None or is_paged(x))


def repaginate(paged_tree, dense_tree):
    """Scatter a dense cache pytree back through the paged tree's tables
    (exit side of a paged jitted dispatch); non-paged leaves pass the
    dense value through."""
    return jax.tree_util.tree_map(
        lambda p, d: from_dense(p, d) if is_paged(p) else d,
        paged_tree, dense_tree,
        is_leaf=lambda x: x is None or is_paged(x))


def any_paged(tree) -> bool:
    return any(is_paged(leaf) for leaf in jax.tree_util.tree_leaves(
        tree, is_leaf=lambda x: x is None or is_paged(x))
        if leaf is not None)
