"""Mixture-of-Experts: token-choice top-k router + sort-based grouped GEMM.

Design notes (TPU adaptation):
  * Dispatch is *sort-based*: tokens are replicated top-k times, sorted by
    expert id, and packed into an ``[E, C, d]`` buffer (capacity
    ``C = ceil(T·k/E · capacity_factor)``; overflow tokens are dropped, as in
    Switch/GShard).  Expert compute is then three grouped GEMMs
    ``[E,C,d]×[E,d,f]`` whose FLOPs equal the *active* parameter count —
    this is what makes the roofline's MODEL_FLOPS/HLO_FLOPs ratio honest,
    unlike a masked dense-over-all-experts formulation.
  * Under pjit the ``E`` dimension of the buffers and weights is sharded on
    the "model" mesh axis => expert parallelism; the scatter/gather around
    the grouped GEMM lowers to all-to-all-style collectives.
  * Shared experts (Qwen-MoE / DeepSeek / Moonlight) are a plain dense MLP
    with ``num_shared · d_ff_expert`` width, always active.
  * The router aux (load-balance) loss is returned for the training path.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, init_mlp, mlp


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32):
    mo = cfg.moe
    d, f, e = cfg.d_model, mo.d_ff_expert, mo.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32),  # fp32 router
        "w_gate": dense_init(ks[1], (e, d, f), in_axis=1, dtype=dtype),
        "w_up": dense_init(ks[2], (e, d, f), in_axis=1, dtype=dtype),
        "w_down": dense_init(ks[3], (e, f, d), in_axis=1, dtype=dtype),
    }
    if mo.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d, mo.num_shared_experts * f,
                               "swiglu", dtype=dtype)
    return p


def capacity(tokens: int, cfg: ModelConfig) -> int:
    mo = cfg.moe
    c = math.ceil(tokens * mo.experts_per_token / mo.num_experts
                  * mo.capacity_factor)
    # keep lane-aligned for TPU layouts
    return max(8, -(-c // 8) * 8)


def route(params, cfg: ModelConfig, x_flat) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (topk_idx [T,k], topk_gate [T,k], aux_loss scalar)."""
    mo = cfg.moe
    logits = x_flat.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, mo.experts_per_token)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux: E * sum_e f_e * p_e
    t = x_flat.shape[0]
    density = jnp.zeros((mo.num_experts,), jnp.float32).at[idx.reshape(-1)].add(
        1.0) / (t * mo.experts_per_token)
    mean_prob = probs.mean(axis=0)
    aux = mo.num_experts * jnp.sum(density * mean_prob)
    return idx, gate.astype(x_flat.dtype), aux


# ---- distributed-dispatch knobs (set by the launcher) ---------------------
# _GROUPS: dispatch groups — tokens are routed/sorted/capacity-bounded
#   *within* each group.  With groups == number of data shards and the group
#   dim sharded over 'data', the argsort and the scatter stay shard-local
#   (no distributed sort) and only the expert GEMM communicates (§Perf H2).
# _BUF_SHARDING / _H_SHARDING: optional NamedShardings constraining the
#   dispatch buffers, e.g. P(('pod','data'), 'model', None, None).
_GROUPS = 1
_BUF_SHARDING = None
_X_SHARDING = None  # [B,S,d] sharding at MoE entry (batch-only: the token
#                     stream must be group-aligned so sorts/scatters stay
#                     shard-local — sequence parallelism is re-applied by
#                     the caller after the block)


def set_dispatch(groups: int = 1, buf_sharding=None,
                 x_sharding=None) -> None:
    global _GROUPS, _BUF_SHARDING, _X_SHARDING
    _GROUPS = max(1, int(groups))
    _BUF_SHARDING = buf_sharding
    _X_SHARDING = x_sharding


def _constrain_buf(x):
    if _BUF_SHARDING is not None:
        return jax.lax.with_sharding_constraint(x, _BUF_SHARDING)
    return x


def moe_forward(params, cfg: ModelConfig, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> (y [B,S,d], aux_loss).

    Group-wise sort-based dispatch: within each of ``_GROUPS`` token groups,
    replicate tokens top-k times, sort by expert id (axis-local argsort),
    pack into a per-group [E, C_g, d] buffer, run the grouped expert GEMMs,
    and combine.  Per-group capacity C_g = ceil(T_g·k/E · cf); overflow is
    dropped per group (standard Switch/GShard semantics per shard).
    """
    mo = cfg.moe
    if _X_SHARDING is not None:
        x = jax.lax.with_sharding_constraint(x, _X_SHARDING)
    b, s, d = x.shape
    t = b * s
    k = mo.experts_per_token
    e = mo.num_experts
    g = _GROUPS if t % _GROUPS == 0 else 1
    tl = t // g
    x_flat = x.reshape(t, d)

    idx, gate, aux = route(params, cfg, x_flat)

    # ---- group-local sort-based dispatch ---------------------------------
    fe = idx.reshape(g, tl * k)                          # [G, Tl*k]
    ft = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tl), k)[None], (g, tl * k))
    fg = gate.reshape(g, tl * k)
    order = jnp.argsort(fe, axis=-1, stable=True)        # local sorts
    se = jnp.take_along_axis(fe, order, -1)
    st = jnp.take_along_axis(ft, order, -1)
    sg = jnp.take_along_axis(fg, order, -1)

    one_pos = jnp.arange(tl * k)[None]
    counts = (fe[:, None, :] == jnp.arange(e)[None, :, None]).sum(-1)  # [G,E]
    starts = jnp.concatenate(
        [jnp.zeros((g, 1), counts.dtype), jnp.cumsum(counts, -1)[:, :-1]], -1)
    pos_in_e = one_pos - jnp.take_along_axis(starts, se, -1)
    cap = capacity(tl, cfg)
    keep = pos_in_e < cap
    slot = se * cap + jnp.where(keep, pos_in_e, 0)       # [G, Tl*k]

    # NOTE gather-only dataflow (no scatters): scatters with explicit index
    # arrays defeat the SPMD partitioner's batch-dim detection and replicate
    # the [G, Tl·k, d] operands across the mesh (§Perf H2).  Because entries
    # are expert-sorted, both dispatch and combine are pure gathers.
    xg = x_flat.reshape(g, tl, d)
    src = jnp.take_along_axis(xg, st[..., None], 1)      # [G, Tl*k, d]

    # dispatch: buffer position (e, c) reads sorted entry starts[e] + c
    bpos = jnp.arange(e * cap)[None]
    b_e = bpos // cap
    b_c = bpos % cap
    src_pos = jnp.take_along_axis(starts, jnp.broadcast_to(b_e, (g, e * cap)),
                                  -1) + b_c
    b_valid = b_c < jnp.take_along_axis(
        counts, jnp.broadcast_to(b_e, (g, e * cap)), -1)
    src_pos = jnp.where(b_valid, src_pos, 0)
    buf = jnp.where(b_valid[..., None],
                    jnp.take_along_axis(src, src_pos[..., None], 1), 0)
    buf = _constrain_buf(buf.reshape(g, e, cap, d))

    # ---- grouped expert GEMMs (active FLOPs only) ------------------------
    h_gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, params["w_gate"]))
    h_up = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    h = jnp.einsum("gecf,efd->gecd", h_gate * h_up, params["w_down"])
    h = _constrain_buf(h).reshape(g, e * cap, d)

    # ---- combine: un-sort (gather) then sum the k copies per token --------
    gathered = jnp.take_along_axis(h, slot[..., None], 1) \
        * (sg * keep)[..., None]                         # [G, Tl*k, d] sorted
    inv = jnp.argsort(order, axis=-1)
    contrib = jnp.take_along_axis(gathered, inv[..., None], 1)
    y = contrib.reshape(g, tl, k, d).sum(2).reshape(t, d).astype(x.dtype)

    if mo.num_shared_experts:
        y = y + mlp(params["shared"], x_flat, "swiglu")
    return y.reshape(b, s, d), aux
