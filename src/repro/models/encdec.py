"""Encoder tower for enc-dec backbones (Whisper-style).

Per the task carve-out, the *modality frontend* (mel spectrogram + conv
feature extractor) is a stub — ``repro.models.frontends`` supplies frame
embeddings of shape [B, n_frames, d_model].  The encoder here is the real
transformer tower: bidirectional self-attention + MLP blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.config import ModelConfig
from repro.models.layers import init_mlp, init_rmsnorm, mlp, rmsnorm


def init_encoder(key, cfg: ModelConfig, dtype=jnp.float32):
    enc = cfg.encoder
    ks = jax.random.split(key, enc.num_layers + 1)
    layers = []
    for i in range(enc.num_layers):
        lk = jax.random.split(ks[i], 2)
        layers.append({
            "norm1": init_rmsnorm(cfg.d_model, dtype),
            "attn": attn.init_attention(lk[0], cfg, dtype),
            "norm2": init_rmsnorm(cfg.d_model, dtype),
            "mlp": init_mlp(lk[1], cfg.d_model, enc.d_ff or cfg.d_ff,
                            "gelu", dtype),
        })
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {"layers": stacked, "final_norm": init_rmsnorm(cfg.d_model, dtype)}


def encode(params, cfg: ModelConfig, frames):
    """frames: [B, T, d_model] stub embeddings -> encoder output."""
    b, t, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))

    def body(x, layer_p):
        h = rmsnorm(layer_p["norm1"], x, cfg.norm_eps)
        y, _ = attn.attn_forward(layer_p["attn"], cfg, h, positions,
                                 causal=False)
        x = x + y
        h2 = rmsnorm(layer_p["norm2"], x, cfg.norm_eps)
        x = x + mlp(layer_p["mlp"], h2, "gelu")
        return x, None

    x, _ = jax.lax.scan(body, frames, params["layers"])
    return rmsnorm(params["final_norm"], x, cfg.norm_eps)
