from repro.models.config import (EncoderConfig, MLAConfig, ModelConfig,
                                 MoEConfig, RGLRUConfig, SSMConfig)

__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "RGLRUConfig",
    "EncoderConfig",
]
