"""Decoder model covering all assigned architecture families.

Layer organisation
------------------
Every architecture is a repetition of a *unit* (a short tuple of sub-layer
kinds), e.g. dense = ``("attn",)``, Mamba-2 = ``("ssm",)``, RecurrentGemma =
``("rglru","rglru","local")``.  The repeated region is executed with
``lax.scan`` over stacked unit params (MaxText-style) so that 64-layer
configs lower to compact HLO; non-uniform prefix layers (MoE ``first_dense``)
and the pattern remainder are unrolled.

Execution modes
---------------
  * full   — training / prefill over a whole sequence (optionally filling the
             model KV cache / recurrent states).
  * decode — one token per step against the model cache (``serve_step``).
  * tree   — PipeDec: verify one prediction-tree layer against the two-level
             cache (model cache + tree cache) with the ancestor mask.

All functions are pure; caches/states are explicit pytrees.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import config as config_mod
from repro.models import paging
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (embed, init_embedding, init_mlp,
                                 init_rmsnorm, mlp, rmsnorm, unembed)


# --------------------------------------------------------------------------
# unit layout
# --------------------------------------------------------------------------
def unit_kinds(cfg: ModelConfig) -> Tuple[str, ...]:
    if cfg.family == "ssm":
        return ("ssm",)
    if cfg.rglru is not None:
        return tuple(config_mod.PATTERN_KINDS.get(c, "local")
                     for c in cfg.rglru.pattern)
    return ("attn",)


def layout(cfg: ModelConfig) -> Tuple[int, int, Tuple[str, ...]]:
    """(n_prefix_dense, n_repeats, tail_kinds)."""
    kinds = unit_kinds(cfg)
    n_prefix = cfg.moe.first_dense if cfg.moe is not None else 0
    body = cfg.num_layers - n_prefix
    reps = body // len(kinds)
    tail = kinds[: body % len(kinds)]
    return n_prefix, reps, tail


def _sub_has_ffn(cfg: ModelConfig, kind: str) -> bool:
    if kind == "ssm":
        return False
    return cfg.d_ff > 0 or cfg.moe is not None


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def _init_sublayer(key, cfg: ModelConfig, kind: str, *, use_moe: bool, dtype):
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": init_rmsnorm(cfg.d_model, dtype)}
    if kind in ("attn", "local"):
        p["mixer"] = attn.init_attention(ks[0], cfg, dtype)
    elif kind == "ssm":
        p["mixer"] = ssm_mod.init_ssm(ks[0], cfg, dtype)
    elif kind == "rglru":
        p["mixer"] = rglru_mod.init_rglru(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if cfg.is_encdec and kind in ("attn", "local"):
        p["cross_norm"] = init_rmsnorm(cfg.d_model, dtype)
        p["cross"] = attn.init_attention(ks[1], cfg, dtype, cross=True)
    if _sub_has_ffn(cfg, kind):
        p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
        if use_moe:
            p["ffn"] = moe_mod.init_moe(ks[2], cfg, dtype)
        else:
            p["ffn"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_variant,
                                dtype)
    return p


def _init_unit(key, cfg: ModelConfig, *, use_moe: bool, dtype,
               kinds: Optional[Tuple[str, ...]] = None):
    kinds = kinds or unit_kinds(cfg)
    ks = jax.random.split(key, len(kinds))
    return [
        _init_sublayer(ks[i], cfg, kind, use_moe=use_moe and kind != "ssm",
                       dtype=dtype)
        for i, kind in enumerate(kinds)
    ]


def init_model(key, cfg: ModelConfig, dtype=jnp.float32):
    n_prefix, reps, tail = layout(cfg)
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(ks[1], cfg.vocab_size, cfg.d_model,
                                           dtype)
    if n_prefix:
        pk = jax.random.split(ks[2], n_prefix)
        params["prefix"] = [
            _init_unit(pk[i], cfg, use_moe=False, dtype=dtype, kinds=("attn",))
            for i in range(n_prefix)
        ]
    if reps:
        rk = jax.random.split(ks[3], reps)
        units = [_init_unit(rk[i], cfg, use_moe=cfg.moe is not None,
                            dtype=dtype) for i in range(reps)]
        params["stack"] = jax.tree.map(lambda *xs: jnp.stack(xs), *units)
    if tail:
        params["tail"] = _init_unit(ks[4], cfg, use_moe=cfg.moe is not None,
                                    dtype=dtype, kinds=tail)
    if cfg.is_encdec:
        from repro.models.encdec import init_encoder
        params["encoder"] = init_encoder(ks[5], cfg, dtype)
    return params


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------
def _init_sub_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                    dtype):
    if kind in ("attn", "local"):
        return attn.init_kv_cache(cfg, batch, max_len, dtype)
    if kind == "ssm":
        return ssm_mod.init_ssm_state(cfg, batch, dtype)
    if kind == "rglru":
        return rglru_mod.init_rglru_state(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32,
               *, stacked: bool = True):
    """Model KV/state cache.

    ``stacked=True`` stacks the repeated-unit caches with a leading reps dim
    (scan-over-layers; prefill/training).  ``stacked=False`` keeps one
    buffer per layer ("units" list) — the serving layout, which lets XLA
    alias each donated buffer through the decode step's in-place update
    instead of double-buffering the whole cache through a scan.
    """
    n_prefix, reps, tail = layout(cfg)
    kinds = unit_kinds(cfg)
    cache: Dict[str, Any] = {}
    if n_prefix:
        cache["prefix"] = [
            [_init_sub_cache(cfg, "attn", batch, max_len, dtype)]
            for _ in range(n_prefix)
        ]
    if reps:
        unit = [_init_sub_cache(cfg, k, batch, max_len, dtype) for k in kinds]
        if stacked:
            cache["stack"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (reps, *x.shape)).copy(),
                unit)
        else:
            cache["units"] = [
                [_init_sub_cache(cfg, k, batch, max_len, dtype)
                 for k in kinds]
                for _ in range(reps)
            ]
    if tail:
        cache["tail"] = [_init_sub_cache(cfg, k, batch, max_len, dtype)
                         for k in tail]
    return cache


def restack_cache(cfg: ModelConfig, cache):
    """Convert an unstacked ("units") cache to the stacked layout."""
    if "units" not in cache:
        return cache
    out = {k: v for k, v in cache.items() if k != "units"}
    out["stack"] = jax.tree.map(lambda *xs: jnp.stack(xs), *cache["units"])
    return out


def unstack_cache(cfg: ModelConfig, cache):
    """Convert a stacked cache to the serving ("units") layout."""
    if "stack" not in cache:
        return cache
    reps = layout(cfg)[1]
    out = {k: v for k, v in cache.items() if k != "stack"}
    out["units"] = [jax.tree.map(lambda t: t[i], cache["stack"])
                    for i in range(reps)]
    return out


def unstack_params(cfg: ModelConfig, params):
    """Serving layout for params: per-layer weight trees instead of one
    stacked tensor per weight.  Keeps each layer's weights a separate
    buffer so per-step streaming reads exactly one layer (XLA cannot hoist
    a whole-stack convert/copy in front of the layer loop)."""
    if "stack" not in params:
        return params
    reps = layout(cfg)[1]
    out = {k: v for k, v in params.items() if k != "stack"}
    out["units"] = [jax.tree.map(lambda t: t[i], params["stack"])
                    for i in range(reps)]
    return out


def init_tree_caches(cfg: ModelConfig, batch: int, capacity: int,
                     dtype=jnp.float32):
    """Tree (level-2) KV caches; attention sub-layers only."""
    assert cfg.family not in ("ssm",), "tree cache is attention-only"
    n_prefix, reps, tail = layout(cfg)
    kinds = unit_kinds(cfg)
    tc: Dict[str, Any] = {}

    def sub(kind):
        if kind in ("attn", "local"):
            return attn.init_tree_cache(cfg, batch, capacity, dtype)
        return None

    if n_prefix:
        tc["prefix"] = [[sub("attn")] for _ in range(n_prefix)]
    if reps:
        unit = [sub(k) for k in kinds]
        tc["stack"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (reps, *x.shape)).copy(), unit)
    if tail:
        tc["tail"] = [sub(k) for k in tail]
    return tc


# --------------------------------------------------------------------------
# activation sharding (Megatron-style sequence parallelism)
# --------------------------------------------------------------------------
# When set (by the launcher) to a NamedSharding over [B, S, d], the residual
# stream is constrained to it between layers — sharding the *sequence* dim
# over the "model" axis so per-device activation carries shrink by the model
# axis size.  XLA converts the surrounding all-reduces into
# reduce-scatter + all-gather pairs (same volume, less live memory).
_ACTIVATION_SHARDING = None
_SCAN_UNROLL = 1  # >1 unrolls the layer scan (exact cost_analysis accounting)


def set_activation_sharding(sharding) -> None:
    global _ACTIVATION_SHARDING
    _ACTIVATION_SHARDING = sharding


def set_scan_unroll(n: int) -> None:
    global _SCAN_UNROLL
    _SCAN_UNROLL = max(1, int(n))


def _constrain(x):
    if _ACTIVATION_SHARDING is not None and x.ndim == 3:
        return jax.lax.with_sharding_constraint(x, _ACTIVATION_SHARDING)
    return x


# --------------------------------------------------------------------------
# sub-layer application
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Ctx:
    """Static + traced context threaded through the layers."""
    mode: str                       # full | decode | tree | chunk
    positions: Any                  # [B,S] absolute positions
    cache_len: Any = None           # committed tokens: scalar (decode) or
                                    # per-row [B] (tree mode); in chunk
                                    # mode the per-row chunk start offsets
    tree_write_index: Any = None    # [B] per-row tree buffer write offsets
    tree_mask: Any = None           # [B, n, Tcap] per-row ancestor masks
    enc_kv: Any = None              # per-layer (k, v) list for cross-attn
    enc_kv_idx: int = 0
    window_override: int = -1       # -1: use config default per kind
    causal: bool = True
    remat: bool = False             # checkpoint the scan body (training)


def _window(cfg: ModelConfig, kind: str, ctx: Ctx) -> int:
    if ctx.window_override >= 0:
        return ctx.window_override
    if kind == "local":
        return cfg.rglru.window
    return cfg.sliding_window


def _apply_sublayer(p, cfg: ModelConfig, kind: str, x, cache, tree_cache,
                    ctx: Ctx, enc_kv=None):
    """Returns (x, new_cache, new_tree_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    win = _window(cfg, kind, ctx)
    if kind in ("attn", "local"):
        if ctx.mode == "full":
            y, cache = attn.attn_forward(
                p["mixer"], cfg, h, ctx.positions, window=win, cache=cache,
                cache_index=0, causal=ctx.causal)
        elif ctx.mode == "decode":
            y, cache = attn.attn_decode(
                p["mixer"], cfg, h, ctx.positions[:, 0], cache, ctx.cache_len,
                window=win)
        elif ctx.mode == "chunk":
            y, cache = attn.attn_prefill_chunk(
                p["mixer"], cfg, h, ctx.positions, cache, ctx.cache_len,
                window=win)
        else:  # tree
            y, tree_cache = attn.attn_tree_verify(
                p["mixer"], cfg, h, ctx.positions, model_cache=cache,
                model_len=ctx.cache_len, tree_cache=tree_cache,
                tree_write_index=ctx.tree_write_index,
                tree_mask=ctx.tree_mask, window=win)
            cache = None  # model cache is read-only here; don't re-emit it
    elif kind == "ssm":
        if ctx.mode == "chunk":
            raise NotImplementedError(
                "chunked prefill through an ssm sub-layer is undefined "
                "(no mid-sequence recurrent re-entry); recurrent "
                "architectures keep the whole-prompt prefill path")
        if ctx.mode == "tree":
            # a width-w tree layer has no single recurrent successor state;
            # recurrent architectures speculate in chain-mode instead
            # (core/chain.py) — fail loudly rather than decode garbage
            raise NotImplementedError(
                "tree-verify through an ssm sub-layer is undefined; use "
                "chain-mode speculation (repro.core.chain) for recurrent "
                "architectures")
        if ctx.mode == "full":
            # full mode is always a from-scratch prefill (positions start at
            # 0), so the SSD scan must seed from the zero state — a recycled
            # KV-arena slot's ``cache["ssd"]`` holds the PREVIOUS occupant's
            # final recurrent state and must never leak into the new
            # request (tests/test_serving_db.py pins fresh == recycled).
            y, state = ssm_mod.ssm_forward(p["mixer"], cfg, h)
            cache = state if cache is not None else None
        else:  # decode
            y, cache = ssm_mod.ssm_decode(p["mixer"], cfg, h, cache)
    elif kind == "rglru":
        if ctx.mode == "chunk":
            raise NotImplementedError(
                "chunked prefill through an rglru sub-layer is undefined "
                "(no mid-sequence recurrent re-entry); recurrent "
                "architectures keep the whole-prompt prefill path")
        if ctx.mode == "tree":
            raise NotImplementedError(
                "tree-verify through an rglru sub-layer is undefined; use "
                "chain-mode speculation (repro.core.chain) for recurrent "
                "architectures")
        if ctx.mode == "full":
            # like the ssm branch: prefill starts the recurrence from the
            # zero state (no ``state=`` seed), so recycled slots are clean
            y, state = rglru_mod.rglru_forward(p["mixer"], cfg, h)
            cache = state if cache is not None else None
        else:
            y, cache = rglru_mod.rglru_decode(p["mixer"], cfg, h, cache)
    else:
        raise ValueError(kind)
    x = x + y

    if "cross" in p and enc_kv is not None:
        hc = rmsnorm(p["cross_norm"], x, cfg.norm_eps)
        x = x + attn.cross_attn_forward(p["cross"], cfg, hc, enc_kv)

    if "ffn" in p:
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        if cfg.moe is not None and "router" in p["ffn"]:
            y2, aux = moe_mod.moe_forward(p["ffn"], cfg, h2)
        else:
            y2 = mlp(p["ffn"], h2, cfg.mlp_variant)
        x = x + y2
    return x, cache, tree_cache, aux


def _apply_unit(unit_p, cfg: ModelConfig, kinds, x, unit_cache, unit_tcache,
                ctx: Ctx, enc_kv_list=None):
    new_cache, new_tcache = [], []
    aux_total = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(kinds):
        c = unit_cache[i] if unit_cache is not None else None
        tc = unit_tcache[i] if unit_tcache is not None else None
        ekv = None
        if enc_kv_list is not None and kind in ("attn", "local"):
            ekv = enc_kv_list[i]
        x, c, tc, aux = _apply_sublayer(unit_p[i], cfg, kind, x, c, tc, ctx,
                                        enc_kv=ekv)
        new_cache.append(c)
        new_tcache.append(tc)
        aux_total = aux_total + aux
    return x, new_cache, new_tcache, aux_total


# --------------------------------------------------------------------------
# whole-model application
# --------------------------------------------------------------------------
def _run_layers(params, cfg: ModelConfig, x, cache, tcache, ctx: Ctx,
                enc_out=None):
    n_prefix, reps, tail = layout(cfg)
    kinds = unit_kinds(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    new_tcache: Dict[str, Any] = {}

    enc_kv = None
    if enc_out is not None:
        # precompute per-unit cross KV lazily inside the scan is not possible
        # with stacked params; compute per sub-layer outside for prefix/tail
        # and inside the scan body for the stack (cheap einsums).
        enc_kv = enc_out

    def get(c, key):
        return None if c is None else c.get(key)

    if n_prefix:
        pc, ptc = [], []
        for i in range(n_prefix):
            x, c, tc, aux = _apply_unit(
                params["prefix"][i], cfg, ("attn",), x,
                get(cache, "prefix")[i] if cache else None,
                get(tcache, "prefix")[i] if tcache else None, ctx,
                enc_kv_list=None)
            pc.append(c)
            ptc.append(tc)
            aux_total = aux_total + aux
        new_cache["prefix"], new_tcache["prefix"] = pc, ptc

    if reps:
        stack_p = params.get("stack")
        units_p = params.get("units")
        stack_c = get(cache, "stack")
        stack_tc = get(tcache, "stack")

        def _unit_ekv(unit_p):
            if enc_kv is None:
                return None
            return [
                attn.encode_cross_kv(unit_p[i]["cross"], cfg, enc_kv)
                if kinds[i] in ("attn", "local") and "cross" in unit_p[i]
                else None
                for i in range(len(kinds))
            ]

        units_c = get(cache, "units")
        if units_c is not None:
            # Serving layout: one buffer per layer, unrolled loop — each
            # donated buffer is updated in place (no scan double-buffer).
            new_units = []
            for i in range(reps):
                unit_p = (units_p[i] if units_p is not None
                          else jax.tree.map(lambda t: t[i], stack_p))
                x, nc, _, aux = _apply_unit(unit_p, cfg, kinds, x,
                                            units_c[i], None, ctx,
                                            enc_kv_list=_unit_ekv(unit_p))
                aux_total = aux_total + aux
                new_units.append(nc)
            new_cache["units"], new_tcache["units"] = new_units, None
            cache_done = True
        else:
            cache_done = False
            assert stack_p is not None, \
                "unstacked params require the serving (units) cache layout"

        def body(carry, xs):
            xh, auxc = carry
            unit_p, unit_c, unit_tc = xs
            xh = _constrain(xh)
            xh, nc, ntc, aux = _apply_unit(unit_p, cfg, kinds, xh, unit_c,
                                           unit_tc, ctx,
                                           enc_kv_list=_unit_ekv(unit_p))
            xh = _constrain(xh)
            return (xh, auxc + aux), (nc, ntc)

        if not cache_done:
            scan_body = jax.checkpoint(body) if ctx.remat else body
            (x, aux_total), (sc, stc) = jax.lax.scan(
                scan_body, (x, aux_total),
                (stack_p, stack_c, stack_tc),
                unroll=min(_SCAN_UNROLL, reps))
            new_cache["stack"], new_tcache["stack"] = sc, stc

    if tail:
        x, tcch, ttc, aux = _apply_unit(
            params["tail"], cfg, tail, x,
            get(cache, "tail") if cache else None,
            get(tcache, "tail") if tcache else None, ctx, enc_kv_list=None)
        new_cache["tail"], new_tcache["tail"] = tcch, ttc
        aux_total = aux_total + aux

    return x, (new_cache if cache is not None else None), \
        (new_tcache if tcache is not None else None), aux_total


def _logits(params, cfg: ModelConfig, x):
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        return unembed(params["embed"], x)
    return unembed(params["lm_head"], x)


def _embed_inputs(params, cfg: ModelConfig, tokens, prefix_embeds=None):
    x = embed(params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return x


# -- public API --------------------------------------------------------------
def forward(params, cfg: ModelConfig, tokens, *, prefix_embeds=None,
            enc_out=None, window_override: int = -1, remat: bool = False):
    """Training forward: logits [B, S(+P), V] and MoE aux loss."""
    x = _embed_inputs(params, cfg, tokens, prefix_embeds)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    ctx = Ctx(mode="full", positions=positions,
              window_override=window_override, remat=remat)
    x, _, _, aux = _run_layers(params, cfg, x, None, None, ctx,
                               enc_out=enc_out)
    return _logits(params, cfg, x), aux


def prefill(params, cfg: ModelConfig, tokens, cache, *, prefix_embeds=None,
            enc_out=None, window_override: int = -1):
    """Fill the model cache; returns (last-position logits [B,V], cache)."""
    x = _embed_inputs(params, cfg, tokens, prefix_embeds)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    ctx = Ctx(mode="full", positions=positions, cache_len=0,
              window_override=window_override)
    x, cache, _, _ = _run_layers(params, cfg, x, cache, None, ctx,
                                 enc_out=enc_out)
    return _logits(params, cfg, x[:, -1]), cache


def prefill_chunk(params, cfg: ModelConfig, tokens, cache, chunk_start, *,
                  window_override: int = -1):
    """Fill the model cache with ONE chunk of a longer prompt (chunked
    prefill-in-ring): row b's ``tokens[b]`` occupy absolute positions
    ``[chunk_start[b], chunk_start[b] + s)``.  Chunks must be fed in
    order; each chunk attends over the cache rows earlier chunks already
    wrote (bit-identical to a one-shot ``prefill`` — see
    ``attention.attn_prefill_chunk``).  Returns (logits [B, s, V], cache)
    — ALL chunk positions' logits, so the caller picks the last valid
    prompt position of the final chunk for the next-token prediction.
    """
    x = embed(params["embed"], tokens)
    b, s, _ = x.shape
    chunk_start = jnp.broadcast_to(
        jnp.asarray(chunk_start, jnp.int32).reshape(-1), (b,))
    positions = chunk_start[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
    ctx = Ctx(mode="chunk", positions=positions, cache_len=chunk_start,
              window_override=window_override)
    x, cache, _, _ = _run_layers(params, cfg, x, cache, None, ctx)
    return _logits(params, cfg, x), cache


def decode_step(params, cfg: ModelConfig, token, cache, cache_len, *,
                enc_out=None, window_override: int = -1):
    """token [B] -> (logits [B,V], cache). Writes at position cache_len."""
    x = embed(params["embed"], token[:, None])
    b = x.shape[0]
    positions = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32)[None, None], (b, 1))
    ctx = Ctx(mode="decode", positions=positions, cache_len=cache_len,
              window_override=window_override)
    x, cache, _, _ = _run_layers(params, cfg, x, cache, None, ctx,
                                 enc_out=enc_out)
    return _logits(params, cfg, x[:, 0]), cache


def tree_verify_step(params, cfg: ModelConfig, node_tokens, node_positions,
                     tree_mask, cache, cache_len, tree_caches,
                     tree_write_index, *, enc_out=None,
                     window_override: int = -1):
    """Verify one tree layer (PipeDec §3.4.2).

    node_tokens: [B, n] token ids of the new layer (padded);
    node_positions: [B, n] absolute positions;
    tree_mask: [B, n, Tcap] per-row ancestor mask vs the whole tree buffer
               (a single [n, Tcap] mask broadcasts over the batch);
    cache_len: [B] per-row committed-prefix length (scalar broadcasts);
    tree_write_index: [B] per-row tree-buffer write offset (scalar
               broadcasts).
    Rows are fully independent — SpecPipe-DB stacks every in-flight
    request's deepest layer here for ONE fused dispatch per timestep; the
    single-request engine is the B=1 case of the same code.
    Returns (logits [B, n, V], tree_caches).
    """
    b = node_tokens.shape[0]
    cache_len = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (b,))
    tree_write_index = jnp.broadcast_to(
        jnp.asarray(tree_write_index, jnp.int32).reshape(-1), (b,))
    if tree_mask.ndim == 2:
        tree_mask = tree_mask[None]
    tree_mask = jnp.broadcast_to(tree_mask, (b, *tree_mask.shape[1:]))
    x = embed(params["embed"], node_tokens)
    ctx = Ctx(mode="tree", positions=node_positions, cache_len=cache_len,
              tree_write_index=tree_write_index, tree_mask=tree_mask,
              window_override=window_override)
    x, _, tree_caches, _ = _run_layers(params, cfg, x, cache, tree_caches,
                                       ctx, enc_out=enc_out)
    return _logits(params, cfg, x), tree_caches


# distance of the cache "length" axis from the trailing axis, per buffer name
# (buffers may carry an extra leading `reps` dim when stacked for scan);
# k_scale/v_scale are the int8 layout's per-row scales [B, L, KV]
CACHE_LEN_AXIS_FROM_END = {"k": 3, "v": 3, "c_kv": 2, "k_rope": 2,
                           "k_scale": 2, "v_scale": 2}


def cache_len_axis(name: str, arr) -> int:
    return arr.ndim - CACHE_LEN_AXIS_FROM_END[name]


# --------------------------------------------------------------------------
# slot-stacked cache views (SpecPipe-DB KV arena)
# --------------------------------------------------------------------------
def _slot_axis(path) -> int:
    """Axis carrying the slot/batch dim of an arena buffer: stacked
    repeated-unit buffers ("stack") have a leading reps dim, so their slot
    axis is 1; prefix/tail/units buffers use axis 0.  Works for KV buffers
    and recurrent state dicts alike."""
    return 1 if path and getattr(path[0], "key", None) == "stack" else 0


def slice_cache_rows(cache, start: int, size: int):
    """Static slice of ``size`` slot rows starting at ``start`` from every
    buffer of a slot-stacked cache pytree (``None`` leaves pass through)."""

    def f(path, buf):
        if buf is None:
            return None
        if paging.is_paged(buf):
            # table slice, shared pool — O(1) view, no row gather
            return paging.slice_slots(buf, start, size)
        return jax.lax.slice_in_dim(buf, start, start + size,
                                    axis=_slot_axis(path))

    return jax.tree_util.tree_map_with_path(
        f, cache, is_leaf=lambda x: x is None or paging.is_paged(x))


def update_cache_rows(cache, rows, start: int = 0):
    """Write a row slice (as produced by ``slice_cache_rows``) back into the
    full slot-stacked cache pytree at slot ``start``."""

    def f(path, buf, upd):
        if buf is None:
            return None
        if paging.is_paged(buf):
            if paging.is_paged(upd):
                # a slice_slots view shares the full pool: its updated
                # pages ARE the updated arena — keep the full table
                return paging.adopt_pool(buf, upd)
            return paging.write_slot_rows(buf, upd, start)
        return jax.lax.dynamic_update_slice_in_dim(
            buf, upd.astype(buf.dtype), start, axis=_slot_axis(path))

    return jax.tree_util.tree_map_with_path(
        f, cache, rows, is_leaf=lambda x: x is None or paging.is_paged(x))


def where_cache_rows(on, new, old):
    """Per-slot select over slot-stacked cache pytrees: slot ``b`` of
    every buffer takes ``new`` where ``on[b]`` and keeps ``old``
    otherwise (``None`` leaves pass through).  Used by batched prefill
    paths that compute all slot rows but must only land the
    participating ones (e.g. the overlapped executor's in-tick draft
    prefill)."""
    on = jnp.asarray(on)

    def f(path, o, n):
        if o is None:
            return None
        if paging.is_paged(o):
            # block-granularity select through the shared table
            return paging.where_slots(on, n, o)
        shape = [1] * o.ndim
        shape[_slot_axis(path)] = on.shape[0]
        return jnp.where(on.reshape(shape), n.astype(o.dtype), o)

    return jax.tree_util.tree_map_with_path(
        f, old, new, is_leaf=lambda x: x is None or paging.is_paged(x))


def commit_tree_node(cfg: ModelConfig, cache, tree_caches, node_idx,
                     model_len):
    """Two-level cache sync (paper §3.4.3): move one verified tree node's KV
    from every tree cache into the model cache at position ``model_len``.

    Mapped over ``tree_caches`` first with its ``None`` entries (recurrent
    sub-layers have no tree cache) treated as leaves, so hybrid configs
    pass their state dicts through untouched.
    """

    def merge(path, tree_buf, model_buf):
        if tree_buf is None:
            return model_buf
        name = path[-1].key
        ax = cache_len_axis(name, model_buf)
        row = jax.lax.dynamic_slice_in_dim(tree_buf, node_idx, 1, axis=ax)
        return jax.lax.dynamic_update_slice_in_dim(
            model_buf, row.astype(model_buf.dtype), model_len, axis=ax)

    return jax.tree_util.tree_map_with_path(
        merge, tree_caches, cache, is_leaf=lambda x: x is None)


def _dense_node_rows(name, tree_buf, node_idx):
    """Per-row single-node gather from a dense tree buffer: row b takes its
    row ``node_idx[b]``, keeping the dense layout [*pre, B, 1, *post]."""
    ax = cache_len_axis(name, tree_buf)
    bx = ax - 1
    return jax.vmap(
        lambda tb, ni: jax.lax.dynamic_slice_in_dim(tb, ni, 1, axis=ax - 1),
        in_axes=(bx, 0), out_axes=bx)(tree_buf, node_idx)


def commit_tree_nodes(cfg: ModelConfig, cache, tree_caches, node_idx,
                      model_len, commit_mask=None):
    """Batched per-row two-level cache sync (SpecPipe-DB exit phase).

    Row b migrates its tree-cache row ``node_idx[b]`` into its model cache
    at position ``model_len[b]``.  Rows where ``commit_mask`` is False (no
    flight exiting this timestep) keep their caches bit-unchanged.  The
    batch axis of every buffer sits immediately before its length axis
    (``cache_len_axis``), which also holds for stacked (leading ``reps``
    dim) buffers.
    """
    node_idx = jnp.asarray(node_idx, jnp.int32).reshape(-1)
    model_len = jnp.asarray(model_len, jnp.int32).reshape(-1)

    def merge(path, tree_buf, model_buf):
        if tree_buf is None:
            return model_buf
        name = path[-1].key
        if paging.is_paged(model_buf):
            # paged commit: gather each row's verified node from the tree
            # pool, scatter it at ``model_len[b]`` through the model block
            # table — no dense materialisation of either buffer.
            row = (paging.take_len_rows(tree_buf, node_idx[:, None])
                   if paging.is_paged(tree_buf)
                   else _dense_node_rows(name, tree_buf, node_idx))
            return paging.write_len_rows(model_buf, row, model_len,
                                         on=commit_mask)
        if paging.is_paged(tree_buf):
            tree_buf = paging.to_dense(tree_buf)
        ax = cache_len_axis(name, model_buf)
        bx = ax - 1                    # batch axis precedes the length axis
        inner = ax - 1                 # length axis once batch is vmapped out

        def one(mb, tb, ni, ml):
            row = jax.lax.dynamic_slice_in_dim(tb, ni, 1, axis=inner)
            return jax.lax.dynamic_update_slice_in_dim(
                mb, row.astype(mb.dtype), ml, axis=inner)

        upd = jax.vmap(one, in_axes=(bx, bx, 0, 0), out_axes=bx)(
            model_buf, tree_buf, node_idx, model_len)
        if commit_mask is not None:
            sel_shape = [1] * model_buf.ndim
            sel_shape[bx] = commit_mask.shape[0]
            upd = jnp.where(jnp.asarray(commit_mask).reshape(sel_shape),
                            upd, model_buf)
        return upd

    return jax.tree_util.tree_map_with_path(
        merge, tree_caches, cache,
        is_leaf=lambda x: x is None or paging.is_paged(x))


def remap_tree_cache_rows(tree_caches, index_maps):
    """Batched post-prune tree-cache compaction (SpecPipe-DB exit phase).

    ``index_maps [B, cap]`` carries one old→new prune map per slot row
    (identity rows leave that slot's buffers bit-unchanged, so callers mix
    pruned and untouched slots in ONE gather).  Per slot the permutation
    is exactly ``core.speculative.remap_tree_caches``'s: dropped rows
    (``-1``) are pushed past the buffer end, then the inverse permutation
    gathers each surviving row to its compacted position.  Buffers may
    carry ``capacity + w`` rows (fixed-width layer-write slack) and a
    leading reps/stage dim — the length axis is resolved per buffer name,
    with the slot axis immediately before it (as in ``commit_tree_nodes``).
    """
    index_maps = jnp.asarray(index_maps, jnp.int32)

    def gather(path, buf):
        if buf is None:
            return None
        name = path[-1].key
        cap = buf.length if paging.is_paged(buf) else \
            buf.shape[cache_len_axis(name, buf)]
        im = jnp.concatenate([
            index_maps,
            jnp.full((index_maps.shape[0], cap - index_maps.shape[1]), -1,
                     jnp.int32)], axis=1)
        # inverse permutation per row: g[b, new] = old (dropped → the end)
        g = jnp.argsort(jnp.where(im >= 0, im, cap + jnp.arange(cap)[None]),
                        axis=1)
        if paging.is_paged(buf):
            # gather the permuted rows through the table, scatter them back
            # through the same table (the logical buffer is small — cap+w
            # rows — so the round-trip is the whole compaction)
            return paging.from_dense(buf, paging.take_len_rows(buf, g))
        ax = cache_len_axis(name, buf)
        bx = ax - 1                    # slot axis precedes the length axis
        return jax.vmap(lambda b, gi: jnp.take(b, gi, axis=ax - 1),
                        in_axes=(bx, 0), out_axes=bx)(buf, g)

    return jax.tree_util.tree_map_with_path(
        gather, tree_caches, is_leaf=lambda x: x is None or paging.is_paged(x))


def _hidden(params, cfg: ModelConfig, tokens, *, prefix_embeds=None,
            enc_out=None, window_override: int = -1, remat: bool = False):
    """Final-norm hidden states (pre-unembed) + MoE aux loss."""
    x = _embed_inputs(params, cfg, tokens, prefix_embeds)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    ctx = Ctx(mode="full", positions=positions,
              window_override=window_override, remat=remat)
    x, _, _, aux = _run_layers(params, cfg, x, None, None, ctx,
                               enc_out=enc_out)
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def chunked_ce(table, hidden, labels, *, chunk: int = 256) -> jnp.ndarray:
    """Streaming cross-entropy: never materialises [B, S, V] logits.

    The per-chunk body is rematerialised in backward, so peak memory is one
    [B, chunk, V] logits block instead of the whole sequence (the dominant
    temp for 150k-250k vocabularies).
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (s + pad) // chunk
    hs = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)   # [nc,B,chunk,d]
    ys = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, xs):
        hc, yc = xs
        logits = (hc @ table.T).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        safe = jnp.maximum(yc, 0)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        nll = jnp.where(yc >= 0, nll, 0.0)
        return carry + nll.sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ys))
    return total / (b * s)


def loss_fn(params, cfg: ModelConfig, tokens, labels, *, prefix_embeds=None,
            enc_out=None, remat: bool = False, window_override: int = -1,
            ce_chunk: int = 256):
    hidden, aux = _hidden(params, cfg, tokens, prefix_embeds=prefix_embeds,
                          enc_out=enc_out, remat=remat,
                          window_override=window_override)
    if prefix_embeds is not None:
        hidden = hidden[:, prefix_embeds.shape[1]:]
    table = params["embed"]["table"] if cfg.tie_embeddings \
        else params["lm_head"]["table"]
    ce = chunked_ce(table, hidden, labels, chunk=ce_chunk)
    if cfg.moe is not None:
        ce = ce + cfg.moe.router_aux_weight * aux
    return ce
