"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block = in-projections to two branches (x, y), short depthwise conv + RG-LRU
on the x branch, GeLU on the y branch, elementwise product, out-projection.

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a x_t),  i_t = sigmoid(W_x x_t)
    a_t = a^(c·r_t)          with  a = sigmoid(Λ),  c = 8
    h_t = a_t · h_{t-1} + sqrt(1 - a_t²) · (i_t ⊙ x_t)

The scan is a first-order linear recurrence — we use an associative scan in
log-space decays (TPU-friendly: O(log T) depth, no per-token HBM state dump).
Decode keeps (conv_state, h) per layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init

_C = 8.0


def _width(cfg: ModelConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def init_rglru(key, cfg: ModelConfig, dtype=jnp.float32):
    d, w = cfg.d_model, _width(cfg)
    k = cfg.rglru.d_conv
    ks = jax.random.split(key, 6)
    # Λ init so that a ∈ [0.9, 0.999] roughly (standard Griffin init)
    u = jax.random.uniform(ks[5], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(u ** (-1.0 / _C) - 1.0) * -1.0  # sigmoid(Λ)^c ≈ u
    return {
        "in_x": dense_init(ks[0], (d, w), dtype=dtype),
        "in_y": dense_init(ks[1], (d, w), dtype=dtype),
        "conv_w": dense_init(ks[2], (k, w), dtype=dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": dense_init(ks[3], (w, w), dtype=dtype),
        "w_i": dense_init(ks[4], (w, w), dtype=dtype),
        "lambda": lam.astype(jnp.float32),
        "out": dense_init(jax.random.fold_in(key, 7), (w, d), dtype=dtype),
    }


def _gates(params, x):
    """x: [..., w] -> (log_a, gated_input) both fp32."""
    r = jax.nn.sigmoid(x.astype(jnp.float32) @ params["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(x.astype(jnp.float32) @ params["w_i"].astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(-params["lambda"])  # log sigmoid(Λ)^(c·r)
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - a2, 1e-12)) * (i * x.astype(jnp.float32))
    return log_a, gated


def _conv_full(params, x):
    w = params["conv_w"]
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(k)) + params["conv_b"]


def rglru_scan(log_a, u, h0=None):
    """Associative scan of h_t = exp(log_a_t)·h_{t-1} + u_t over axis 1."""
    if h0 is not None:
        # fold initial state into the first input
        u = u.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)

    def combine(left, right):
        la, xa = left
        lb, xb = right
        return la + lb, jnp.exp(lb) * xa + xb

    _, h = jax.lax.associative_scan(combine, (log_a, u), axis=1)
    return h


def rglru_forward(params, cfg: ModelConfig, x_in, *, state=None):
    """x_in: [B,S,d] -> (out [B,S,d], new_state {conv, h})."""
    b, s, _ = x_in.shape
    xb = x_in @ params["in_x"]
    yb = jax.nn.gelu(x_in @ params["in_y"], approximate=True)
    k = params["conv_w"].shape[0]
    if state is not None:
        pad = jnp.concatenate([state["conv"], xb], axis=1)
        conv = sum(pad[:, i:i + s, :] * params["conv_w"][i]
                   for i in range(k)) + params["conv_b"]
        new_conv = pad[:, -(k - 1):]
    else:
        conv = _conv_full(params, xb)
        new_conv = xb[:, -(k - 1):] if s >= k - 1 else jnp.pad(
            xb, ((0, 0), (k - 1 - s, 0), (0, 0)))
    log_a, gated = _gates(params, conv)
    h0 = state["h"] if state is not None else None
    h = rglru_scan(log_a, gated, h0)
    out = (h.astype(x_in.dtype) * yb) @ params["out"]
    return out, {"conv": new_conv, "h": h[:, -1]}


def init_rglru_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    w, k = _width(cfg), cfg.rglru.d_conv
    return {"conv": jnp.zeros((batch, k - 1, w), dtype),
            "h": jnp.zeros((batch, w), jnp.float32)}


def rglru_decode(params, cfg: ModelConfig, x_in, state):
    """One-token step. x_in: [B,1,d] -> (y [B,1,d], state)."""
    xb = x_in[:, 0] @ params["in_x"]  # [B,w]
    yb = jax.nn.gelu(x_in[:, 0] @ params["in_y"], approximate=True)
    window = jnp.concatenate([state["conv"], xb[:, None]], axis=1)
    conv = jnp.einsum("bkc,kc->bc", window, params["conv_w"]) + params["conv_b"]
    log_a, gated = _gates(params, conv)
    h = jnp.exp(log_a) * state["h"] + gated
    out = (h.astype(x_in.dtype) * yb) @ params["out"]
    return out[:, None], {"conv": window[:, 1:], "h": h}
