"""Stub modality frontends (the single permitted carve-out).

The audio conv-codec (Whisper mel + conv1d×2) and the VLM vision encoder
(InternViT) are NOT implemented; instead these helpers produce deterministic
embeddings of the correct shape/dtype so that (a) smoke tests run end to end
and (b) ``input_specs()`` can hand ShapeDtypeStructs to the dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def audio_frames_spec(cfg: ModelConfig, batch: int):
    n = cfg.encoder.max_source_positions
    return jax.ShapeDtypeStruct((batch, n, cfg.d_model), jnp.float32)


def vision_prefix_spec(cfg: ModelConfig, batch: int):
    return jax.ShapeDtypeStruct((batch, cfg.prefix_tokens, cfg.d_model),
                                jnp.float32)


def stub_audio_frames(cfg: ModelConfig, batch: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    n = cfg.encoder.max_source_positions
    return jax.random.normal(key, (batch, n, cfg.d_model)) * 0.02


def stub_vision_prefix(cfg: ModelConfig, batch: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, (batch, cfg.prefix_tokens, cfg.d_model)) * 0.02
