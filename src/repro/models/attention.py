"""Attention: GQA / MQA / MLA / sliding-window, with KV caches and the
PipeDec two-level (model + tree) cache path.

Shapes follow the convention  x: [B, S, d_model],  q: [B, S, H, hd],
k/v: [B, S, KV, hd].  Masks are boolean, True = may attend, broadcastable to
[B, H, Sq, Sk].

Three entry points per layer:
  * ``attn_forward``      — full-sequence (training / prefill), optionally
                            filling a model KV cache.
  * ``attn_decode``       — one new token against a model KV cache.
  * ``attn_tree_verify``  — a tree layer of speculative tokens against
                            model cache + tree cache (paper Algorithm 1).
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.quant import dequantize_rows, quantize_rows
from repro.models import paging
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, dtype=jnp.float32, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    if cfg.mla is not None and not cross:
        m = cfg.mla
        ks = jax.random.split(key, 6)
        qd = m.qk_nope_head_dim + m.qk_rope_head_dim
        if m.q_lora_rank:
            q_p = {
                "w_dq": dense_init(ks[5], (d, m.q_lora_rank), dtype=dtype),
                "w_q": dense_init(ks[0], (m.q_lora_rank, h, qd), dtype=dtype),
            }
        else:
            q_p = {"w_q": dense_init(ks[0], (d, h, qd), dtype=dtype)}
        p = {
            **q_p,
            "w_dkv": dense_init(ks[1], (d, m.kv_lora_rank), dtype=dtype),
            "w_kr": dense_init(ks[2], (d, m.qk_rope_head_dim), dtype=dtype),
            "w_ukv": dense_init(
                ks[3], (m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim),
                dtype=dtype),
            "w_o": dense_init(ks[4], (h, m.v_head_dim, d), in_axis=1, dtype=dtype),
        }
        return p
    ks = jax.random.split(key, 4)
    p = {
        "w_q": dense_init(ks[0], (d, h, hd), dtype=dtype),
        "w_k": dense_init(ks[1], (d, kv, hd), dtype=dtype),
        "w_v": dense_init(ks[2], (d, kv, hd), dtype=dtype),
        "w_o": dense_init(ks[3], (h, hd, d), in_axis=1, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((h, hd), dtype)
        p["b_k"] = jnp.zeros((kv, hd), dtype)
        p["b_v"] = jnp.zeros((kv, hd), dtype)
    return p


# --------------------------------------------------------------------------
# core
# --------------------------------------------------------------------------
def _proj(x, w, eq: str):
    """Projection einsum that also accepts a quantized ``{"q8", "scale"}``
    weight (int8 values, per-out-channel scales).  The quantized layout
    encodes the contraction split itself (leading ``q8.ndim - scale.ndim``
    axes contract), so the einsum spec only drives the fp32 path."""
    if isinstance(w, dict) and "q8" in w:
        from repro.kernels import ops as kops
        return kops.quant_matmul(x, w)
    return jnp.einsum(eq, x, w)


def _project_qkv(params, cfg: ModelConfig, x, positions, rope: bool = True):
    q = _proj(x, params["w_q"], "bsd,dhk->bshk")
    k = _proj(x, params["w_k"], "bsd,dhk->bshk")
    v = _proj(x, params["w_v"], "bsd,dhk->bshk")
    if "b_q" in params:
        q = q + params["b_q"]
        k = k + params["b_k"]
        v = v + params["b_v"]
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _project_q_mla(params, cfg: ModelConfig, x, positions):
    m = cfg.mla
    if "w_dq" in params:
        x = x @ params["w_dq"]
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_ckv_mla(params, cfg: ModelConfig, x, positions):
    """Compressed KV for MLA: c_kv [B,S,r], k_rope [B,S,rd] (single head)."""
    m = cfg.mla
    c_kv = x @ params["w_dkv"]
    k_rope = x @ params["w_kr"]
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope


def _expand_ckv(params, cfg: ModelConfig, c_kv):
    """Expand compressed KV into per-head k_nope and v."""
    m = cfg.mla
    kv = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_ukv"])
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    return k_nope, v


def gqa_attend(q, k, v, mask, *, scale: Optional[float] = None):
    """q: [B,Sq,H,hd], k/v: [B,Sk,KV,hd] — grouped-query attention.

    KV heads are *not* materialised to H (a ``jnp.repeat`` would stream
    rep× the KV cache from HBM; §Perf H3): q is grouped [KV, rep] and both
    einsums contract against the shared KV head directly.
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qg = q.reshape(b, sq, kvh, rep, hd)
    logits = jnp.einsum("bqgrk,bsgk->bgrqs", qg, k).astype(jnp.float32) * scale
    if mask is not None:
        # mask: [B|1, 1, Sq, Sk] -> broadcast over (g, r)
        logits = jnp.where(mask[:, :, None], logits,
                           jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgrqs,bsgk->bqgrk", probs, v)
    return out.reshape(b, sq, h, v.shape[-1])


# full-sequence attention switches to the chunked (memory-efficient) path
# at this sequence length: logits temps become [B, H, CHUNK, S] instead of
# [B, H, S, S].
CHUNKED_ATTN_THRESHOLD = 2048
CHUNK_Q = 1024


def chunked_causal_attend(q, k, v, *, window: int = 0, scale=None):
    """Causal attention via lax.scan over query chunks (+remat): identical
    math to ``gqa_attend`` with a causal mask, O(S·chunk) temp memory."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    cq = min(CHUNK_Q, s)
    pad = (-s) % cq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = (s + pad) // cq
    qs = q.reshape(b, nq, cq, h, hd).swapaxes(0, 1)  # [nq,B,cq,H,hd]

    kpos = jnp.arange(s)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(_, xs):
        qc, ci = xs
        qpos = ci * cq + jnp.arange(cq)
        m = kpos[None, :] <= qpos[:, None]
        if window:
            m &= kpos[None, :] > qpos[:, None] - window
        qg = qc.reshape(b, cq, kvh, rep, hd)  # grouped GQA: no KV repeat
        logits = jnp.einsum("bqgrk,bsgk->bgrqs", qg, k).astype(jnp.float32)
        logits = jnp.where(m[None, None, None], logits * scale,
                           jnp.finfo(jnp.float32).min)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bgrqs,bsgk->bqgrk", probs, v)
        return None, out.reshape(b, cq, h, v.shape[-1])

    _, outs = jax.lax.scan(body, None,
                           (qs, jnp.arange(nq, dtype=jnp.int32)))
    hd_v = v.shape[-1]  # MLA: v head dim may differ from qk head dim
    out = outs.swapaxes(0, 1).reshape(b, s + pad, h, hd_v)
    return out[:, :s]


def causal_mask(sq: int, sk: int, q_offset, window: int = 0):
    """q position i (absolute q_offset+i) attends k position j if j<=i, and
    within ``window`` if window>0."""
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m[None, None]  # [1,1,Sq,Sk]


# --------------------------------------------------------------------------
# model KV cache
# --------------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    hd, kv = cfg.resolved_head_dim, cfg.num_kv_heads
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
        }
    if getattr(cfg, "quant", "") == "int8":
        # int8 serving layout: values are per-row symmetric int8 with one
        # fp32 scale per (position, kv-head) row — ``dtype`` is ignored
        # (the layout is fixed by the quantization scheme).
        return {
            "k": jnp.zeros((batch, max_len, kv, hd), jnp.int8),
            "v": jnp.zeros((batch, max_len, kv, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, max_len, kv), jnp.float32),
            "v_scale": jnp.zeros((batch, max_len, kv), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, kv, hd), dtype),
    }


def _kv_updates(cache, k_new, v_new):
    """Build the updates dict for a K/V cache write.  Quantized caches
    (detected by the ``k_scale`` leaf) quantize the fresh rows here so the
    int8 values AND their scales land in the same write — ``_cache_write``
    only returns names present in ``updates``."""
    if "k_scale" in cache:
        kq, ks = quantize_rows(k_new)
        vq, vs = quantize_rows(v_new)
        return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    return {"k": k_new, "v": v_new}


def _raw(buf):
    """Densify one cache leaf if it is block-paged (``models.paging``)."""
    return paging.to_dense(buf) if paging.is_paged(buf) else buf


def _kv_read(cache, name):
    """Read K or V from a cache, dequantizing int8 layouts to fp32.
    Paged leaves are gathered to their dense view through the block
    table (bit-exact round-trip; see ``models.paging``)."""
    if "k_scale" in cache:
        return dequantize_rows(_raw(cache[name]),
                               _raw(cache[name + "_scale"]))
    return _raw(cache[name])


def _cache_max_len(cache, cfg: ModelConfig) -> int:
    buf = cache["c_kv"] if cfg.mla is not None else cache["k"]
    return buf.length if paging.is_paged(buf) else buf.shape[1]


def _cache_write(cache, updates, index):
    out = {}
    for name, u in updates.items():
        buf = cache[name]
        if paging.is_paged(buf):
            starts = jnp.broadcast_to(
                jnp.asarray(index, jnp.int32).reshape(()), (buf.slots,))
            out[name] = paging.write_len_rows(buf, u, starts)
            continue
        out[name] = jax.lax.dynamic_update_slice_in_dim(
            buf, u.astype(buf.dtype), index, axis=1)
    return out


def _cache_write_rows(cache, updates, indices):
    """Per-row cache write: row b of every update is written at its own
    offset ``indices[b]`` (SpecPipe-DB fused dispatch — every in-flight
    request's tree layer lands at that request's ``layer_start``)."""
    indices = jnp.asarray(indices, jnp.int32)
    out = {}
    for name, u in updates.items():
        buf = cache[name]
        if paging.is_paged(buf):
            out[name] = paging.write_len_rows(buf, u, indices)
            continue

        def write_row(b, u_row, i):
            return jax.lax.dynamic_update_slice_in_dim(
                b, u_row.astype(b.dtype), i, axis=0)

        out[name] = jax.vmap(write_row)(buf, u, indices)
    return out


def _cache_write_rows_at(cache, updates, starts, *, on=None):
    """Per-row contiguous cache write with DROP semantics at the buffer
    edge: row b's ``n`` update rows land at positions
    ``[starts[b], starts[b]+n)``; out-of-range positions — and whole rows
    where ``on[b]`` is False — are dropped, never clamped.  This is the
    chunked-prefill write: a final chunk whose fixed-width window overruns
    ``max_len`` must not clobber live rows, which the clamping
    ``dynamic_update_slice`` of ``_cache_write_rows`` would."""
    starts = jnp.asarray(starts, jnp.int32).reshape(-1)
    out = {}
    for name, u in updates.items():
        buf = cache[name]
        if paging.is_paged(buf):
            out[name] = paging.write_len_rows(buf, u, starts, on=on)
            continue
        b, max_len = buf.shape[0], buf.shape[1]
        n = u.shape[1]
        pos = starts[:, None] + jnp.arange(n, dtype=jnp.int32)[None]
        if on is not None:
            pos = jnp.where(jnp.asarray(on).reshape(-1, 1), pos, max_len)
        out[name] = buf.at[jnp.arange(b)[:, None], pos].set(
            u.astype(buf.dtype), mode="drop")
    return out


def _pool_kv(buf):
    """Blocked kernel layout of a paged K/V leaf: pool rows
    [N_rows, KV, hd] -> [Nb, KV, page, hd] so each grid step's BlockSpec
    picks one physical block through the block-table prefetch ref."""
    nb = buf.pages.shape[0] // buf.page
    return buf.pages.reshape(nb, buf.page,
                             *buf.pages.shape[1:]).swapaxes(1, 2)


def _pool_scales(buf):
    """Blocked kernel layout of a paged per-row scale leaf:
    [N_rows, KV] -> [Nb, KV, page]."""
    nb = buf.pages.shape[0] // buf.page
    return buf.pages.reshape(nb, buf.page, -1).swapaxes(1, 2)


# --------------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------------
def attn_forward(params, cfg: ModelConfig, x, positions, *,
                 window: int = 0, cache=None, cache_index: int = 0,
                 causal: bool = True):
    """Full-sequence attention. Returns (out, new_cache_or_None)."""
    b, s, _ = x.shape
    if cfg.mla is not None:
        q_nope, q_rope = _project_q_mla(params, cfg, x, positions)
        c_kv, k_rope = _project_ckv_mla(params, cfg, x, positions)
        new_cache = None
        if cache is not None:
            new_cache = _cache_write(cache, {"c_kv": c_kv, "k_rope": k_rope},
                                     cache_index)
        k_nope, v = _expand_ckv(params, cfg, c_kv)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (*k_nope.shape[:3], k_rope.shape[-1]))],
            axis=-1)
        scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
        if causal and s >= CHUNKED_ATTN_THRESHOLD:
            out = chunked_causal_attend(q, k, v, window=window, scale=scale)
        else:
            mask = causal_mask(s, s, 0, window) if causal else None
            out = gqa_attend(q, k, v, mask, scale=scale)
        y = jnp.einsum("bqhk,hkd->bqd", out, params["w_o"])
        return y, new_cache
    q, k, v = _project_qkv(params, cfg, x, positions)
    new_cache = None
    if cache is not None:
        new_cache = _cache_write(cache, _kv_updates(cache, k, v), cache_index)
        if "k_scale" in cache:
            # int8 serving layout: attend over the same quantize ->
            # dequantize round-trip the cache keeps.  Chunked
            # prefill-in-ring can only read prompt rows back from the
            # int8 cache, so one-shot prefill must see the identical
            # (lossy) values for the two paths to stay bit-identical.
            k = dequantize_rows(*quantize_rows(k))
            v = dequantize_rows(*quantize_rows(v))
    if causal and s >= CHUNKED_ATTN_THRESHOLD:
        out = chunked_causal_attend(q, k, v, window=window)
    else:
        mask = causal_mask(s, s, 0, window) if causal else None
        out = gqa_attend(q, k, v, mask)
    y = _proj(out, params["w_o"], "bqhk,hkd->bqd")
    return y, new_cache


# Absorbed MLA decode (DeepSeek-V2 §"matrix absorption"): attend in the
# compressed-KV space instead of expanding the cache to per-head K/V every
# step — HBM traffic per step drops from S·H·(d_nope+d_v) to S·kv_lora.
# Mathematically identical; disable with REPRO_MLA_ABSORBED=0 to measure
# the naive baseline (EXPERIMENTS.md §Perf H1).
MLA_ABSORBED_DECODE = os.environ.get("REPRO_MLA_ABSORBED", "1") != "0"

# Dispatch decode / tree-verify attention through the Pallas kernels
# (kernels/flash.py + kernels/tree_block.py).  Off by default on CPU: the
# kernels are TPU-targeted (interpret-mode on CPU is correct but slow) and
# single-device only (inside SPMD they would need shard_map manual mode).
USE_PALLAS_ATTN = os.environ.get("REPRO_USE_PALLAS_ATTN", "0") == "1"


def _mla_absorbed_attend(params, cfg: ModelConfig, q_nope, q_rope, cache,
                         valid):
    """q_*: [B,n,H,*]; cache holds c_kv [B,S,r] / k_rope [B,S,dr];
    valid: [B,1,n,S].  Returns attention output [B,n,H,dv]."""
    m = cfg.mla
    c_kv, k_rope = _raw(cache["c_kv"]), _raw(cache["k_rope"])
    w_uk = params["w_ukv"][..., :m.qk_nope_head_dim]   # [r,H,dn]
    w_uv = params["w_ukv"][..., m.qk_nope_head_dim:]   # [r,H,dv]
    q_eff = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)  # absorb W_uk into q
    lo = jnp.einsum("bqhr,bsr->bhqs", q_eff, c_kv) + \
        jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope)
    scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    lo = lo.astype(jnp.float32) * scale
    lo = jnp.where(valid, lo, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(lo, axis=-1).astype(c_kv.dtype)
    ctx = jnp.einsum("bhqs,bsr->bqhr", probs, c_kv)
    return jnp.einsum("bqhr,rhd->bqhd", ctx, w_uv)


def attn_decode(params, cfg: ModelConfig, x, position, cache, cache_len, *,
                window: int = 0):
    """One-step decode: x [B,1,d], position [B] absolute position of the new
    token; cache holds ``cache_len`` valid entries (new token written at
    ``cache_len``).  Returns (out [B,1,d], new_cache)."""
    b = x.shape[0]
    positions = position[:, None]  # [B,1]
    max_len = _cache_max_len(cache, cfg)
    kpos = jnp.arange(max_len)[None, None, None, :]
    valid = kpos <= positions[:, None, None, :]
    if window:
        valid &= kpos > positions[:, None, None, :] - window
    if cfg.mla is not None:
        q_nope, q_rope = _project_q_mla(params, cfg, x, positions)
        c_kv, k_rope = _project_ckv_mla(params, cfg, x, positions)
        cache = _cache_write(cache, {"c_kv": c_kv, "k_rope": k_rope}, cache_len)
        if MLA_ABSORBED_DECODE:
            out = _mla_absorbed_attend(params, cfg, q_nope, q_rope, cache,
                                       valid)
        else:
            k_nope, v = _expand_ckv(params, cfg, cache["c_kv"])
            q = jnp.concatenate([q_nope, q_rope], axis=-1)
            kr = cache["k_rope"]
            k = jnp.concatenate(
                [k_nope, jnp.broadcast_to(kr[:, :, None, :],
                                          (*k_nope.shape[:3],
                                           kr.shape[-1]))],
                axis=-1)
            scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
            out = gqa_attend(q, k, v, valid, scale=scale)
    else:
        q, k_new, v_new = _project_qkv(params, cfg, x, positions)
        cache = _cache_write(cache, _kv_updates(cache, k_new, v_new),
                             cache_len)
        if USE_PALLAS_ATTN and paging.is_paged(cache["k"]):
            # paged kernel: K/V stay in their block pools; the per-slot
            # block table rides the kernel as a scalar-prefetch ref.
            from repro.kernels import ops as kops
            qkw = {}
            if "k_scale" in cache:
                qkw = dict(k_scale=_pool_scales(cache["k_scale"]),
                           v_scale=_pool_scales(cache["v_scale"]))
            out = kops.paged_decode_attention(
                q.swapaxes(1, 2), _pool_kv(cache["k"]), _pool_kv(cache["v"]),
                cache["k"].table, position + 1,
                window=window, **qkw).swapaxes(1, 2)
        elif USE_PALLAS_ATTN:
            from repro.kernels import ops as kops
            qkw = {}
            if "k_scale" in cache:
                qkw = dict(k_scale=cache["k_scale"].swapaxes(1, 2),
                           v_scale=cache["v_scale"].swapaxes(1, 2))
            out = kops.decode_attention(
                q.swapaxes(1, 2), cache["k"].swapaxes(1, 2),
                cache["v"].swapaxes(1, 2), position[0] + 1,
                window=window, **qkw).swapaxes(1, 2)
        else:
            out = gqa_attend(q, _kv_read(cache, "k"), _kv_read(cache, "v"),
                             valid)
    y = _proj(out, params["w_o"], "bqhk,hkd->bqd")
    return y, cache


def attn_prefill_chunk(params, cfg: ModelConfig, x, positions, cache,
                       chunk_start, *, window: int = 0, on=None):
    """One prefill *chunk* against the model cache (chunked prefill-in-ring).

    x: [B, s, d] hidden states of chunk rows whose absolute positions are
    ``positions[b, i] = chunk_start[b] + i``.  The chunk's K/V rows are
    written into the cache FIRST (drop semantics at the ``max_len`` edge,
    ``on[b]`` False rows untouched), then q attends decode-style over the
    WHOLE cache with the per-query bound ``kpos <= position`` — so valid
    keys are a contiguous prefix and everything past them is trailing
    masked padding, the only padding placement the bit-identity pins
    tolerate (head/middle insertion would change gemm reduction grouping).
    Chunk c > 0 sees chunks [0, c)'s rows already in the cache from earlier
    ticks; row projections are row-independent, so every cached row is
    bit-identical to a full one-shot prefill's.  Returns (out, cache).
    """
    b, s, _ = x.shape
    max_len = _cache_max_len(cache, cfg)
    kpos = jnp.arange(max_len)[None, None, None, :]
    valid = kpos <= positions[:, None, :, None]
    if window:
        valid &= kpos > positions[:, None, :, None] - window
    if cfg.mla is not None:
        q_nope, q_rope = _project_q_mla(params, cfg, x, positions)
        c_kv, k_rope = _project_ckv_mla(params, cfg, x, positions)
        cache = _cache_write_rows_at(cache, {"c_kv": c_kv, "k_rope": k_rope},
                                     chunk_start, on=on)
        k_nope, v = _expand_ckv(params, cfg, _raw(cache["c_kv"]))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        kr = _raw(cache["k_rope"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr[:, :, None, :],
                                      (*k_nope.shape[:3], kr.shape[-1]))],
            axis=-1)
        scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
        out = gqa_attend(q, k, v, valid, scale=scale)
    else:
        q, k_new, v_new = _project_qkv(params, cfg, x, positions)
        cache = _cache_write_rows_at(cache, _kv_updates(cache, k_new, v_new),
                                     chunk_start, on=on)
        out = gqa_attend(q, _kv_read(cache, "k"), _kv_read(cache, "v"),
                         valid)
    y = _proj(out, params["w_o"], "bqhk,hkd->bqd")
    return y, cache


# --------------------------------------------------------------------------
# PipeDec two-level cache path (paper §3.4.2, Algorithm 1)
# --------------------------------------------------------------------------
def init_tree_cache(cfg: ModelConfig, batch: int, capacity: int,
                    dtype=jnp.float32):
    """Speculative (tree) KV cache — level 2 of the two-level cache."""
    return init_kv_cache(cfg, batch, capacity, dtype)


def attn_tree_verify(params, cfg: ModelConfig, x, positions, *,
                     model_cache, model_len, tree_cache, tree_write_index,
                     tree_mask, window: int = 0):
    """Attention for one new tree layer (paper Algorithm 1).

    x:            [B, n, d]    hidden states of the new tree layer nodes
    positions:    [B, n]       absolute positions (model_len-1 + depth)
    model_cache:  committed-token KV; row b has ``model_len[b]`` valid rows
    model_len:    [B] int32    per-row committed-prefix bound
    tree_cache:   speculative KV; row b's layer written at
                  ``tree_write_index[b]``
    tree_write_index: [B] int32 per-row tree-buffer write offsets
    tree_mask:    [B, n, T_cap] bool — per-row ancestor mask of the new
                  nodes against the whole tree buffer (True = attend),
                  already includes self-attention of each node.
    Rows are independent, so the SpecPipe-DB fused dispatch stacks every
    in-flight request here and the single-request engine is the B=1 case.
    Returns (out [B,n,d], new_tree_cache).
    """
    b, n, _ = x.shape
    # -- past part: plain causal over committed tokens --------------------
    max_len = _cache_max_len(model_cache, cfg)
    kpos = jnp.arange(max_len)[None, None, None, :]
    mlen = jnp.asarray(model_len, jnp.int32).reshape(-1)
    # per-row bound: every committed token of THIS row is an ancestor
    past_valid = kpos < mlen[:, None, None, None]
    if window:
        past_valid = past_valid & (kpos > positions[:, None, :, None] - window)
    tcap = _cache_max_len(tree_cache, cfg)
    tmask = tree_mask[:, None]  # [B,1,n,Tcap]

    if cfg.mla is not None:
        q_nope, q_rope = _project_q_mla(params, cfg, x, positions)
        c_kv, k_rope = _project_ckv_mla(params, cfg, x, positions)
        tree_cache = _cache_write_rows(tree_cache,
                                       {"c_kv": c_kv, "k_rope": k_rope},
                                       tree_write_index)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)

        def expand(cache_part):
            k_nope, v = _expand_ckv(params, cfg, _raw(cache_part["c_kv"]))
            kr = _raw(cache_part["k_rope"])
            k = jnp.concatenate(
                [k_nope, jnp.broadcast_to(kr[:, :, None, :],
                                          (*k_nope.shape[:3], kr.shape[-1]))],
                axis=-1)
            return k, v

        k_past, v_past = expand(model_cache)
        k_tree, v_tree = expand(tree_cache)
        scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    else:
        q, k_new, v_new = _project_qkv(params, cfg, x, positions)
        tree_cache = _cache_write_rows(tree_cache,
                                       _kv_updates(tree_cache, k_new, v_new),
                                       tree_write_index)
        k_past, v_past = _kv_read(model_cache, "k"), _kv_read(model_cache, "v")
        k_tree, v_tree = _kv_read(tree_cache, "k"), _kv_read(tree_cache, "v")
        scale = None

    if USE_PALLAS_ATTN and cfg.mla is None and window == 0 and \
            paging.is_paged(model_cache["k"]):
        # paged two-kernel path: both halves gather K/V tiles through
        # their block tables (scalar-prefetch side refs), LSE-combined —
        # identical math to the joint softmax below, zero densification.
        from repro.kernels import ops as kops
        qkw = {}
        if "k_scale" in tree_cache:
            qkw = dict(k_scale=_pool_scales(model_cache["k_scale"]),
                       v_scale=_pool_scales(model_cache["v_scale"]),
                       kt_scale=_pool_scales(tree_cache["k_scale"]),
                       vt_scale=_pool_scales(tree_cache["v_scale"]))
        out = kops.paged_tree_attention(
            q.swapaxes(1, 2),
            _pool_kv(model_cache["k"]), _pool_kv(model_cache["v"]),
            model_cache["k"].table,
            _pool_kv(tree_cache["k"]), _pool_kv(tree_cache["v"]),
            tree_cache["k"].table,
            tree_mask, mlen, **qkw).swapaxes(1, 2)
        y = _proj(out, params["w_o"], "bqhk,hkd->bqd")
        return y, tree_cache
    if USE_PALLAS_ATTN and cfg.mla is None and window == 0:
        # two-kernel path: flash over past + tree-block, LSE-combined
        # (kernels/ops.py) — identical math to the joint softmax below.
        # Quantized caches pass int8 K/V + per-row scales; the dequant
        # fuses into both kernels instead of materialising fp32 copies.
        from repro.kernels import ops as kops
        qkw = {}
        if "k_scale" in tree_cache:
            qkw = dict(k_scale=model_cache["k_scale"].swapaxes(1, 2),
                       v_scale=model_cache["v_scale"].swapaxes(1, 2),
                       kt_scale=tree_cache["k_scale"].swapaxes(1, 2),
                       vt_scale=tree_cache["v_scale"].swapaxes(1, 2))
        out = kops.tree_attention(
            q.swapaxes(1, 2),
            model_cache["k"].swapaxes(1, 2), model_cache["v"].swapaxes(1, 2),
            tree_cache["k"].swapaxes(1, 2), tree_cache["v"].swapaxes(1, 2),
            tree_mask, mlen, **qkw).swapaxes(1, 2)
        y = _proj(out, params["w_o"], "bqhk,hkd->bqd")
        return y, tree_cache
    # Joint softmax over [past ‖ tree] (paper computes the two score blocks
    # separately then softmaxes the concat — identical math).
    k = jnp.concatenate([k_past, k_tree], axis=1)
    v = jnp.concatenate([v_past, v_tree], axis=1)
    mask = jnp.concatenate(
        [jnp.broadcast_to(past_valid, (b, 1, n, max_len)),
         jnp.broadcast_to(tmask, (b, 1, n, tcap))], axis=-1)
    out = gqa_attend(q, k, v, mask, scale=scale)
    y = _proj(out, params["w_o"], "bqhk,hkd->bqd")
    return y, tree_cache


# --------------------------------------------------------------------------
# cross attention (enc-dec)
# --------------------------------------------------------------------------
def cross_attn_forward(params, cfg: ModelConfig, x, enc_kv):
    """Decoder cross-attention; enc_kv = (k, v) precomputed from encoder."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    k, v = enc_kv
    out = gqa_attend(q, k, v, None)
    return jnp.einsum("bqhk,hkd->bqd", out, params["w_o"])


def encode_cross_kv(params, cfg: ModelConfig, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["w_k"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["w_v"])
    return k, v
