"""Model configuration covering every assigned architecture family.

One frozen dataclass describes dense / MoE / MLA / SSM / hybrid (RG-LRU) /
encoder-decoder (audio) / VLM backbones.  Configs for the ten assigned
architectures live in ``repro.configs.<id>`` and are plain instances of
:class:`ModelConfig`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (token-choice top-k routing)."""

    num_experts: int
    experts_per_token: int
    d_ff_expert: int
    num_shared_experts: int = 0
    # which decoder layers are MoE; ``first_dense`` dense layers at the bottom
    # (Moonlight/DeepSeek style) keep a plain MLP.
    first_dense: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0  # 0 => direct q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD settings."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


# hybrid block-pattern characters -> sub-layer kinds (single source of
# truth for ModelConfig.block_kind and transformer.unit_kinds); any other
# character means local attention
PATTERN_KINDS = {"r": "rglru", "s": "ssm"}


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU + local attention hybrid."""

    lru_width: int = 0  # 0 => d_model
    d_conv: int = 4
    # repeating block pattern: 'r' = RG-LRU recurrent, 's' = Mamba-2 SSD
    # (requires ``ModelConfig.ssm``; Jamba-style attn+ssm hybrids),
    # anything else = local attention.
    pattern: str = "rra"
    window: int = 2048


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder tower for enc-dec (whisper) / VLM prefix settings."""

    num_layers: int = 0
    num_heads: int = 0
    d_ff: int = 0
    max_source_positions: int = 1500  # audio frames / vision patches


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0  # 0 => d_model // num_heads
    mlp_variant: str = "swiglu"  # swiglu | geglu | gelu
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    max_seq_len: int = 8192

    # attention variant for long_500k: 0 => full causal attention.
    sliding_window: int = 0

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None

    # VLM: number of prefix embedding slots fed by the (stub) vision frontend.
    prefix_tokens: int = 0

    dtype: str = "float32"

    # serving quantization: "" = fp32 reference path (bit-pinned),
    # "int8" = per-out-channel int8 weights + per-row int8 KV cache
    # (ModelBundle.quantize() sets this; dense attention families only).
    quant: str = ""

    # ---- derived ----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None and self.family == "audio"

    def block_kind(self, layer: int) -> str:
        """'attn' | 'ssm' | 'rglru' | 'local' for decoder layer ``layer``."""
        if self.family == "ssm":
            return "ssm"
        if self.rglru is not None:
            c = self.rglru.pattern[layer % len(self.rglru.pattern)]
            return PATTERN_KINDS.get(c, "local")
        return "attn"

    def layer_is_moe(self, layer: int) -> bool:
        return self.moe is not None and layer >= self.moe.first_dense

    # rough parameter counts (for roofline MODEL_FLOPS = 6·N·D) -------------
    def param_count(self, active_only: bool = False) -> int:
        d, ff, hd = self.d_model, self.d_ff, self.resolved_head_dim
        n_emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = n_emb
        for layer in range(self.num_layers):
            kind = self.block_kind(layer)
            if kind == "ssm":
                s = self.ssm
                di = s.d_inner(d)
                total += d * (2 * di + 2 * s.d_state + s.num_heads(d))
                total += di * s.d_conv + di * d + di  # conv, out_proj, norm-ish
                continue
            if kind == "rglru":
                w = self.rglru.lru_width or d
                total += 2 * d * w + w * d + 3 * w * w // w * w  # in/out + gates
            else:  # attention
                if self.mla is not None:
                    m = self.mla
                    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
                    total += d * (self.num_heads * qd)  # q
                    total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    total += m.kv_lora_rank * self.num_heads * (
                        m.qk_nope_head_dim + m.v_head_dim)
                    total += self.num_heads * m.v_head_dim * d
                else:
                    total += d * self.num_heads * hd  # q
                    total += 2 * d * self.num_kv_heads * hd  # k, v
                    total += self.num_heads * hd * d  # o
            # MLP / MoE
            if self.layer_is_moe(layer):
                mo = self.moe
                per_expert = 3 * d * mo.d_ff_expert
                shared = mo.num_shared_experts * per_expert
                if active_only:
                    total += shared + mo.experts_per_token * per_expert
                else:
                    total += shared + mo.num_experts * per_expert
                total += d * mo.num_experts  # router
            elif kind in ("attn", "local", "rglru"):
                mult = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
                total += mult * d * ff
        return total
