"""Chain-mode speculative decoding for recurrent architectures (SSM /
RG-LRU hybrids) — DESIGN.md §Arch-applicability.

Attention-free models have no ancestor-mask trick: verifying a *tree* would
need one forked recurrent state per node.  The paper's pipeline-filling
idea still applies with tree width 1: the draft proposes a linear chain,
each pipeline stage processes a different chain position (PipeDec with
w = c = 1), and the recurrent state is checkpointed per chain position so a
mismatch rolls back to the accepted prefix.  Losslessness is identical:
every committed token is the target's own argmax/sample.

Logical engine (single device, exact information schedule): target states
are snapshotted functionally per speculative position; logits exit
``n_stages`` timesteps after entry.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipedec import GenStats
from repro.core.speculative import ModelBundle, SamplingParams, select_token


@dataclasses.dataclass
class ChainConfig:
    """Chain (width-1 tree) speculative pipeline config — the PipeInfer-
    style ablation of the dynamic tree.
    """
    n_stages: int = 4
    max_chain: int = 0  # 0 => n_stages + 4
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)

    @property
    def chain_cap(self) -> int:
        return self.max_chain or self.n_stages + 4


@dataclasses.dataclass
class _Flight:
    exit_t: int
    pos: int              # speculative chain position this logits verifies
    logits: jnp.ndarray   # [V]


class ChainSpecEngine:
    """Draft-in-pipeline chain speculative decoding for recurrent models."""

    def __init__(self, target: ModelBundle, draft: ModelBundle,
                 ccfg: ChainConfig, max_len: int = 512):
        assert target.cfg.vocab_size == draft.cfg.vocab_size
        self.target, self.draft, self.ccfg = target, draft, ccfg
        self.max_len = max_len

    def generate(self, prompt: np.ndarray, max_new_tokens: int,
                 key: Optional[jax.Array] = None):
        c = self.ccfg
        key = key if key is not None else jax.random.PRNGKey(0)
        tgt, drf = self.target, self.draft

        t_cache = tgt.init_cache(1, self.max_len)
        d_cache = drf.init_cache(1, self.max_len)
        prompt_j = jnp.asarray(prompt, jnp.int32)[None]
        t_logits, t_cache = tgt.prefill(prompt_j, t_cache)
        _, d_cache = drf.prefill(prompt_j, d_cache)
        model_len = len(prompt)

        key, sk = jax.random.split(key)
        committed = [int(select_token(t_logits[0], c.sampling, sk))]

        # speculative chain state: chain[0] = last committed token;
        # *_states[i] = cache/state AFTER processing chain[:i] tokens beyond
        # the committed prefix (so *_states[0] never contains speculation).
        chain: List[int] = [committed[-1]]
        t_states = [t_cache]
        d_states = [d_cache]
        spec_len = 0            # chain tokens processed so far
        flights: List[_Flight] = []
        stats = GenStats()
        t = 0
        limit = max_new_tokens * (c.n_stages + 2) + 16

        while len(committed) < 1 + max_new_tokens and t < limit:
            t += 1
            stats.timesteps = t

            # ---- entry: next unprocessed chain token enters the pipeline
            if spec_len < len(chain) and len(chain) <= c.chain_cap:
                tok = jnp.asarray([chain[spec_len]], jnp.int32)
                lg, new_cache = tgt.decode(tok, t_states[spec_len],
                                           model_len + spec_len)
                flights.append(_Flight(t + c.n_stages - 1, spec_len + 1,
                                       lg[0]))
                t_states.append(new_cache)

                # draft processes the same token and proposes the next one
                dlg, d_new = drf.decode(tok, d_states[spec_len],
                                        model_len + spec_len)
                d_states.append(d_new)
                chain.append(int(jnp.argmax(dlg[0])))
                spec_len += 1
                stats.entries += 1

            # ---- exit + sync -----------------------------------------
            exiting = [f for f in flights if f.exit_t == t]
            flights = [f for f in flights if f.exit_t != t]
            for fl in exiting:
                key, sk = jax.random.split(key)
                x = int(select_token(fl.logits, c.sampling, sk))
                committed.append(x)
                stats.commits += 1
                model_len += 1
                if fl.pos < len(chain) and chain[fl.pos] == x:
                    stats.hits += 1
                    # the chain prefix is consumed: shift the window
                    chain = chain[1:]
                    t_states = t_states[1:]
                    d_states = d_states[1:]
                    spec_len -= 1
                    for f2 in flights:
                        f2.pos -= 1
                else:
                    stats.misses += 1
                    # rollback to the state after the accepted prefix
                    # (chain[:pos] are committed tokens, so states are exact)
                    p = min(fl.pos, len(t_states) - 1)
                    chain = [x]
                    t_states = [t_states[p]]
                    d_states = [d_states[min(p, len(d_states) - 1)]]
                    spec_len = 0
                    flights = []
                if len(committed) >= 1 + max_new_tokens:
                    break
            stats.commits_per_step.append(0)

        return np.asarray(committed[: 1 + max_new_tokens]), stats
