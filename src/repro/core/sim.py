"""Discrete-event wall-clock model of the deployments (PP / STPP / PipeDec
/ SpecPipe-DB) — reproduces the paper's Fig. 5 / Fig. 8 *shape* on CPU.

The logical engines (``pipedec.py``, ``baselines.py``) give exact token
traces and acceptance statistics; this module prices those traces in
seconds using per-stage hardware times derived from the dry-run roofline
(`benchmarks/fig5_latency.py` wires the two together).

Timing model (paper §2.4):
  PP        latency/token  = Σ_i T_c,i + Σ_i T_t,i
  PipeDec   timestep       = max(T_draft, C·max_i T_c,i + max_i T_t,i)
            latency/token  = timestep / tokens_per_timestep(measured)
  STPP      round          = depth·T_draft + Σ_i T_c,i(tree) + Σ T_t,i
            latency/token  = round / (accepted_per_round + 1)
  SpecPipe-DB  timestep    = max(T_draft·s(B), s(B)·max_i T_c,i + max T_t,i)
            throughput     = B · tokens_per_timestep / timestep
            TBT            = timestep / tokens_per_timestep
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional


@dataclasses.dataclass(frozen=True)
class StageHardware:
    """Per-stage times in seconds for a given verification width."""
    n_stages: int
    t_stage_one: float        # stage compute, width-1 (vanilla decode)
    t_stage_width: float      # stage compute, width-w tree layer (C·max T_c)
    t_comm: float             # inter-stage activation transfer
    t_draft: float            # draft model full forward (one tree layer)
    t_sync: float = 0.0       # hit_index broadcast + prune


def pp_latency_per_token(hw: StageHardware) -> float:
    """Seconds/token for plain PP: one full ring traversal per token."""
    return hw.n_stages * hw.t_stage_one + (hw.n_stages - 1) * hw.t_comm


def pipedec_latency_per_token(hw: StageHardware,
                              tokens_per_timestep: float) -> float:
    """Seconds/token for single-request SpecPipe: one timestep
    (max(draft, hop) + sync) amortised over tokens/timestep.
    """
    timestep = max(hw.t_draft, hw.t_stage_width + hw.t_comm) + hw.t_sync
    return timestep / max(tokens_per_timestep, 1e-9)


def stpp_latency_per_token(hw: StageHardware, depth: int,
                           mean_accepted: float) -> float:
    """Seconds/token for STPP: a serial draft+full-verify round
    amortised over the mean accepted path.
    """
    t_round = depth * hw.t_draft \
        + hw.n_stages * hw.t_stage_width + (hw.n_stages - 1) * hw.t_comm
    return t_round / (mean_accepted + 1.0)


def stage_hardware_from_roofline(
        *, n_stages: int, layer_time_one: float, layer_time_width: float,
        layers_per_stage: float, bytes_per_activation: float,
        link_bw: float = 50e9, t_draft: float = 0.0,
        t_sync: float = 1e-5) -> StageHardware:
    """Build stage times from per-layer roofline terms.

    layer_time_one/width: dominant roofline term for one target layer at
    verification width 1 / w; transfer prices one activation tensor over a
    single ICI/DCN link (the paper's 10 GbE is the analogous bottleneck).
    """
    return StageHardware(
        n_stages=n_stages,
        t_stage_one=layer_time_one * layers_per_stage,
        t_stage_width=layer_time_width * layers_per_stage,
        t_comm=bytes_per_activation / link_bw,
        t_draft=t_draft,
        t_sync=t_sync)


# --------------------------------------------------------------------------
# throughput (Fig. 8): k concurrent requests
# --------------------------------------------------------------------------
def pp_throughput(hw: StageHardware, batch: int,
                  batch_scale: Callable[[int], float] = None) -> float:
    """Tokens/s for PP with ``batch`` concurrent requests: the pipeline
    overlaps batches, so steady-state emits ``batch`` tokens per pipeline
    *stage* time (all stages busy on different requests)."""
    s = batch_scale(batch) if batch_scale else 1.0
    stage = hw.t_stage_one * s + hw.t_comm
    # pipeline full: one batch of tokens per stage-time
    return batch / stage if batch >= hw.n_stages else \
        batch / (hw.n_stages * stage / max(batch, 1))


def pipedec_throughput(hw: StageHardware, batch: int,
                       tokens_per_timestep: float,
                       batch_scale: Callable[[int], float] = None) -> float:
    """PipeDec serialises tasks (whole pipeline per task), so throughput is
    batch-independent: tokens/s = 1/latency."""
    del batch, batch_scale
    return 1.0 / pipedec_latency_per_token(hw, tokens_per_timestep)


def stpp_throughput(hw: StageHardware, batch: int, depth: int,
                    mean_accepted: float,
                    batch_scale: Callable[[int], float] = None) -> float:
    """Tokens/s for STPP with ``batch`` tasks overlapping their verify
    passes across stages.
    """
    s = batch_scale(batch) if batch_scale else 1.0
    stage = hw.t_stage_width * s + hw.t_comm
    # with k≥1 concurrent tasks the pipeline overlaps different tasks'
    # verify passes; draft runs on its own device, overlapped.
    rounds_per_s = min(batch, hw.n_stages) / (hw.n_stages * stage)
    tokens_per_round = mean_accepted + 1.0
    return rounds_per_s * tokens_per_round


# --------------------------------------------------------------------------
# SpecPipe-DB (dynamic batching): ``batch`` requests share every pipeline
# timestep — their tree layers are stacked along the batch axis in each
# stage, so stage compute grows by batch_scale(batch) (sub-linear while the
# verify pass stays memory-bound) while token output grows linearly with
# occupancy.  Engine: repro.serving.dynbatch.SpecPipeDBEngine.
# --------------------------------------------------------------------------
def specpipe_db_timestep(hw: StageHardware, batch: int,
                         batch_scale: Callable[[int], float] = None) -> float:
    """``batch_scale(batch)`` is the stage-time inflation from stacking
    ``batch`` width-w layers in one verify pass.  ``None`` models the fully
    memory-bound regime (stage time independent of batch — param streaming
    dominates), the SAME convention as ``pp_throughput``/``stpp_throughput``
    above; pass a roofline-derived scale for a finite-compute curve
    (``benchmarks.fig8_throughput.db_batch_scale``)."""
    s = batch_scale(batch) if batch_scale else 1.0
    return max(hw.t_draft * s, hw.t_stage_width * s + hw.t_comm) + hw.t_sync


def specpipe_db_throughput(hw: StageHardware, batch: int,
                           tokens_per_timestep: float,
                           batch_scale: Callable[[int], float] = None
                           ) -> float:
    """Tokens/s with ``batch`` concurrent requests: each timestep emits
    ~``batch * tokens_per_timestep`` tokens (per-request acceptance is
    unchanged by batching — the DB engine runs the same per-request
    schedule, only stacked)."""
    ts = specpipe_db_timestep(hw, batch, batch_scale)
    return batch * tokens_per_timestep / ts


def specpipe_db_tbt(hw: StageHardware, batch: int,
                    tokens_per_timestep: float,
                    batch_scale: Callable[[int], float] = None) -> float:
    """Time-between-tokens for ONE request under DB (the paper's TBT
    metric): each request still advances every timestep, so TBT degrades
    only by the batched stage-time inflation, not by round-robin stalls."""
    ts = specpipe_db_timestep(hw, batch, batch_scale)
    return ts / max(tokens_per_timestep, 1e-9)


# --------------------------------------------------------------------------
# SpecPipe-DB on the sharded deployment (serving.executor over
# launch.pipeline): the batched tree layers ride the ppermute activation
# ring, so the per-hop transfer cost is explicit.  ``flush=True`` prices
# the synchronous-flush executor (``ShardedPipelineExecutor``: each
# timestep pushes the batched entry through all n_stages hops inside one
# dispatch — the bit-exact reference schedule); ``flush=False`` prices the
# steady-state overlapped deployment (``OverlappedShardedExecutor``: ring
# always full, ONE tick per timestep with deferred exit logits and
# in-ring pruning propagation — the paper's wall-clock regime, now
# executed and measured: benchmarks/fig8_throughput.py records 1
# tick/timestep vs the flush's n_stages hops, with bit-identical tokens).
#
# Steady-state cost terms (the cheap-ticks PR):
#   * ``ctrl_rate`` × ``t_ctrl`` — the gated in-ring ctrl: only the
#     fraction of ticks whose ctrl message is active pays the per-stage
#     commit-scatter + prune-gather cost ``t_ctrl`` (ungated executors
#     pay it every tick: ``ctrl_rate=1``; the measured rate is
#     ``calls["ctrl_active_ticks"] / calls["pipeline_tick"]``).
#   * ``prefill_rate`` × ``t_prefill`` — admission prefill: the flush
#     schedule pays a separate prefill dispatch per admission
#     (``prefill_rate`` admissions per timestep); the overlapped schedule
#     rides the prompt through the tick's prefill lane (prefill-in-ring),
#     so the separate term vanishes and only the (already-counted) hop is
#     paid.
# --------------------------------------------------------------------------
def specpipe_db_sharded_timestep(hw: StageHardware, batch: int,
                                 batch_scale: Callable[[int], float] = None,
                                 flush: bool = False,
                                 ctrl_rate: float = 0.0,
                                 t_ctrl: float = 0.0,
                                 prefill_rate: float = 0.0,
                                 t_prefill: float = 0.0) -> float:
    """Per-timestep cost of the sharded deployment: flush pays
    n_stages hops + separate ctrl/prefill dispatches; overlapped
    pays ONE hop with gated ctrl riding it.
    """
    s = batch_scale(batch) if batch_scale else 1.0
    hop = hw.t_stage_width * s + hw.t_comm
    if flush:
        # flush: n_stages hops per timestep, a separate central
        # commit/remap application (ctrl_rate prices how often), and a
        # separate prefill dispatch per admission
        steps = hw.n_stages * hop + ctrl_rate * t_ctrl \
            + prefill_rate * t_prefill
        return max(hw.t_draft * s, steps) + hw.t_sync
    # overlapped: ONE hop per timestep; the gated ctrl rides the hop only
    # on active ticks, and prefill-in-ring amortises admission into the
    # same hop (no separate term)
    return max(hw.t_draft * s, hop + ctrl_rate * t_ctrl) + hw.t_sync


def specpipe_db_sharded_throughput(hw: StageHardware, batch: int,
                                   tokens_per_timestep: float,
                                   batch_scale: Callable[[int], float]
                                   = None, flush: bool = False,
                                   **cost_terms) -> float:
    """Tokens/s = batch * tokens_per_timestep / sharded timestep."""
    ts = specpipe_db_sharded_timestep(hw, batch, batch_scale, flush,
                                      **cost_terms)
    return batch * tokens_per_timestep / ts


def specpipe_db_sharded_tbt(hw: StageHardware, batch: int,
                            tokens_per_timestep: float,
                            batch_scale: Callable[[int], float] = None,
                            flush: bool = False, **cost_terms) -> float:
    """Time-between-tokens = sharded timestep / tokens_per_timestep."""
    ts = specpipe_db_sharded_timestep(hw, batch, batch_scale, flush,
                                      **cost_terms)
    return ts / max(tokens_per_timestep, 1e-9)


# --------------------------------------------------------------------------
# Async free-running stages + disaggregated draft
# (``AsyncPipelineExecutor``): no host lockstep, so the per-timestep host
# synchronisation term ``t_sync`` — the barrier the overlapped schedule
# still pays to dispatch its one tick and broadcast hit indices — drops
# out entirely.  The draft term leaves the max() too: the disaggregated
# draft actor speculates on its own device concurrently with the target
# hops, so steady-state throughput is gated by the slowest stage hop (plus
# the gated ctrl share), with the draft only binding if it is slower than
# the whole target pipe — the PipeInfer/PipeSpec regime.
# --------------------------------------------------------------------------
def specpipe_db_async_timestep(hw: StageHardware, batch: int,
                               batch_scale: Callable[[int], float] = None,
                               ctrl_rate: float = 0.0,
                               t_ctrl: float = 0.0) -> float:
    """Steady-state per-timestep cost of the async free-running schedule:
    ``max(draft, hop + ctrl_rate * t_ctrl)`` with NO ``t_sync`` — the
    lockstep barrier is gone, and per-stage inbox queues absorb jitter."""
    s = batch_scale(batch) if batch_scale else 1.0
    hop = hw.t_stage_width * s + hw.t_comm
    return max(hw.t_draft * s, hop + ctrl_rate * t_ctrl)


def specpipe_db_async_throughput(hw: StageHardware, batch: int,
                                 tokens_per_timestep: float,
                                 batch_scale: Callable[[int], float]
                                 = None, **cost_terms) -> float:
    """Tokens/s = batch * tokens_per_timestep / async timestep."""
    ts = specpipe_db_async_timestep(hw, batch, batch_scale, **cost_terms)
    return batch * tokens_per_timestep / ts


def specpipe_db_async_tbt(hw: StageHardware, batch: int,
                          tokens_per_timestep: float,
                          batch_scale: Callable[[int], float] = None,
                          **cost_terms) -> float:
    """Time-between-tokens = async timestep / tokens_per_timestep."""
    ts = specpipe_db_async_timestep(hw, batch, batch_scale, **cost_terms)
    return ts / max(tokens_per_timestep, 1e-9)
