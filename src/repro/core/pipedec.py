"""PipeDec decode engine — draft-in-pipeline speculative decoding.

This is the *logical* engine: it executes the exact computation and
information schedule of the paper's distributed system on one device.  The
pipeline-stage partition of the target model changes only *when* a layer's
logits become available (``n_stages - 1`` timesteps after the entry
timestep: the layer occupies stage 1 during the timestep it enters, so an
entry at timestep t exits at ``t + n_stages - 1`` and entry-to-exit spans
``n_stages`` timesteps inclusive — tests/test_serving_db.py pins this
pipeline-fill latency), never *what* is computed, so the single-device
engine is bit-identical to the multi-node system.  Wall-clock behaviour is
modelled separately (``core/sim.py``) and the sharded deployment lives in
``repro.launch``.

Per timestep (paper §3.4, Fig. 2):
  1. the current deepest tree layer *enters* the pipeline: the target
     computes its verification logits (buffered until exit) and the draft
     processes the same layer to propose the next layer (tree expand);
  2. the layer that entered ``n_stages`` timesteps ago *exits*: the logits
     row of the current root gives the next committed token x; the root's
     KV row migrates from the tree cache to the model cache (two-level
     cache sync, §3.4.3); the tree is pruned to the subtree of the child
     matching x (hit) or re-initialised at x (miss), and all in-flight
     state is remapped/invalidated accordingly.

The per-request loop state lives in ``DecodeState`` and one timestep is
``PipeDecEngine.step``; ``generate`` drives a single state to completion,
while the dynamic-batching engine (``repro.serving.dynbatch``) multiplexes
many states through one shared pipeline schedule — each request's operation
trace is identical either way, so SpecPipe-DB inherits losslessness from
this engine.

Vanilla pipeline parallelism is the degenerate case w=0 (every step a
miss); STPP (static tree) is in ``core/baselines.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tree as tree_lib
from repro.core.speculative import (ModelBundle, SamplingParams,
                                    draft_candidates, remap_tree_caches,
                                    select_token)


@dataclasses.dataclass
class PipeDecConfig:
    """Dynamic-tree SpecPipe config: stage count, max tree layer width
    w, max children per node c, tree depth cap and sampling.
    """
    n_stages: int = 4
    width: int = 8            # max tree layer width w
    branch: int = 4           # max children per node c
    max_depth: int = 0        # 0 => n_stages + 4
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)

    @property
    def depth_cap(self) -> int:
        return self.max_depth or self.n_stages + 4

    @property
    def capacity(self) -> int:
        return 1 + self.width * self.depth_cap

    @property
    def tree_buffer_capacity(self) -> int:
        """Tree KV buffer rows: ``capacity`` plus width-w slack so every
        fixed-width layer write (and masked DB rows parked at
        ``capacity``) fits without clamping."""
        return self.capacity + self.width


@dataclasses.dataclass
class Flight:
    """One in-flight tree layer between entry and exit.

    ``logits`` is either the concrete [w, V] verify logits (the flush /
    local schedules compute them at entry and buffer them here) or a
    *deferred* handle exposing ``resolve() -> [w, V]`` (the overlapped
    sharded schedule — the layer is still riding the stage ring and its
    logits only exist at ``exit_t``, when the backend resolves the
    future).  ``exit_apply`` resolves at consumption time, so the engine
    schedule is identical either way."""
    exit_t: int
    node_idx: np.ndarray      # [w] int32 global tree indices (-1 invalid)
    logits: Any               # [w, V] array, or a deferred-logits handle


@dataclasses.dataclass
class EntryInputs:
    """One request's deepest tree layer, ready for the (fused) tree-verify
    dispatch — the per-slot unit the DB engine stacks along the batch axis
    (``TreeBatch.deepest_layers`` produces the same views already stacked).
    """
    tokens: jnp.ndarray       # [w] int32 layer tokens (padded with 0)
    positions: jnp.ndarray    # [w] int32 absolute positions
    mask: jnp.ndarray         # [w, Tcap] padded ancestor-mask rows
    write_index: jnp.ndarray  # () int32 tree-buffer write offset
    node_idx: np.ndarray      # [w] int32 global tree indices (-1 invalid)


def remap_flight_indices(node_idx: np.ndarray, index_map) -> np.ndarray:
    """Apply a prune's old→new ``index_map`` to buffered flight/draft node
    indices (-1 rows stay -1; dropped nodes become -1).  int32 in, int32
    out — all tree/flight indices share one dtype across hit/prune cycles
    (tests pin the stability)."""
    imap = np.asarray(index_map)
    out = np.where(node_idx >= 0, imap[np.maximum(node_idx, 0)], -1)
    return out.astype(np.int32)


@dataclasses.dataclass
class GenStats:
    """Per-request SpecPipe counters: timesteps, commits, hit/miss
    verifications and ring entries.
    """
    timesteps: int = 0
    commits: int = 0
    hits: int = 0
    misses: int = 0
    entries: int = 0
    commits_per_step: List[int] = dataclasses.field(default_factory=list)

    @property
    def acceptance(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    @property
    def tokens_per_timestep(self) -> float:
        return self.commits / self.timesteps if self.timesteps else 0.0


@dataclasses.dataclass
class DecodeState:
    """Everything one in-flight request carries between timesteps."""
    committed: List[int]
    tree: tree_lib.Tree
    t_cache: Any              # target model (level-1) KV cache
    d_cache: Any              # draft model cache
    t_tree: Any               # target tree (level-2) KV cache
    d_tree: Any               # draft tree cache
    model_len: int
    key: jax.Array
    max_new_tokens: int
    limit: int                # local-timestep budget
    flights: List[Flight] = dataclasses.field(default_factory=list)
    pending: bool = True      # deepest layer not yet entered
    last_draft: Optional[Tuple[np.ndarray, jnp.ndarray]] = None
    stats: GenStats = dataclasses.field(default_factory=GenStats)
    t: int = 0                # local timestep counter
    eos: Optional[int] = None
    eos_hit: bool = False
    sampling: Optional[SamplingParams] = None  # per-request (None => cfg's)

    @property
    def done(self) -> bool:
        return (self.eos_hit
                or len(self.committed) >= 1 + self.max_new_tokens
                or self.t >= self.limit)

    def output(self) -> np.ndarray:
        return np.asarray(self.committed[: 1 + self.max_new_tokens])

    def caches(self):
        return (self.t_cache, self.d_cache, self.t_tree, self.d_tree)


class PipeDecEngine:
    """Single-request SpecPipe engine: drives the dynamic token tree
    through the stage ring one timestep at a time (entry at t exits
    at t + n_stages - 1) and commits on the hit path.
    """
    def __init__(self, target: ModelBundle, draft: ModelBundle,
                 pcfg: PipeDecConfig, max_len: int = 512):
        assert target.cfg.vocab_size == draft.cfg.vocab_size
        self.target, self.draft, self.pcfg = target, draft, pcfg
        self.max_len = max_len

    # ------------------------------------------------------------------
    def _pad_mask(self, mask_rows: jnp.ndarray, tcap: int) -> jnp.ndarray:
        n, cap = mask_rows.shape
        return jnp.pad(mask_rows, ((0, 0), (0, tcap - cap)))

    @property
    def tree_buffer_capacity(self) -> int:
        return self.pcfg.tree_buffer_capacity

    # ------------------------------------------------------------------
    def init_state(self, prompt: np.ndarray, max_new_tokens: int,
                   key: Optional[jax.Array] = None,
                   max_timesteps: Optional[int] = None, *,
                   caches=None, eos: Optional[int] = None,
                   sampling: Optional[SamplingParams] = None,
                   prefill_fn=None) -> DecodeState:
        """Prefill both models and commit the first token.

        ``caches`` optionally supplies recycled (t_cache, d_cache, t_tree,
        d_tree) buffers (the serving KV arena): prefill overwrites the
        prompt prefix and every attention mask is bounded by ``model_len``
        / the ancestor mask, so stale rows from a previous occupant are
        never attended and outputs are unchanged.

        ``prefill_fn`` hands the prefill to an executor backend that owns
        the cache storage (``serving.executor.PipelineExecutor.prefill``):
        it receives the [1, len] prompt, fills both models' caches
        wherever the backend keeps them, and returns the target's
        last-position logits; the state then carries no cache pytrees of
        its own (they live in the executor's arena).

        ``sampling`` overrides the engine-global ``pcfg.sampling`` for
        this request only (per-request temperature/top-k/top-p — mixed
        greedy/stochastic batches under SpecPipe-DB).
        """
        p = self.pcfg
        key = key if key is not None else jax.random.PRNGKey(0)
        tcap = self.tree_buffer_capacity
        sp = sampling if sampling is not None else p.sampling

        tgt, drf = self.target, self.draft
        prompt_j = jnp.asarray(prompt, jnp.int32)[None]
        if prefill_fn is not None:
            t_cache = d_cache = t_tree = d_tree = None
            t_logits = prefill_fn(prompt_j)
        else:
            if caches is None:
                t_cache = tgt.init_cache(1, self.max_len)
                d_cache = drf.init_cache(1, self.max_len)
                t_tree = tgt.init_tree_caches(1, tcap)
                d_tree = drf.init_tree_caches(1, tcap)
            else:
                t_cache, d_cache, t_tree, d_tree = caches
            t_logits, t_cache = tgt.prefill(prompt_j, t_cache)
            _, d_cache = drf.prefill(prompt_j, d_cache)

        prefix = 0
        if tgt.prefix_embeds is not None:
            prefix = tgt.prefix_embeds.shape[1]
        model_len = prefix + len(prompt)

        key, sk = jax.random.split(key)
        first = int(select_token(t_logits[0], sp, sk))

        st = DecodeState(
            committed=[first],
            tree=tree_lib.tree_init(p.capacity, first),
            t_cache=t_cache, d_cache=d_cache, t_tree=t_tree, d_tree=d_tree,
            model_len=model_len, key=key, max_new_tokens=max_new_tokens,
            limit=max_timesteps or (max_new_tokens * (p.n_stages + 2) + 16),
            eos=eos, sampling=sp)
        st.eos_hit = eos is not None and first == eos
        return st

    # ---- phase 1a: gather-entry (pure read) --------------------------
    def gather_entry(self, st: DecodeState) -> Optional["EntryInputs"]:
        """Read the deepest tree layer as stacked-axis-ready entry inputs.
        No state change; returns None when no layer is pending entry.  The
        DB engine stacks these across slots for ONE fused tree-verify
        dispatch per model; ``step`` runs the same arrays at B=1."""
        if not st.pending:
            return None
        w = self.pcfg.width
        tokens, idxs, valid, mask_rows = tree_lib.last_layer(st.tree, w)
        depths = jnp.where(valid, st.tree.depth[idxs], 0)
        positions = (st.model_len + depths).astype(jnp.int32)       # [w]
        pmask = self._pad_mask(mask_rows, self.tree_buffer_capacity)
        node_idx = np.where(np.asarray(valid), np.asarray(idxs),
                            -1).astype(np.int32)
        return EntryInputs(tokens=tokens, positions=positions, mask=pmask,
                           write_index=st.tree.layer_start,
                           node_idx=node_idx)

    # ---- phase 1b: apply-fused (bookkeeping from the verify logits) --
    def apply_entry(self, st: DecodeState, entry: "EntryInputs",
                    v_logits, d_logits: jnp.ndarray) -> None:
        """Record the entry's in-flight state from this request's rows of
        the (possibly fused) tree-verify logits ([w, V] each).

        ``v_logits`` may be a deferred handle instead of an array (the
        overlapped sharded backend delivers the target's verify logits at
        exit time; see ``Flight``).  ``d_logits`` is always concrete —
        the draft proposes the next layer the same timestep, so it runs
        beside stage 0 with no pipeline delay on every backend."""
        st.flights.append(Flight(exit_t=st.t + self.pcfg.n_stages - 1,
                                 node_idx=entry.node_idx,
                                 logits=v_logits))
        st.stats.entries += 1
        st.last_draft = (entry.node_idx.copy(), d_logits)
        st.pending = False

    # ---- phase 1c: tree expansion (may be deferred) ------------------
    def can_expand(self, tree: tree_lib.Tree) -> bool:
        """Depth-cap / buffer-capacity guard for appending one layer.  A
        full layer appends ``width`` slots, so ``n_nodes + width`` must fit
        within ``capacity`` NOW — admitting ``n_nodes + w == cap + 1``
        (the old off-by-one) makes ``tree_expand`` silently truncate the
        layer's last candidate at the buffer edge (pinned by the
        capacity-saturation regression test)."""
        p = self.pcfg
        cur_depth = int(jnp.max(jnp.where(tree.valid(), tree.depth, 0)))
        return (cur_depth < p.depth_cap
                and int(tree.n_nodes) + p.width <= p.capacity)

    def maybe_expand(self, st: DecodeState) -> None:
        p = self.pcfg
        w, c = p.width, p.branch
        if st.last_draft is None or st.pending:
            return
        if not self.can_expand(st.tree):
            return  # deferred: retried next timestep once a prune frees room
        nidx, dlog = st.last_draft
        rows_valid = nidx >= 0
        if not rows_valid.any():
            return
        if hasattr(dlog, "resolve"):
            # async backend: the draft actor's verify is a lazy future —
            # block here (expansion is the first consumer of the logits)
            dlog = dlog.resolve()
        # surviving rows, in (compacted) index order, align with the
        # deepest layer's slots
        order = np.argsort(np.where(rows_valid, nidx,
                                    np.iinfo(np.int32).max))
        dlog_sorted = dlog[jnp.asarray(order)]
        valid_sorted = jnp.asarray(rows_valid[order])
        cand_tok, cand_lp = draft_candidates(dlog_sorted, valid_sorted, c)
        st.tree = tree_lib.tree_expand(st.tree, cand_tok, cand_lp, w)
        st.pending = True
        st.last_draft = None

    # ---- phase 2a: pick the exiting flight ---------------------------
    def exit_pick(self, st: DecodeState) -> Optional[Tuple[Flight, int]]:
        """Pop the flight exiting this timestep.  Returns (flight,
        root_row) or None (nothing exiting, or a stale flight whose root
        was pruned away — should not happen)."""
        exiting = [f for f in st.flights if f.exit_t == st.t]
        st.flights = [f for f in st.flights if f.exit_t != st.t]
        for fl in exiting:
            root_rows = np.where(fl.node_idx == 0)[0]
            if len(root_rows):
                return fl, int(root_rows[0])
        return None

    # ---- phase 2b: exit-commit (token, prune, remap) -----------------
    def exit_apply(self, st: DecodeState, fl: Flight, root_row: int, *,
                   commit_caches, remap_caches) -> int:
        """Commit the root's token and sync all in-flight state.  Cache
        mutation is delegated: ``commit_caches(st)`` migrates tree-buffer
        row 0 into the model caches at ``st.model_len`` (two-level cache
        sync, §3.4.3) and ``remap_caches(st, index_map)`` compacts the
        tree caches after a prune — the single-request engine mutates
        ``st``'s own caches, the DB engine its arena rows.  Returns the
        number of commits (1)."""
        p = self.pcfg
        sp = st.sampling if st.sampling is not None else p.sampling
        st.key, sk = jax.random.split(st.key)
        logits = fl.logits
        if hasattr(logits, "resolve"):   # deferred future: resolved by the
            logits = logits.resolve()    # backend the tick the layer exits
        x = int(select_token(logits[root_row], sp, sk))
        st.committed.append(x)
        st.stats.commits += 1
        commit_caches(st)
        st.model_len += 1
        if st.eos is not None and x == st.eos:
            st.eos_hit = True

        hit = int(tree_lib.find_child_with_token(st.tree, x))
        if hit >= 0:
            st.stats.hits += 1
            st.tree, index_map = tree_lib.tree_prune_to_child(st.tree, hit)
            remap_caches(st, index_map)
            for f2 in st.flights:
                f2.node_idx = remap_flight_indices(f2.node_idx, index_map)
            if st.last_draft is not None:
                st.last_draft = (remap_flight_indices(st.last_draft[0],
                                                      index_map),
                                 st.last_draft[1])
        else:
            st.stats.misses += 1
            st.tree = tree_lib.tree_init(p.capacity, x)
            st.flights = []
            st.last_draft = None
            st.pending = True
        return 1

    # default cache plumbing: the request owns its caches (B=1)
    def _commit_own_caches(self, st: DecodeState) -> None:
        st.t_cache = self.target.commit(st.t_cache, st.t_tree, 0,
                                        st.model_len)
        st.d_cache = self.draft.commit(st.d_cache, st.d_tree, 0,
                                       st.model_len)

    def _remap_own_caches(self, st: DecodeState, index_map) -> None:
        cap = self.pcfg.capacity
        st.t_tree = remap_tree_caches(st.t_tree, index_map, cap)
        st.d_tree = remap_tree_caches(st.d_tree, index_map, cap)

    def step(self, st: DecodeState) -> DecodeState:
        """Advance one pipeline timestep: gather-entry → verify (target
        entry + draft proposal) → expansion → exit-commit.  Mutates and
        returns ``st``.  The DB engine drives the same phases with the
        verify dispatch fused across slots; this per-request path is its
        B=1 case."""
        st.t += 1
        st.stats.timesteps = st.t
        step_commits = 0

        entry = self.gather_entry(st)
        if entry is not None:
            v_logits, st.t_tree = self.target.tree_verify(
                entry.tokens[None], entry.positions[None], entry.mask[None],
                st.t_cache, st.model_len, st.t_tree, entry.write_index)
            d_logits, st.d_tree = self.draft.tree_verify(
                entry.tokens[None], entry.positions[None], entry.mask[None],
                st.d_cache, st.model_len, st.d_tree, entry.write_index)
            self.apply_entry(st, entry, v_logits[0], d_logits[0])

        self.maybe_expand(st)

        ev = self.exit_pick(st)
        if ev is not None:
            fl, root_row = ev
            step_commits += self.exit_apply(
                st, fl, root_row, commit_caches=self._commit_own_caches,
                remap_caches=self._remap_own_caches)
        st.stats.commits_per_step.append(step_commits)
        return st

    # ------------------------------------------------------------------
    def generate(self, prompt: np.ndarray, max_new_tokens: int,
                 key: Optional[jax.Array] = None,
                 max_timesteps: Optional[int] = None, *,
                 eos: Optional[int] = None,
                 sampling: Optional[SamplingParams] = None):
        st = self.init_state(prompt, max_new_tokens, key, max_timesteps,
                             eos=eos, sampling=sampling)
        while not st.done:
            self.step(st)
        return st.output(), st.stats
