"""PipeDec decode engine — draft-in-pipeline speculative decoding.

This is the *logical* engine: it executes the exact computation and
information schedule of the paper's distributed system on one device.  The
pipeline-stage partition of the target model changes only *when* a layer's
logits become available (``n_stages`` timesteps after entry), never *what*
is computed, so the single-device engine is bit-identical to the multi-node
system.  Wall-clock behaviour is modelled separately (``core/sim.py``) and
the sharded deployment lives in ``repro.launch``.

Per timestep (paper §3.4, Fig. 2):
  1. the current deepest tree layer *enters* the pipeline: the target
     computes its verification logits (buffered until exit) and the draft
     processes the same layer to propose the next layer (tree expand);
  2. the layer that entered ``n_stages`` timesteps ago *exits*: the logits
     row of the current root gives the next committed token x; the root's
     KV row migrates from the tree cache to the model cache (two-level
     cache sync, §3.4.3); the tree is pruned to the subtree of the child
     matching x (hit) or re-initialised at x (miss), and all in-flight
     state is remapped/invalidated accordingly.

Vanilla pipeline parallelism is the degenerate case w=0 (every step a
miss); STPP (static tree) is in ``core/baselines.py``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tree as tree_lib
from repro.core.speculative import (ModelBundle, SamplingParams,
                                    draft_candidates, remap_tree_caches,
                                    select_token)


@dataclasses.dataclass
class PipeDecConfig:
    n_stages: int = 4
    width: int = 8            # max tree layer width w
    branch: int = 4           # max children per node c
    max_depth: int = 0        # 0 => n_stages + 4
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)

    @property
    def depth_cap(self) -> int:
        return self.max_depth or self.n_stages + 4

    @property
    def capacity(self) -> int:
        return 1 + self.width * self.depth_cap


@dataclasses.dataclass
class Flight:
    exit_t: int
    node_idx: np.ndarray      # [w] global tree indices (-1 invalid)
    logits: jnp.ndarray       # [w, V]


@dataclasses.dataclass
class GenStats:
    timesteps: int = 0
    commits: int = 0
    hits: int = 0
    misses: int = 0
    entries: int = 0
    commits_per_step: List[int] = dataclasses.field(default_factory=list)

    @property
    def acceptance(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    @property
    def tokens_per_timestep(self) -> float:
        return self.commits / self.timesteps if self.timesteps else 0.0


class PipeDecEngine:
    def __init__(self, target: ModelBundle, draft: ModelBundle,
                 pcfg: PipeDecConfig, max_len: int = 512):
        assert target.cfg.vocab_size == draft.cfg.vocab_size
        self.target, self.draft, self.pcfg = target, draft, pcfg
        self.max_len = max_len

    # ------------------------------------------------------------------
    def _pad_mask(self, mask_rows: jnp.ndarray, tcap: int) -> jnp.ndarray:
        n, cap = mask_rows.shape
        return jnp.pad(mask_rows, ((0, 0), (0, tcap - cap)))

    def generate(self, prompt: np.ndarray, max_new_tokens: int,
                 key: Optional[jax.Array] = None,
                 max_timesteps: Optional[int] = None):
        p = self.pcfg
        w, c, cap = p.width, p.branch, p.capacity
        key = key if key is not None else jax.random.PRNGKey(0)
        tcap = cap + w  # slack for fixed-w layer writes

        tgt, drf = self.target, self.draft
        t_cache = tgt.init_cache(1, self.max_len)
        d_cache = drf.init_cache(1, self.max_len)
        prompt_j = jnp.asarray(prompt, jnp.int32)[None]
        t_logits, t_cache = tgt.prefill(prompt_j, t_cache)
        _, d_cache = drf.prefill(prompt_j, d_cache)

        prefix = 0
        if tgt.prefix_embeds is not None:
            prefix = tgt.prefix_embeds.shape[1]
        model_len = prefix + len(prompt)

        key, sk = jax.random.split(key)
        first = int(select_token(t_logits[0], p.sampling, sk))
        committed = [first]

        tree = tree_lib.tree_init(cap, first)
        t_tree = tgt.init_tree_caches(1, tcap)
        d_tree = drf.init_tree_caches(1, tcap)

        flights: List[Flight] = []
        pending = True            # deepest layer not yet entered
        last_draft = None         # (node_idx np [w], logits [w, V])
        stats = GenStats()
        t = 0
        limit = max_timesteps or (max_new_tokens * (p.n_stages + 2) + 16)

        while len(committed) < 1 + max_new_tokens and t < limit:
            t += 1
            stats.timesteps = t
            step_commits = 0

            # ---- phase 1: entry (target) + proposal (draft) -------------
            if pending:
                tokens, idxs, valid, mask_rows = tree_lib.last_layer(tree, w)
                depths = jnp.where(valid, tree.depth[idxs], 0)
                positions = (model_len + depths)[None]  # [1, w]
                pmask = self._pad_mask(mask_rows, tcap)
                wi = tree.layer_start

                v_logits, t_tree = tgt.tree_verify(
                    tokens[None], positions, pmask, t_cache, model_len,
                    t_tree, wi)
                flights.append(Flight(
                    exit_t=t + p.n_stages - 1,
                    node_idx=np.where(np.asarray(valid), np.asarray(idxs), -1),
                    logits=v_logits[0]))
                stats.entries += 1

                dl_logits, d_tree = drf.tree_verify(
                    tokens[None], positions, pmask, d_cache, model_len,
                    d_tree, wi)
                last_draft = (np.where(np.asarray(valid),
                                       np.asarray(idxs), -1),
                              dl_logits[0])
                pending = False

            # expansion (may be deferred by the depth cap)
            if last_draft is not None and not pending:
                cur_depth = int(jnp.max(jnp.where(tree.valid(), tree.depth, 0)))
                if cur_depth < p.depth_cap and \
                        int(tree.n_nodes) + w <= cap + 1:
                    nidx, dlog = last_draft
                    rows_valid = nidx >= 0
                    if rows_valid.any():
                        # surviving rows, in (compacted) index order, align
                        # with the deepest layer's slots
                        order = np.argsort(np.where(rows_valid, nidx,
                                                    np.iinfo(np.int32).max))
                        dlog_sorted = dlog[jnp.asarray(order)]
                        valid_sorted = jnp.asarray(rows_valid[order])
                        cand_tok, cand_lp = draft_candidates(
                            dlog_sorted, valid_sorted, c)
                        tree = tree_lib.tree_expand(tree, cand_tok, cand_lp, w)
                        pending = True
                        last_draft = None

            # ---- phase 2: exit + sync (commit, prune) -------------------
            exiting = [f for f in flights if f.exit_t == t]
            flights = [f for f in flights if f.exit_t != t]
            for fl in exiting:
                root_rows = np.where(fl.node_idx == 0)[0]
                if len(root_rows) == 0:
                    continue  # stale flight (should not happen)
                r = int(root_rows[0])
                key, sk = jax.random.split(key)
                x = int(select_token(fl.logits[r], p.sampling, sk))
                committed.append(x)
                stats.commits += 1
                step_commits += 1

                # two-level cache sync: migrate the old root's KV row (tree
                # buffer row 0) into the model cache at position model_len
                t_cache = tgt.commit(t_cache, t_tree, 0, model_len)
                d_cache = drf.commit(d_cache, d_tree, 0, model_len)
                model_len += 1

                hit = int(tree_lib.find_child_with_token(tree, x))
                if hit >= 0:
                    stats.hits += 1
                    tree, index_map = tree_lib.tree_prune_to_child(tree, hit)
                    t_tree = remap_tree_caches(t_tree, index_map, cap)
                    d_tree = remap_tree_caches(d_tree, index_map, cap)
                    imap = np.asarray(index_map)

                    def remap(ix):
                        out = np.where(ix >= 0, imap[np.maximum(ix, 0)], -1)
                        return out.astype(np.int64)

                    for f2 in flights:
                        f2.node_idx = remap(f2.node_idx)
                    if last_draft is not None:
                        last_draft = (remap(last_draft[0]), last_draft[1])
                else:
                    stats.misses += 1
                    tree = tree_lib.tree_init(cap, x)
                    flights = []
                    last_draft = None
                    pending = True
                if len(committed) >= 1 + max_new_tokens:
                    break
            stats.commits_per_step.append(step_commits)

        return np.asarray(committed[: 1 + max_new_tokens]), stats
