"""Baselines: vanilla pipeline decoding (PP) and static-tree speculative
decoding (STPP, after SpecInfer [18] as the paper's baseline).

Both share the target model with PipeDec; STPP also shares the dynamic-tree
machinery — a "static" tree is simply built to full depth before a single
one-shot verification pass, instead of layer-per-timestep.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tree as tree_lib
from repro.core.speculative import (ModelBundle, SamplingParams,
                                    draft_candidates, select_token)


# --------------------------------------------------------------------------
# PP — plain autoregressive greedy/stochastic decode (1 token / pipeline pass)
# --------------------------------------------------------------------------
def generate_autoregressive(target: ModelBundle, prompt: np.ndarray,
                            max_new_tokens: int, *,
                            sampling: SamplingParams = SamplingParams(),
                            max_len: int = 512,
                            key: Optional[jax.Array] = None) -> np.ndarray:
    """Plain autoregressive decode (the paper's PP baseline): one token
    per full pipeline pass.
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    cache = target.init_cache(1, max_len)
    logits, cache = target.prefill(jnp.asarray(prompt, jnp.int32)[None], cache)
    prefix = (target.prefix_embeds.shape[1]
              if target.prefix_embeds is not None else 0)
    model_len = prefix + len(prompt)
    key, sk = jax.random.split(key)
    tok = int(select_token(logits[0], sampling, sk))
    out = [tok]
    for _ in range(max_new_tokens):
        logits, cache = target.decode(jnp.asarray([tok], jnp.int32), cache,
                                      model_len)
        model_len += 1
        key, sk = jax.random.split(key)
        tok = int(select_token(logits[0], sampling, sk))
        out.append(tok)
    return np.asarray(out[: 1 + max_new_tokens])


# --------------------------------------------------------------------------
# STPP — static tree speculative decoding over the pipeline
# --------------------------------------------------------------------------
@dataclasses.dataclass
class STPPConfig:
    """Static-tree speculative decoding config: fixed depth/width/branch
    per round (contrast: ``PipeDecConfig``'s dynamic tree).
    """
    depth: int = 4            # static tree depth per round
    width: int = 8
    branch: int = 4
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)

    @property
    def capacity(self) -> int:
        return 1 + self.width * self.depth


@dataclasses.dataclass
class STPPStats:
    """Per-request STPP counters (rounds, accepted tokens)."""
    rounds: int = 0
    commits: int = 0
    draft_steps: int = 0
    accepted_per_round: List[int] = dataclasses.field(default_factory=list)

    @property
    def mean_accepted(self) -> float:
        return float(np.mean(self.accepted_per_round)) if self.rounds else 0.0


class STPPEngine:
    """STPP baseline: draft a static tree, verify it in one batched
    target pass, accept the longest matching path, repeat.
    """
    def __init__(self, target: ModelBundle, draft: ModelBundle,
                 scfg: STPPConfig, max_len: int = 512):
        assert target.cfg.vocab_size == draft.cfg.vocab_size
        self.target, self.draft, self.scfg = target, draft, scfg
        self.max_len = max_len

    def generate(self, prompt: np.ndarray, max_new_tokens: int,
                 key: Optional[jax.Array] = None):
        s = self.scfg
        w, c, cap = s.width, s.branch, s.capacity
        tcap = cap + w
        key = key if key is not None else jax.random.PRNGKey(0)
        tgt, drf = self.target, self.draft

        t_cache = tgt.init_cache(1, self.max_len)
        d_cache = drf.init_cache(1, self.max_len)
        prompt_j = jnp.asarray(prompt, jnp.int32)[None]
        t_logits, t_cache = tgt.prefill(prompt_j, t_cache)
        _, d_cache = drf.prefill(prompt_j, d_cache)
        prefix = (tgt.prefix_embeds.shape[1]
                  if tgt.prefix_embeds is not None else 0)
        model_len = prefix + len(prompt)

        key, sk = jax.random.split(key)
        root = int(select_token(t_logits[0], s.sampling, sk))
        committed = [root]
        stats = STPPStats()

        while len(committed) < 1 + max_new_tokens:
            stats.rounds += 1
            tree = tree_lib.tree_init(cap, root)
            d_tree = drf.init_tree_caches(1, tcap)
            t_tree = tgt.init_tree_caches(1, tcap)

            # ---- draft builds the static tree, layer by layer -----------
            for _ in range(s.depth):
                tokens, idxs, valid, mask_rows = tree_lib.last_layer(tree, w)
                depths = jnp.where(valid, tree.depth[idxs], 0)
                positions = (model_len + depths)[None]
                pmask = jnp.pad(mask_rows, ((0, 0), (0, tcap - cap)))
                dlogits, d_tree = drf.tree_verify(
                    tokens[None], positions, pmask, d_cache, model_len,
                    d_tree, tree.layer_start)
                stats.draft_steps += 1
                cand_tok, cand_lp = draft_candidates(dlogits[0], valid, c)
                tree = tree_lib.tree_expand(tree, cand_tok, cand_lp, w)

            # ---- target verifies the whole tree in one pass --------------
            all_idx = jnp.arange(cap)
            valid_all = tree.valid()
            tokens_all = jnp.where(valid_all, tree.tokens, 0)
            depths_all = jnp.where(valid_all, tree.depth, 0)
            positions = (model_len + depths_all)[None]
            pmask = jnp.pad(tree.mask & valid_all[:, None],
                            ((0, 0), (0, tcap - cap)))
            v_logits, t_tree = tgt.tree_verify(
                tokens_all[None], positions, pmask, t_cache, model_len,
                t_tree, 0)
            v_logits = v_logits[0]  # [cap, V]

            # ---- greedy path walk (longest accepted prefix) --------------
            cur = 0
            accepted = 0
            while True:
                key, sk = jax.random.split(key)
                x = int(select_token(v_logits[cur], s.sampling, sk))
                committed.append(x)
                # migrate cur's KV into the model caches
                t_cache = tgt.commit(t_cache, t_tree, cur, model_len)
                d_cache = drf.commit(d_cache, d_tree, cur, model_len)
                model_len += 1
                nxt = int(tree_lib.find_child_with_token(tree, x, cur))
                if nxt < 0 or len(committed) >= 1 + max_new_tokens:
                    root = x
                    break
                cur = nxt
                accepted += 1
            stats.accepted_per_round.append(accepted)

        stats.commits = len(committed) - 1
        return np.asarray(committed[: 1 + max_new_tokens]), stats
