"""Dynamic prediction tree (paper §3.3) — fixed-capacity functional form.

The paper stores the tree as flat GPU arrays in BFS order: token array X,
probability array P, child-count array C and an ancestor mask matrix M, and
mutates them in place.  JAX needs static shapes, so the tree lives in a
fixed-capacity buffer of ``capacity`` slots with a packed prefix of
``n_nodes`` valid entries (BFS order preserved), and all three operations —
init / expand / prune — are pure functions:

  * ``tree_init``    — single root node (the last committed token).
  * ``tree_expand``  — append one layer: draft candidates ``[w, c]`` are
    scored by cumulative log-probability ``B = M·log P`` (paper's formula,
    computed incrementally via per-node cumulative logprob), the global
    top-``min(w, ...)`` are appended (paper §3.3.3).  Always appends a
    *fixed* ``w`` slots; invalid ones carry -inf logprob and are excluded
    from the mask, so downstream attention never sees them.
  * ``tree_prune_to_child`` — keep the subtree rooted at a depth-1 child and
    *compact* it back to the buffer prefix (the paper keeps dead entries in
    place; compaction is our TPU adaptation so the buffer never overflows).
    Returns the old→new index map so in-flight pipeline state (buffered
    logits, KV-cache rows) can be remapped identically.

The ancestor mask ``M`` is maintained incrementally like the paper's
block-matrix update: a new node's row = parent's row + its own one-hot.
``M`` is ancestor-or-self (diagonal set), exactly what tree attention needs.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-1e30)


class Tree(NamedTuple):
    """The dynamic prediction tree, packed into fixed-capacity arrays:
    per-node token/logprob/parent/depth, the ancestor-or-self
    attention mask and the packed prefix/deepest-layer bounds.
    """
    tokens: jnp.ndarray       # [N] int32
    logprob: jnp.ndarray      # [N] f32 cumulative log-prob from root (root=0)
    parent: jnp.ndarray       # [N] int32, -1 for root / invalid
    depth: jnp.ndarray        # [N] int32 (root=0), -1 invalid
    mask: jnp.ndarray         # [N, N] bool, ancestor-or-self
    n_nodes: jnp.ndarray      # () int32 packed prefix length
    layer_start: jnp.ndarray  # () int32 first index of the deepest layer
    layer_size: jnp.ndarray   # () int32 valid nodes in the deepest layer

    @property
    def capacity(self) -> int:
        return self.tokens.shape[0]

    def valid(self) -> jnp.ndarray:
        return jnp.arange(self.capacity) < self.n_nodes


def tree_init(capacity: int, root_token) -> Tree:
    """Fresh single-node tree holding ``root_token`` at index 0."""
    tokens = jnp.zeros((capacity,), jnp.int32).at[0].set(
        jnp.asarray(root_token, jnp.int32))
    logprob = jnp.full((capacity,), NEG_INF).at[0].set(0.0)
    parent = jnp.full((capacity,), -1, jnp.int32)
    depth = jnp.full((capacity,), -1, jnp.int32).at[0].set(0)
    mask = jnp.zeros((capacity, capacity), bool).at[0, 0].set(True)
    one = jnp.asarray(1, jnp.int32)
    return Tree(tokens, logprob, parent, depth, mask,
                n_nodes=one, layer_start=jnp.asarray(0, jnp.int32),
                layer_size=one)


def last_layer(tree: Tree, w: int) -> Tuple[jnp.ndarray, jnp.ndarray,
                                            jnp.ndarray, jnp.ndarray]:
    """Deepest layer padded to ``w``: (tokens [w], node_idx [w], valid [w],
    mask_rows [w, N] ancestor-or-self rows for those nodes)."""
    idx = tree.layer_start + jnp.arange(w)
    valid = jnp.arange(w) < tree.layer_size
    safe = jnp.where(valid, idx, 0)
    tokens = jnp.where(valid, tree.tokens[safe], 0)
    mask_rows = tree.mask[safe] & valid[:, None]
    return tokens, safe, valid, mask_rows


def tree_expand(tree: Tree, cand_tokens: jnp.ndarray,
                cand_logprobs: jnp.ndarray, w: int) -> Tree:
    """Append one layer from draft candidates of the current deepest layer.

    cand_tokens/cand_logprobs: [w, c] — row i corresponds to the i-th node of
    the deepest layer (padded rows must carry -inf logprob).  Appends exactly
    ``w`` buffer slots; ``layer_size`` counts the valid ones.
    """
    n = tree.capacity
    c = cand_tokens.shape[1]
    row_valid = jnp.arange(w) < tree.layer_size
    parent_idx = tree.layer_start + jnp.arange(w)
    parent_idx = jnp.where(row_valid, parent_idx, 0)

    # cumulative log-prob of each candidate = parent's cumulative + log q
    parent_lp = jnp.where(row_valid, tree.logprob[parent_idx], NEG_INF)
    cum = cand_logprobs + parent_lp[:, None]          # [w, c]
    cum = jnp.where(row_valid[:, None], cum, NEG_INF)

    flat = cum.reshape(-1)                            # [w*c]
    k = min(w, flat.shape[0])
    top_lp, top_ix = jax.lax.top_k(flat, k)
    # don't overflow the buffer
    space = n - tree.n_nodes
    slot_ok = (jnp.arange(k) < space) & (top_lp > NEG_INF / 2)
    new_size = slot_ok.sum().astype(jnp.int32)

    sel_parent = parent_idx[top_ix // c]
    sel_token = cand_tokens.reshape(-1)[top_ix]
    start = tree.n_nodes
    dest = start + jnp.arange(k, dtype=jnp.int32)
    dest_safe = jnp.where(slot_ok, dest, n)           # OOB -> dropped

    tokens = tree.tokens.at[dest_safe].set(sel_token, mode="drop")
    logprob = tree.logprob.at[dest_safe].set(top_lp, mode="drop")
    parent = tree.parent.at[dest_safe].set(sel_parent, mode="drop")
    depth = tree.depth.at[dest_safe].set(
        tree.depth[sel_parent] + 1, mode="drop")
    new_rows = tree.mask[sel_parent]                  # [k, N] parent rows
    new_rows = new_rows | jax.nn.one_hot(dest_safe, n, dtype=bool)
    mask = tree.mask.at[dest_safe].set(new_rows, mode="drop")

    return Tree(tokens, logprob, parent, depth, mask,
                n_nodes=start + new_size,
                layer_start=start, layer_size=new_size)


def find_child_with_token(tree: Tree, token, parent_idx=0) -> jnp.ndarray:
    """hit_index (paper §3.3.4): node index of the child of ``parent_idx``
    whose token equals ``token``; -1 on miss."""
    is_child = (tree.parent == parent_idx) & tree.valid()
    hit = is_child & (tree.tokens == jnp.asarray(token, jnp.int32))
    any_hit = hit.any()
    idx = jnp.argmax(hit)  # first (= highest-probability, BFS order) match
    return jnp.where(any_hit, idx, -1).astype(jnp.int32)


def root_argmax_child(tree: Tree) -> jnp.ndarray:
    """Most probable depth-1 child (for greedy draft-only flows)."""
    is_child = (tree.parent == 0) & (tree.depth == 1) & tree.valid()
    score = jnp.where(is_child, tree.logprob, NEG_INF)
    return jnp.argmax(score).astype(jnp.int32)


def tree_prune_to_child(tree: Tree, child_idx) -> Tuple[Tree, jnp.ndarray]:
    """Prune to the subtree rooted at ``child_idx`` (a depth-1 node) and
    compact (paper §3.3.4: keep = column ``M[:, hit]``).

    Returns (new_tree, index_map [N] int32) with index_map[i] = new index of
    old node i, or -1 if dropped.
    """
    n = tree.capacity
    keep = tree.mask[:, child_idx] & tree.valid()      # descendants-or-self
    index_map = jnp.where(keep, jnp.cumsum(keep) - 1, -1).astype(jnp.int32)
    new_n = keep.sum().astype(jnp.int32)

    # gather order: old indices of surviving nodes, BFS order preserved
    order_key = jnp.where(keep, jnp.arange(n), n + jnp.arange(n))
    g = jnp.argsort(order_key)                          # [N] old idx per new

    live = jnp.arange(n) < new_n
    tokens = jnp.where(live, tree.tokens[g], 0)
    logprob = jnp.where(live, tree.logprob[g] - tree.logprob[child_idx],
                        NEG_INF)
    depth = jnp.where(live, tree.depth[g] - 1, -1)
    old_parent = tree.parent[g]
    parent = jnp.where(live,
                       jnp.where(g == child_idx, -1,
                                 index_map[jnp.where(old_parent >= 0,
                                                     old_parent, 0)]),
                       -1).astype(jnp.int32)
    mask = tree.mask[g][:, g] & live[:, None] & live[None, :]
    # new root must not keep its old ancestors: the gather already dropped
    # them (they were not descendants of child_idx).

    new_layer_start = index_map[tree.layer_start]
    # the old deepest layer may have been partially pruned; count survivors
    old_layer = (jnp.arange(n) >= tree.layer_start) & \
        (jnp.arange(n) < tree.layer_start + tree.layer_size)
    surv = (old_layer & keep).sum().astype(jnp.int32)
    # if the whole old deepest layer died, the deepest layer is the last one
    # with any survivors; recompute from depth
    max_depth = jnp.max(jnp.where(live, depth, -1))
    is_deepest = live & (depth == max_depth)
    layer_start = jnp.argmax(is_deepest).astype(jnp.int32)
    layer_size = is_deepest.sum().astype(jnp.int32)

    return Tree(tokens, logprob, parent, depth, mask,
                n_nodes=new_n, layer_start=layer_start,
                layer_size=layer_size), index_map
