"""Draft-propose / target-verify machinery shared by PipeDec and STPP.

``ModelBundle`` wraps (params, cfg) with jitted step closures keyed on the
static shapes (tree width w, buffer capacity N), so the Python-level decode
loops stay recompile-free.

Token selection at commit time follows the paper: greedy => argmax of the
target logits at the accepted node; stochastic => sample from the target's
(temperature / top-k / top-p filtered) distribution.  Either way the emitted
token is drawn from the *target* model only — the tree merely decides how
much latency the commit costs — so the output distribution is lossless.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import tree as tree_lib
from repro.models import paging
from repro.models import transformer as tf
from repro.models.config import ModelConfig


@dataclasses.dataclass
class SamplingParams:
    """Sampling controls: temperature (0 => greedy), top-k, top-p."""
    temperature: float = 0.0  # 0 => greedy
    top_k: int = 0
    top_p: float = 1.0


def select_token(logits: jnp.ndarray, sp: SamplingParams, key) -> jnp.ndarray:
    """logits [V] -> token id ()."""
    if sp.temperature <= 0.0:
        return jnp.argmax(logits).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / sp.temperature
    if sp.top_k:
        kth = jax.lax.top_k(logits, sp.top_k)[0][-1]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if sp.top_p < 1.0:
        sorted_logits = jnp.sort(logits)[::-1]
        probs = jax.nn.softmax(sorted_logits)
        cum = jnp.cumsum(probs)
        cutoff_ix = jnp.sum(cum < sp.top_p)
        cutoff = sorted_logits[jnp.minimum(cutoff_ix, logits.shape[0] - 1)]
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


# projection-weight name -> number of leading contraction axes, for the
# int8 serving path (per-out-channel symmetric quantization).  Everything
# else (embeddings, norms, biases, lm_head) stays fp32.
QUANT_WEIGHTS = {"w_q": 1, "w_k": 1, "w_v": 1, "w_o": 2,
                 "w_gate": 1, "w_up": 1, "w_down": 1}


def _tree_verify_rows_impl(params, node_tokens, node_positions, tree_mask,
                           cache, cache_len, tree_caches, tree_write_index,
                           *, bucket: int, cfg, enc_out, window_override):
    """ONE fused tree-verify dispatch over the first ``bucket`` slot rows
    of slot-stacked caches (SpecPipe-DB).

    The full arena rides through unsliced; the static ``bucket`` bounds
    the rows actually read/computed, and the updated tree-cache rows are
    scattered back — so growing/shrinking occupancy only recompiles per
    bucket size (power-of-two slot-count bucketing), never per step.
    """
    cache_b = tf.slice_cache_rows(cache, 0, bucket)
    tc_view = tf.slice_cache_rows(tree_caches, 0, bucket)
    # paged arenas: gather dense views at dispatch entry (paged leaves
    # cannot ride the layer scan) and scatter the updated tree rows back
    # through the block tables at exit — still ONE dispatch per timestep
    logits, tc_b = tf.tree_verify_step(
        params, cfg=cfg, node_tokens=node_tokens,
        node_positions=node_positions, tree_mask=tree_mask,
        cache=paging.densify(cache_b), cache_len=cache_len,
        tree_caches=paging.densify(tc_view),
        tree_write_index=tree_write_index, enc_out=enc_out,
        window_override=window_override)
    if paging.any_paged(tc_view):
        tc_b = paging.repaginate(tc_view, tc_b)
    return logits, tf.update_cache_rows(tree_caches, tc_b, 0)


class ModelBundle:
    """params+cfg with jitted prefill / decode / tree-verify / commit.

    ``calls`` counts dispatches by closure name — the call-count hook the
    SpecPipe-DB equivalence tests use to assert the fused path issues
    exactly ONE tree-verify per model per global timestep.
    """

    def __init__(self, params, cfg: ModelConfig, *, enc_out=None,
                 prefix_embeds=None, window_override: int = -1):
        self.params = params
        self.cfg = cfg
        self.enc_out = enc_out
        self.prefix_embeds = prefix_embeds
        self.window_override = window_override
        self.calls = collections.Counter()

        self._prefill = jax.jit(functools.partial(
            tf.prefill, cfg=cfg, prefix_embeds=prefix_embeds,
            enc_out=enc_out, window_override=window_override),
            static_argnames=())
        self._decode = jax.jit(functools.partial(
            tf.decode_step, cfg=cfg, enc_out=enc_out,
            window_override=window_override))
        self._tree_verify = jax.jit(functools.partial(
            tf.tree_verify_step, cfg=cfg, enc_out=enc_out,
            window_override=window_override))
        self._tree_verify_rows = jax.jit(functools.partial(
            _tree_verify_rows_impl, cfg=cfg, enc_out=enc_out,
            window_override=window_override),
            static_argnames=("bucket",))
        self._commit = jax.jit(functools.partial(
            tf.commit_tree_node, cfg=cfg))
        self._commit_rows = jax.jit(functools.partial(
            tf.commit_tree_nodes, cfg))
        self._forward = jax.jit(functools.partial(
            tf.forward, cfg=cfg, prefix_embeds=prefix_embeds,
            enc_out=enc_out, window_override=window_override))

    # thin wrappers (keyword plumbing) -------------------------------------
    def prefill(self, tokens, cache):
        self.calls["prefill"] += 1
        return self._prefill(self.params, tokens=tokens, cache=cache)

    def decode(self, token, cache, cache_len):
        return self._decode(self.params, token=token, cache=cache,
                            cache_len=cache_len)

    def tree_verify(self, node_tokens, node_positions, tree_mask, cache,
                    cache_len, tree_caches, tree_write_index):
        self.calls["tree_verify"] += 1
        return self._tree_verify(
            self.params, node_tokens=node_tokens,
            node_positions=node_positions, tree_mask=tree_mask, cache=cache,
            cache_len=cache_len, tree_caches=tree_caches,
            tree_write_index=tree_write_index)

    def tree_verify_rows(self, node_tokens, node_positions, tree_mask,
                         cache, cache_len, tree_caches, tree_write_index,
                         *, bucket: int):
        """Fused per-timestep dispatch over slot-stacked caches: row b is
        request b's deepest tree layer, bounded by its own ``cache_len[b]``
        / ancestor mask, written at its own ``tree_write_index[b]``."""
        self.calls["tree_verify_rows"] += 1
        return self._tree_verify_rows(
            self.params, node_tokens=node_tokens,
            node_positions=node_positions, tree_mask=tree_mask, cache=cache,
            cache_len=cache_len, tree_caches=tree_caches,
            tree_write_index=tree_write_index, bucket=bucket)

    def commit(self, cache, tree_caches, node_idx, model_len):
        self.calls["commit"] += 1
        return self._commit(cache=cache, tree_caches=tree_caches,
                            node_idx=node_idx, model_len=model_len)

    def commit_rows(self, cache, tree_caches, node_idx, model_len,
                    commit_mask):
        """Batched per-row two-level cache sync (masked rows untouched)."""
        self.calls["commit_rows"] += 1
        return self._commit_rows(cache, tree_caches, node_idx, model_len,
                                 commit_mask)

    def init_cache(self, batch, max_len):
        return tf.init_cache(self.cfg, batch, max_len)

    def init_tree_caches(self, batch, capacity):
        return tf.init_tree_caches(self.cfg, batch, capacity)

    def quantize(self) -> "ModelBundle":
        """Int8 serving copy: projection weights become per-out-channel
        symmetric int8 ``{"q8", "scale"}`` dicts (converted ONCE here) and
        ``cfg.quant = "int8"`` switches every cache this bundle builds to
        the int8 KV layout.  Dense attention families only; this bundle is
        left untouched — the fp32 path stays the bit-pinned reference.
        """
        cfg = self.cfg
        unsupported = (cfg.mla is not None or cfg.moe is not None
                       or cfg.ssm is not None or cfg.rglru is not None
                       or cfg.is_encdec)
        assert not unsupported, (
            f"int8 serving supports dense attention only, got {cfg.name!r}")
        from repro.kernels.quant import quantize_weight

        def leaf(path, w):
            n_in = QUANT_WEIGHTS.get(getattr(path[-1], "key", None))
            if n_in is None:
                return w
            if getattr(path[0], "key", None) == "stack":
                # stacked scan leaves carry a leading reps dim: quantize
                # each layer independently; the scale keeps the reps dim
                # so per-layer slicing / stage reshapes stay tree-mapped.
                return jax.vmap(lambda t: quantize_weight(t, n_in))(w)
            return quantize_weight(w, n_in)

        q_params = jax.tree_util.tree_map_with_path(leaf, self.params)
        return ModelBundle(q_params, dataclasses.replace(cfg, quant="int8"),
                           enc_out=self.enc_out,
                           prefix_embeds=self.prefix_embeds,
                           window_override=self.window_override)


def remap_tree_caches(tree_caches, index_map, capacity: int):
    """Compact tree-cache rows with the same permutation as the tree
    (rows whose index_map == -1 are dropped; stale rows are never attended).

    Buffers may have ``capacity + w`` rows (slack for fixed-width layer
    writes) and, when stacked for scan-over-layers, a leading reps dim — the
    length axis is resolved per buffer name.
    """
    def perm(cap):
        im = jnp.concatenate([
            index_map,
            jnp.full((cap - index_map.shape[0],), -1, jnp.int32)])
        # inverse permutation: g[new] = old (dropped rows pushed to the end)
        return jnp.argsort(jnp.where(im >= 0, im, cap + jnp.arange(cap)))

    def gather(path, buf):
        if buf is None:
            return None
        if paging.is_paged(buf):
            # paged rows: gather the permuted dense rows through the block
            # table and scatter them back — same permutation per slot
            g = perm(buf.length)
            idx = jnp.broadcast_to(g[None], (buf.slots, buf.length))
            return paging.from_dense(buf, paging.take_len_rows(buf, idx))
        name = path[-1].key
        ax = tf.cache_len_axis(name, buf)
        return jnp.take(buf, perm(buf.shape[ax]), axis=ax)

    return jax.tree_util.tree_map_with_path(
        gather, tree_caches,
        is_leaf=lambda x: x is None or paging.is_paged(x))


def draft_candidates(logits: jnp.ndarray, valid: jnp.ndarray, c: int):
    """Per-node top-c candidates from draft logits.

    logits: [w, V]; valid: [w].  Returns (cand_tokens [w,c],
    cand_logprobs [w,c]) with invalid rows at -inf.
    """
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    top_lp, top_tok = jax.lax.top_k(logp, c)
    top_lp = jnp.where(valid[:, None], top_lp, tree_lib.NEG_INF)
    return top_tok.astype(jnp.int32), top_lp
