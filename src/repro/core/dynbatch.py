"""Batched prediction-tree state for dynamic batching (SpecPipe-DB).

The multi-request engine (``repro.serving.dynbatch``) keeps every in-flight
request's dynamic prediction tree stacked along a leading *slot* axis, the
paper's DB state layout: one fixed-capacity ``Tree`` buffer per KV slot,
all stored as a single pytree of ``[slots, ...]`` arrays.  Per-request
operations (init on admission, expand on proposal, prune-to-child on
commit) are the pure ``core.tree`` functions applied to one row and written
back, so a DB request's tree trace is bit-identical to the single-request
engine's — the property the equivalence tests pin.

``deepest_layers`` exposes the stacked view of every slot's entry layer
(tokens / indices / validity / ancestor-mask rows, all ``[slots, w, ...]``)
via ``jax.vmap`` — the fusion point: the DB engine feeds it (with per-row
``model_len`` / ``tree_write_index`` / masks) into ONE batched
``tree_verify`` dispatch per model per timestep
(``ModelBundle.tree_verify_rows``).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tree as tree_lib


class TreeBatch:
    """Fixed-slot store of prediction trees stacked along axis 0."""

    def __init__(self, slots: int, capacity: int):
        assert slots >= 1 and capacity >= 1
        self.slots, self.capacity = slots, capacity
        proto = tree_lib.tree_init(capacity, 0)
        self.stacked: tree_lib.Tree = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (slots, *x.shape)).copy(),
            proto)
        self.active = np.zeros((slots,), bool)

    # -- row access -----------------------------------------------------
    def _check(self, slot: int) -> None:
        assert 0 <= slot < self.slots, f"slot {slot} out of range"

    def get_row(self, slot: int) -> tree_lib.Tree:
        self._check(slot)
        return jax.tree.map(lambda x: x[slot], self.stacked)

    def set_row(self, slot: int, tree: tree_lib.Tree) -> None:
        self._check(slot)
        self.stacked = jax.tree.map(lambda b, r: b.at[slot].set(r),
                                    self.stacked, tree)

    # -- per-request tree ops (reuse core.tree on one row) --------------
    def init_row(self, slot: int, root_token: int) -> tree_lib.Tree:
        """Admission: fresh single-root tree in ``slot``."""
        t = tree_lib.tree_init(self.capacity, root_token)
        self.adopt_row(slot, t)
        return t

    def adopt_row(self, slot: int, tree: tree_lib.Tree) -> None:
        """Admission of an already-built tree (the decode state's)."""
        assert tree.capacity == self.capacity
        self.set_row(slot, tree)
        self.active[slot] = True

    def release_row(self, slot: int) -> None:
        """Retire: the slot may be recycled by the next admission."""
        self._check(slot)
        self.active[slot] = False

    def expand_row(self, slot: int, cand_tokens: jnp.ndarray,
                   cand_logprobs: jnp.ndarray, w: int) -> tree_lib.Tree:
        t = tree_lib.tree_expand(self.get_row(slot), cand_tokens,
                                 cand_logprobs, w)
        self.set_row(slot, t)
        return t

    def prune_row(self, slot: int,
                  child_idx) -> Tuple[tree_lib.Tree, jnp.ndarray]:
        """Prune one slot's tree to a depth-1 child; returns (tree,
        old→new index_map) so the caller can remap its in-flight state."""
        t, index_map = tree_lib.tree_prune_to_child(self.get_row(slot),
                                                    child_idx)
        self.set_row(slot, t)
        return t, index_map

    # -- stacked views ---------------------------------------------------
    def deepest_layers(self, w: int):
        """Every slot's entry layer, stacked: (tokens [S,w], idx [S,w],
        valid [S,w], mask_rows [S,w,N]).  Inactive slots still produce rows
        (their stale trees); the fused dispatch masks them with
        ``self.active`` / its pending set so they only ever write into
        their own slot's slack region."""
        return jax.vmap(lambda tr: tree_lib.last_layer(tr, w))(self.stacked)

    def occupancy(self) -> int:
        return int(self.active.sum())
