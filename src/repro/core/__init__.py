"""Algorithmic core of the reproduction: the dynamic token tree
(``tree``), the single-request SpecPipe engine (``pipedec``), chain/STPP
baselines, the fused-batch model seam (``speculative.ModelBundle``) and
the analytic latency/throughput models (``sim``).
"""
# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
