"""Jit'd wrappers dispatching to the Pallas kernels (interpret=True on CPU)
or the pure-jnp references.

``combine_lse`` merges partial attention results computed over disjoint KV
sources using their log-sum-exp stats — mathematically identical to a joint
softmax over the concatenation (flash-decoding combination), which is how
paper Algorithm 1's  softmax(concat(S_past, S_predict))  is realised
without materialising the concat.

Interpret-mode policy: the ``REPRO_KERNEL_INTERPRET`` env var sets the
module default (``INTERPRET``), but every dispatcher also takes an
explicit ``interpret=`` override resolved at *call time* — tests and
benchmarks flip modes per call (or by reassigning ``ops.INTERPRET``)
without reimporting.

Quantized paths: passing per-row ``k_scale``/``v_scale`` side tensors
marks K/V as symmetric int8 and fuses the dequant into the kernels;
``dequant_matmul``/``quant_matmul`` dispatch the fused int8-weight matmul
(kernel vs jnp oracle under the same policy, defaulting to the jnp path
unless ``REPRO_USE_PALLAS_QUANT=1`` — mirroring ``USE_PALLAS_ATTN``).
"""
from __future__ import annotations

import math
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import quant as qz
from repro.kernels import ref
from repro.kernels.flash import flash_attention_lse
from repro.kernels.tree_block import tree_block_attention

# On a real TPU set REPRO_KERNEL_INTERPRET=0; CPU CI runs interpret mode.
# This is only the *default* — dispatchers resolve it per call, so
# reassigning ops.INTERPRET (or passing interpret=) needs no reimport.
INTERPRET = os.environ.get("REPRO_KERNEL_INTERPRET", "1") != "0"

# Kernel-vs-jnp policy for the fused dequant-matmul at weight-projection
# call sites (interpret-mode Pallas is slow on CPU CI, so the jnp oracle
# is the host default, like USE_PALLAS_ATTN for the attention paths).
USE_PALLAS_QUANT = os.environ.get("REPRO_USE_PALLAS_QUANT", "0") == "1"


def _interp(interpret: Optional[bool]) -> bool:
    """Resolve the per-call override against the module default."""
    return INTERPRET if interpret is None else bool(interpret)


def combine_lse(parts):
    """parts: list of (o [B,H,n,hd], m [B,H,n,1+], l [B,H,n,1+]).

    o are normalised within their source; m/l are that source's softmax
    stats.  Returns the exact joint-softmax combination.
    """
    ms = jnp.stack([m[..., :1] for _, m, _ in parts])      # [P,B,H,n,1]
    m_all = jnp.max(ms, axis=0)
    num = 0.0
    den = 0.0
    for o, m, l in parts:
        w = l[..., :1] * jnp.exp(m[..., :1] - m_all)       # [B,H,n,1]
        num = num + w * o.astype(jnp.float32)
        den = den + w
    return (num / jnp.maximum(den, 1e-30))


def tree_attention(q, k_past, v_past, k_tree, v_tree, tree_mask, past_len,
                   *, scale=None, window: int = 0, qpos=None,
                   use_kernel: bool = True, block_k: int = 512,
                   interpret: Optional[bool] = None,
                   k_scale=None, v_scale=None, kt_scale=None,
                   vt_scale=None):
    """Two-level tree attention — see kernels/ref.py for the oracle.

    ``past_len`` may be a scalar or per-row [B], ``tree_mask`` [n,T] or
    per-row [B,n,T] (the SpecPipe-DB fused dispatch stacks one request per
    batch row, each with its own committed prefix and ancestor mask).

    Quantized caches pass int8 k/v plus per-row f32 scales
    (``k_scale``/``v_scale`` [B,KV,Lmax] for the past half,
    ``kt_scale``/``vt_scale`` [B,KV,T] for the tree half); the dequant
    fuses into both kernels, and the jnp fallback uses the quant oracle.
    """
    quant = k_scale is not None
    if not use_kernel:
        if quant:
            return ref.tree_attention_quant_ref(
                q, k_past, v_past, k_tree, v_tree, tree_mask, past_len,
                k_scale=k_scale, v_scale=v_scale, kt_scale=kt_scale,
                vt_scale=vt_scale, scale=scale)
        return ref.tree_attention_ref(q, k_past, v_past, k_tree, v_tree,
                                      tree_mask, past_len, scale=scale)
    it = _interp(interpret)
    op, mp, lp = flash_attention_lse(q, k_past, v_past, past_len, qpos,
                                     k_scale=k_scale, v_scale=v_scale,
                                     scale=scale, window=window,
                                     block_k=block_k, interpret=it)
    ot, mt, lt = tree_block_attention(q, k_tree, v_tree, tree_mask,
                                      k_scale=kt_scale, v_scale=vt_scale,
                                      scale=scale, interpret=it)
    out = combine_lse([(op, mp, lp), (ot, mt, lt)])
    return out.astype(q.dtype)


def paged_tree_attention(q, k_pool, v_pool, table, kt_pool, vt_pool,
                         t_table, tree_mask, past_len, *, scale=None,
                         use_kernel: bool = True,
                         interpret: Optional[bool] = None,
                         k_scale=None, v_scale=None, kt_scale=None,
                         vt_scale=None):
    """Two-level tree attention over *paged* caches: K/V live in blocked
    pools [Nb,KV,page,hd] indexed through per-slot block tables [B,mb]
    (``models.paging``), gathered tile-by-tile inside the kernels via
    scalar-prefetch table refs.  Same LSE combination as
    ``tree_attention``; int8 pools pass blocked per-row scale pools
    [Nb,KV,page]."""
    if not use_kernel:
        return ref.paged_tree_attention_ref(
            q, k_pool, v_pool, table, kt_pool, vt_pool, t_table, tree_mask,
            past_len, k_scale=k_scale, v_scale=v_scale, kt_scale=kt_scale,
            vt_scale=vt_scale, scale=scale)
    from repro.kernels.paged import (paged_flash_attention_lse,
                                     paged_tree_block_attention)
    it = _interp(interpret)
    op, mp, lp = paged_flash_attention_lse(q, k_pool, v_pool, table,
                                           past_len, k_scale=k_scale,
                                           v_scale=v_scale, scale=scale,
                                           interpret=it)
    ot, mt, lt = paged_tree_block_attention(q, kt_pool, vt_pool, t_table,
                                            tree_mask, k_scale=kt_scale,
                                            v_scale=vt_scale, scale=scale,
                                            interpret=it)
    out = combine_lse([(op, mp, lp), (ot, mt, lt)])
    return out.astype(q.dtype)


def paged_decode_attention(q, k_pool, v_pool, table, kv_len, *, scale=None,
                           window: int = 0, use_kernel: bool = True,
                           interpret: Optional[bool] = None,
                           k_scale=None, v_scale=None):
    """Flash-decode over a paged KV cache: pools [Nb,KV,page,hd] +
    block table [B,mb]; ``kv_len`` scalar or per-row [B]."""
    if not use_kernel:
        return ref.paged_decode_attention_ref(
            q, k_pool, v_pool, table, kv_len, k_scale=k_scale,
            v_scale=v_scale, window=window, scale=scale)
    from repro.kernels.paged import paged_flash_attention_lse
    n = q.shape[2]
    kv_len = jnp.asarray(kv_len, jnp.int32)
    qpos = jnp.broadcast_to((kv_len - 1).reshape(-1, 1)
                            if kv_len.ndim else kv_len - 1,
                            (q.shape[0], n))
    o, _, _ = paged_flash_attention_lse(q, k_pool, v_pool, table, kv_len,
                                        qpos, k_scale=k_scale,
                                        v_scale=v_scale, scale=scale,
                                        window=window,
                                        interpret=_interp(interpret))
    return o.astype(q.dtype)


def prefill_attention(q, k, v, positions, *, scale=None, window: int = 0,
                      block_k: int = 512, block_q: int = 512,
                      interpret: Optional[bool] = None):
    """Causal flash attention for prefill/training — q: [B,H,S,hd],
    k/v: [B,KV,S,hd], positions: [S]."""
    o, _, _ = flash_attention_lse(
        q, k, v, k.shape[2], positions, scale=scale, window=window,
        causal=True, block_k=block_k, block_q=min(block_q, q.shape[2]),
        interpret=_interp(interpret))
    return o.astype(q.dtype)


def decode_attention(q, k, v, kv_len, *, scale=None, window: int = 0,
                     use_kernel: bool = True, block_k: int = 512,
                     interpret: Optional[bool] = None,
                     k_scale=None, v_scale=None):
    """Single-/few-token decode over a long KV cache (optionally int8
    with per-row ``k_scale``/``v_scale`` [B,KV,Lmax] dequantized
    in-kernel)."""
    if not use_kernel:
        if k_scale is not None:
            return ref.decode_attention_quant_ref(
                q, k, v, kv_len, k_scale=k_scale, v_scale=v_scale,
                window=window, scale=scale)
        return ref.decode_attention_ref(q, k, v, kv_len, window=window,
                                        scale=scale)
    n = q.shape[2]
    qpos = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32) - 1, (n,))
    o, _, _ = flash_attention_lse(q, k, v, kv_len, qpos, k_scale=k_scale,
                                  v_scale=v_scale, scale=scale,
                                  window=window, block_k=block_k,
                                  interpret=_interp(interpret))
    return o.astype(q.dtype)


def dequant_matmul(x, w_q, w_scale, *, use_kernel: Optional[bool] = None,
                   interpret: Optional[bool] = None, block_m: int = 128,
                   block_n: int = 128, block_k: int = 128):
    """Fused dequant-matmul: x [M,K] f32 @ int8 w_q [K,N] with
    per-out-channel f32 scales [N] -> [M,N] f32.  ``use_kernel=None``
    follows the ``USE_PALLAS_QUANT`` module policy."""
    if use_kernel is None:
        use_kernel = USE_PALLAS_QUANT
    if not use_kernel:
        return ref.dequant_matmul_ref(x, w_q, w_scale)
    return qz.dequant_matmul_kernel(x, w_q, w_scale, block_m=block_m,
                                    block_n=block_n, block_k=block_k,
                                    interpret=_interp(interpret))


def quant_matmul(x, w, *, use_kernel: Optional[bool] = None,
                 interpret: Optional[bool] = None):
    """Apply a quantized weight dict ``{"q8", "scale"}`` to ``x``,
    contracting x's trailing axes with w's leading (first
    ``q8.ndim - scale.ndim``) axes — the generalised einsum every
    quantized projection call site routes through.  Shapes collapse to
    one 2-D ``dequant_matmul`` and reshape back."""
    q8, scale = w["q8"], w["scale"]
    nin = q8.ndim - scale.ndim
    kdim = math.prod(q8.shape[:nin])
    out_shape = q8.shape[nin:]
    batch = x.shape[:x.ndim - nin]
    y = dequant_matmul(x.reshape(-1, kdim).astype(jnp.float32),
                       q8.reshape(kdim, -1), scale.reshape(-1),
                       use_kernel=use_kernel, interpret=interpret)
    return y.reshape(*batch, *out_shape)
