"""Jit'd wrappers dispatching to the Pallas kernels (interpret=True on CPU)
or the pure-jnp references.

``combine_lse`` merges partial attention results computed over disjoint KV
sources using their log-sum-exp stats — mathematically identical to a joint
softmax over the concatenation (flash-decoding combination), which is how
paper Algorithm 1's  softmax(concat(S_past, S_predict))  is realised
without materialising the concat.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash import flash_attention_lse
from repro.kernels.tree_block import tree_block_attention

# On a real TPU set REPRO_KERNEL_INTERPRET=0; CPU CI runs interpret mode.
INTERPRET = os.environ.get("REPRO_KERNEL_INTERPRET", "1") != "0"


def combine_lse(parts):
    """parts: list of (o [B,H,n,hd], m [B,H,n,1+], l [B,H,n,1+]).

    o are normalised within their source; m/l are that source's softmax
    stats.  Returns the exact joint-softmax combination.
    """
    ms = jnp.stack([m[..., :1] for _, m, _ in parts])      # [P,B,H,n,1]
    m_all = jnp.max(ms, axis=0)
    num = 0.0
    den = 0.0
    for o, m, l in parts:
        w = l[..., :1] * jnp.exp(m[..., :1] - m_all)       # [B,H,n,1]
        num = num + w * o.astype(jnp.float32)
        den = den + w
    return (num / jnp.maximum(den, 1e-30))


def tree_attention(q, k_past, v_past, k_tree, v_tree, tree_mask, past_len,
                   *, scale=None, window: int = 0, qpos=None,
                   use_kernel: bool = True, block_k: int = 512):
    """Two-level tree attention — see kernels/ref.py for the oracle.

    ``past_len`` may be a scalar or per-row [B], ``tree_mask`` [n,T] or
    per-row [B,n,T] (the SpecPipe-DB fused dispatch stacks one request per
    batch row, each with its own committed prefix and ancestor mask).
    """
    if not use_kernel:
        return ref.tree_attention_ref(q, k_past, v_past, k_tree, v_tree,
                                      tree_mask, past_len, scale=scale)
    op, mp, lp = flash_attention_lse(q, k_past, v_past, past_len, qpos,
                                     scale=scale, window=window,
                                     block_k=block_k, interpret=INTERPRET)
    ot, mt, lt = tree_block_attention(q, k_tree, v_tree, tree_mask,
                                      scale=scale, interpret=INTERPRET)
    out = combine_lse([(op, mp, lp), (ot, mt, lt)])
    return out.astype(q.dtype)


def prefill_attention(q, k, v, positions, *, scale=None, window: int = 0,
                      block_k: int = 512, block_q: int = 512):
    """Causal flash attention for prefill/training — q: [B,H,S,hd],
    k/v: [B,KV,S,hd], positions: [S]."""
    o, _, _ = flash_attention_lse(
        q, k, v, k.shape[2], positions, scale=scale, window=window,
        causal=True, block_k=block_k, block_q=min(block_q, q.shape[2]),
        interpret=INTERPRET)
    return o.astype(q.dtype)


def decode_attention(q, k, v, kv_len, *, scale=None, window: int = 0,
                     use_kernel: bool = True, block_k: int = 512):
    """Single-/few-token decode over a long KV cache."""
    if not use_kernel:
        return ref.decode_attention_ref(q, k, v, kv_len, window=window,
                                        scale=scale)
    n = q.shape[2]
    qpos = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32) - 1, (n,))
    o, _, _ = flash_attention_lse(q, k, v, kv_len, qpos, scale=scale,
                                  window=window, block_k=block_k,
                                  interpret=INTERPRET)
    return o.astype(q.dtype)
