"""Flash attention with running-softmax statistics (Pallas, TPU target).

One kernel serves three call sites:
  * the *past* half of dynamic tree attention (validity = ``kv_len`` prefix,
    per-query sliding window optional),
  * single-token flash-decode over a long KV cache,
  * prefill/causal use via the per-query window/position masking.

The kernel streams K/V in ``block_k``-row VMEM tiles along the last grid
axis and keeps (acc, m, l) in VMEM scratch; outputs are the normalised
attention plus the (m, l) log-sum-exp stats so partial results over
different KV sources can be combined exactly (flash-decoding style) — this
is how the two-level (model + tree) cache attention is assembled without
concatenating caches.

VMEM budget per step ≈ q (n·hd) + 2·(block_k·hd) + acc (n·hd) floats; with
n ≤ 128, hd ≤ 256, block_k = 512 that is ≈ 1.3 MB — well inside the ~16 MB
VMEM of a TPU core, with MXU-aligned (128-multiple) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# jax renamed pltpu.TPUCompilerParams -> pltpu.CompilerParams; accept either.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def _flash_kernel(plen_ref, q_ref, k_ref, v_ref, *rest, scale, block_k,
                  window, causal, quant):
    # quantized K/V ride with per-row scale side refs ([bk] per tile,
    # same index map as k/v) that dequantize in-kernel before the fp32
    # QK^T / PV accumulation
    if quant:
        (ks_ref, vs_ref, qpos_ref, o_ref, m_ref, l_ref,
         acc_ref, ms_ref, ls_ref) = rest
    else:
        qpos_ref, o_ref, m_ref, l_ref, acc_ref, ms_ref, ls_ref = rest
    kb = pl.program_id(3)
    nb = pl.num_programs(3)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [n, hd]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)                  # [bk, hd]
    if quant:
        k = k * ks_ref[0, 0][:, None]
        v = v * vs_ref[0, 0][:, None]
    n = q.shape[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [n, bk]

    kpos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (n, block_k), 1)
    plen = plen_ref[pl.program_id(0)]     # per-batch-row valid prefix
    valid = kpos < plen
    if causal or window > 0:
        qp = qpos_ref[0, 0][:, :1]                       # [n, 1] int32
        if causal:
            valid &= kpos <= qp
        if window > 0:
            valid &= kpos > qp - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = ms_ref[:, :1]                               # [n, 1]
    l_prev = ls_ref[:, :1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)           # [n, 1]
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                               # [n, bk]
    p = jnp.where(valid, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)                      # [n, 1]
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    ms_ref[...] = jnp.broadcast_to(m_new, ms_ref.shape)
    ls_ref[...] = jnp.broadcast_to(l_new, ls_ref.shape)

    @pl.when(kb == nb - 1)
    def _finalize():
        l = ls_ref[:, :1]
        safe = jnp.maximum(l, 1e-30)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)
        m_ref[0, 0] = ms_ref[...].astype(m_ref.dtype)
        l_ref[0, 0] = ls_ref[...].astype(l_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "block_q", "window",
                                             "interpret", "scale", "causal"))
def flash_attention_lse(q, k, v, kv_len, qpos=None, *, k_scale=None,
                        v_scale=None, scale=None,
                        block_k: int = 512, block_q: int = 0,
                        window: int = 0, causal: bool = False,
                        interpret: bool = True):
    """q: [B,H,n,hd]; k/v: [B,KV,L,hd]; kv_len: () or per-row [B] int32
    valid prefix (a scalar broadcasts over the batch).

    qpos: [n] or per-row [B,n] int32 absolute query positions (required
    when window > 0 or causal).  block_q tiles the query dim (0 => one tile
    — decode/tree widths; prefill passes e.g. 512).  k_scale/v_scale
    [B,KV,L] f32 mark k/v as per-row symmetric int8: each block_k tile of
    scales rides beside its K/V tile and the dequant fuses into the
    kernel (fp32 accumulate unchanged).  Returns
    (o [B,H,n,hd], m [B,H,n,128], l [B,H,n,128]) — lane-replicated LSE
    stats for flash-decoding combination.
    """
    quant = k_scale is not None
    b, h, n0, hd = q.shape
    kvh, lmax = k.shape[1], k.shape[2]
    rep = h // kvh
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    if lmax % block_k:
        pad = block_k - lmax % block_k
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        if quant:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, 0), (0, pad)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, 0), (0, pad)))
        lmax += pad
    nb = lmax // block_k
    if qpos is None:
        qpos = jnp.zeros((n0,), jnp.int32)
    qpos = jnp.asarray(qpos, jnp.int32)
    if qpos.ndim == 1:
        qpos = jnp.broadcast_to(qpos[None], (b, n0))
    bq = block_q or n0
    qpad = (-n0) % bq
    n = n0 + qpad
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, qpad), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, qpad)))
    nq = n // bq
    qpos2 = jnp.broadcast_to(qpos[:, None, :, None],
                             (b, 1, n, 128)).astype(jnp.int32)
    plen = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,))

    grid = (b, h, nq, nb)
    kernel = functools.partial(_flash_kernel, scale=scale, block_k=block_k,
                               window=window, causal=causal, quant=quant)
    out_shape = [
        jax.ShapeDtypeStruct((b, h, n, hd), q.dtype),
        jax.ShapeDtypeStruct((b, h, n, 128), jnp.float32),
        jax.ShapeDtypeStruct((b, h, n, 128), jnp.float32),
    ]
    kv_spec = pl.BlockSpec((1, 1, block_k, hd),
                           lambda i, j, qi, kb, *_: (i, j // rep, kb, 0))
    scale_specs, scale_args = [], []
    if quant:
        scale_specs = [pl.BlockSpec((1, 1, block_k),
                                    lambda i, j, qi, kb, *_:
                                    (i, j // rep, kb))] * 2
        scale_args = [k_scale.astype(jnp.float32),
                      v_scale.astype(jnp.float32)]
    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bq, hd),
                             lambda i, j, qi, kb, *_: (i, j, qi, 0)),
                kv_spec,
                kv_spec,
                *scale_specs,
                pl.BlockSpec((1, 1, bq, 128),
                             lambda i, j, qi, kb, *_: (i, 0, qi, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, bq, hd),
                             lambda i, j, qi, kb, *_: (i, j, qi, 0)),
                pl.BlockSpec((1, 1, bq, 128),
                             lambda i, j, qi, kb, *_: (i, j, qi, 0)),
                pl.BlockSpec((1, 1, bq, 128),
                             lambda i, j, qi, kb, *_: (i, j, qi, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((bq, hd), jnp.float32),
                pltpu.VMEM((bq, 128), jnp.float32),
                pltpu.VMEM((bq, 128), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(plen, q, k, v, *scale_args, qpos2)
    if qpad:
        o, m, l = o[:, :, :n0], m[:, :, :n0], l[:, :, :n0]
    return o, m, l
