"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_attention_ref(q, k_past, v_past, k_tree, v_tree, tree_mask,
                       past_len, *, scale=None):
    """Two-level tree attention (paper Algorithm 1), dense reference.

    q:        [B, H, n, hd]
    k_past:   [B, KV, Lmax, hd]   (valid rows: < past_len)
    v_past:   [B, KV, Lmax, hd]
    k_tree:   [B, KV, T, hd]
    v_tree:   [B, KV, T, hd]
    tree_mask:[n, T] or per-row [B, n, T] bool — ancestor-or-self mask
              (True = attend)
    past_len: scalar int, or per-row [B] int
    Returns   [B, H, n, hd].
    """
    b, h, n, hd = q.shape
    kvh = k_past.shape[1]
    rep = h // kvh
    if rep > 1:
        k_past = jnp.repeat(k_past, rep, axis=1)
        v_past = jnp.repeat(v_past, rep, axis=1)
        k_tree = jnp.repeat(k_tree, rep, axis=1)
        v_tree = jnp.repeat(v_tree, rep, axis=1)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd)
    lp = jnp.einsum("bhnd,bhsd->bhns", q, k_past).astype(jnp.float32) * scale
    lt = jnp.einsum("bhnd,bhsd->bhns", q, k_tree).astype(jnp.float32) * scale
    lmax = k_past.shape[2]
    plen = jnp.broadcast_to(jnp.asarray(past_len, jnp.int32).reshape(-1),
                            (b,))
    past_ok = jnp.arange(lmax)[None, None, None, :] < \
        plen[:, None, None, None]
    tmask = tree_mask if tree_mask.ndim == 3 else tree_mask[None]
    lp = jnp.where(past_ok, lp, -jnp.inf)
    lt = jnp.where(tmask[:, None], lt, -jnp.inf)
    logits = jnp.concatenate([lp, lt], axis=-1)
    probs = jax.nn.softmax(logits, axis=-1)
    pv = probs[..., :lmax].astype(v_past.dtype)
    pt = probs[..., lmax:].astype(v_tree.dtype)
    out = jnp.einsum("bhns,bhsd->bhnd", pv, v_past) + \
        jnp.einsum("bhns,bhsd->bhnd", pt, v_tree)
    return out


def _dequant(q8, row_scale):
    """int8 values [..., L, hd] * per-row f32 scales [..., L] -> f32."""
    return q8.astype(jnp.float32) * row_scale[..., None]


def tree_attention_quant_ref(q, k_past, v_past, k_tree, v_tree, tree_mask,
                             past_len, *, k_scale, v_scale, kt_scale,
                             vt_scale, scale=None):
    """Quantized two-level tree attention oracle: int8 K/V with per-row
    f32 scales (``k_scale``/``v_scale`` [B, KV, Lmax], ``kt_scale``/
    ``vt_scale`` [B, KV, T]) are dequantized densely, then fed through the
    fp32 reference — what the fused kernels must match."""
    return tree_attention_ref(
        q, _dequant(k_past, k_scale), _dequant(v_past, v_scale),
        _dequant(k_tree, kt_scale), _dequant(v_tree, vt_scale),
        tree_mask, past_len, scale=scale)


def dequant_matmul_ref(x, w_q, w_scale):
    """Fused dequant-matmul oracle: x [M, K] f32 @ int8 w_q [K, N] with
    per-out-channel f32 scales [N] -> [M, N] f32 (scale applied after the
    fp32 accumulation, matching the kernel's association)."""
    acc = x.astype(jnp.float32) @ w_q.astype(jnp.float32)
    return acc * w_scale


def decode_attention_ref(q, k, v, kv_len, *, window=0, scale=None):
    """Flash-decode reference: q [B, H, 1, hd] vs cache k/v [B, KV, Lmax, hd]
    with ``kv_len`` valid rows, optional sliding window. -> [B, H, 1, hd]."""
    b, h, _, hd = q.shape
    kvh = k.shape[1]
    rep = h // kvh
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd)
    logits = jnp.einsum("bhnd,bhsd->bhns", q, k).astype(jnp.float32) * scale
    pos = jnp.arange(k.shape[2])[None, None, None, :]
    ok = pos < kv_len
    if window:
        ok &= pos > kv_len - 1 - window
    logits = jnp.where(ok, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhns,bhsd->bhnd", probs, v)


def decode_attention_quant_ref(q, k, v, kv_len, *, k_scale, v_scale,
                               window=0, scale=None):
    """Quantized flash-decode oracle: int8 k/v [B, KV, Lmax, hd] with
    per-row f32 scales [B, KV, Lmax], dequantized then scored in fp32."""
    return decode_attention_ref(q, _dequant(k, k_scale),
                                _dequant(v, v_scale), kv_len,
                                window=window, scale=scale)


def paged_gather_ref(pool, table, length):
    """Dense view of a paged pool: pool [Nb, KV, page, hd] (or scales
    [Nb, KV, page]) gathered through ``table`` [B, mb] into
    [B, KV, length, ...] — the oracle form of the kernels' in-BlockSpec
    table indirection (unallocated logical blocks read physical block 0,
    the null block, whose rows every mask excludes)."""
    page = pool.shape[2]
    ls = jnp.arange(length)
    blk = table[:, ls // page]                       # [B, L]
    g = pool[blk]                                    # [B, L, KV, page, ...]
    r = (ls % page).reshape(1, length, 1, 1, *([1] * (g.ndim - 4)))
    r = jnp.broadcast_to(r, g.shape[:3] + (1,) + g.shape[4:])
    g = jnp.take_along_axis(g, r, axis=3).squeeze(3)  # [B, L, KV, ...]
    return jnp.moveaxis(g, 1, 2)                     # [B, KV, L, ...]


def paged_decode_attention_ref(q, k_pool, v_pool, table, kv_len, *,
                               k_scale=None, v_scale=None, window=0,
                               scale=None):
    """Paged flash-decode oracle: gather the dense view through the block
    table, then the dense reference (quant oracle when scales ride)."""
    length = table.shape[1] * k_pool.shape[2]
    k = paged_gather_ref(k_pool, table, length)
    v = paged_gather_ref(v_pool, table, length)
    if k_scale is not None:
        k = _dequant(k, paged_gather_ref(k_scale, table, length))
        v = _dequant(v, paged_gather_ref(v_scale, table, length))
    kv_len = jnp.asarray(kv_len, jnp.int32)
    if kv_len.ndim:
        kv_len = kv_len.reshape(-1, 1, 1, 1)         # per-row [B]
    return decode_attention_ref(q, k, v, kv_len, window=window, scale=scale)


def paged_tree_attention_ref(q, k_pool, v_pool, table, kt_pool, vt_pool,
                             t_table, tree_mask, past_len, *, k_scale=None,
                             v_scale=None, kt_scale=None, vt_scale=None,
                             scale=None):
    """Paged two-level tree attention oracle: both halves gathered dense
    through their tables, then the joint-softmax reference."""
    lp = table.shape[1] * k_pool.shape[2]
    tcap = tree_mask.shape[-1]
    kp = paged_gather_ref(k_pool, table, lp)
    vp = paged_gather_ref(v_pool, table, lp)
    kt = paged_gather_ref(kt_pool, t_table, tcap)
    vt = paged_gather_ref(vt_pool, t_table, tcap)
    if k_scale is not None:
        kp = _dequant(kp, paged_gather_ref(k_scale, table, lp))
        vp = _dequant(vp, paged_gather_ref(v_scale, table, lp))
        kt = _dequant(kt, paged_gather_ref(kt_scale, t_table, tcap))
        vt = _dequant(vt, paged_gather_ref(vt_scale, t_table, tcap))
    return tree_attention_ref(q, kp, vp, kt, vt, tree_mask, past_len,
                              scale=scale)
