"""Tree-suffix attention block (Pallas) — the speculative half of the
paper's dynamic tree attention.

The tree buffer is small (w·d ≤ a few hundred nodes), so it is one VMEM
tile: a single grid step per (batch, head) computes the masked softmax
against the ancestor mask and emits (o, m, l) stats for exact combination
with the past half (``kernels.flash``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# jax renamed pltpu.TPUCompilerParams -> pltpu.CompilerParams; accept either.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def _tree_kernel(q_ref, k_ref, v_ref, mask_ref, *rest, scale, quant):
    # quantized K/V carry per-row scale side refs ([t] each, same head
    # index map) dequantized in-kernel before the fp32 masked softmax
    if quant:
        ks_ref, vs_ref, o_ref, m_ref, l_ref = rest
    else:
        o_ref, m_ref, l_ref = rest
    q = q_ref[0, 0].astype(jnp.float32) * scale          # [n, hd]
    k = k_ref[0, 0].astype(jnp.float32)                  # [t, hd]
    v = v_ref[0, 0].astype(jnp.float32)
    if quant:
        k = k * ks_ref[0, 0][:, None]
        v = v * vs_ref[0, 0][:, None]
    mask = mask_ref[0] != 0                              # [n, t] (this row's)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)               # [n, 1]
    p = jnp.where(mask, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o = o / jnp.maximum(l, 1e-30)
    o_ref[0, 0] = o.astype(o_ref.dtype)
    m_ref[0, 0] = jnp.broadcast_to(m, m_ref.shape[2:]).astype(jnp.float32)
    l_ref[0, 0] = jnp.broadcast_to(l, l_ref.shape[2:]).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret", "scale"))
def tree_block_attention(q, k_tree, v_tree, tree_mask, *, k_scale=None,
                         v_scale=None, scale=None, interpret: bool = True):
    """q: [B,H,n,hd]; k/v_tree: [B,KV,T,hd]; tree_mask: [n,T] bool, or
    per-row [B,n,T] (SpecPipe-DB fused dispatch: each batch row is a
    different request's tree, so each row carries its own ancestor mask).
    k_scale/v_scale [B,KV,T] f32 mark k/v_tree as per-row symmetric int8;
    the dequant fuses into the kernel.

    Returns (o [B,H,n,hd], m [B,H,n,128], l [B,H,n,128]).
    """
    quant = k_scale is not None
    b, h, n, hd = q.shape
    kvh, t = k_tree.shape[1], k_tree.shape[2]
    rep = h // kvh
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    if tree_mask.ndim == 2:
        tree_mask = tree_mask[None]
    mask_i8 = jnp.broadcast_to(tree_mask, (b, n, t)).astype(jnp.int8)

    scale_specs, scale_args = [], []
    if quant:
        scale_specs = [pl.BlockSpec((1, 1, t),
                                    lambda i, j: (i, j // rep, 0))] * 2
        scale_args = [k_scale.astype(jnp.float32),
                      v_scale.astype(jnp.float32)]
    out_shape = [
        jax.ShapeDtypeStruct((b, h, n, hd), q.dtype),
        jax.ShapeDtypeStruct((b, h, n, 128), jnp.float32),
        jax.ShapeDtypeStruct((b, h, n, 128), jnp.float32),
    ]
    o, m, l = pl.pallas_call(
        functools.partial(_tree_kernel, scale=scale, quant=quant),
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, n, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, hd), lambda i, j: (i, j // rep, 0, 0)),
            pl.BlockSpec((1, 1, t, hd), lambda i, j: (i, j // rep, 0, 0)),
            pl.BlockSpec((1, n, t), lambda i, j: (i, 0, 0)),
            *scale_specs,
        ],
        out_specs=[
            pl.BlockSpec((1, 1, n, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, n, 128), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, n, 128), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(q, k_tree, v_tree, mask_i8, *scale_args)
    return o, m, l
