"""Paged variants of the attention kernels (Pallas, TPU target).

The dense kernels in ``kernels.flash`` / ``kernels.tree_block`` read K/V
from contiguous ``[B, KV, L, hd]`` caches.  Here the cache is a *paged*
arena (``models.paging``): a flat pool of physical blocks

    k_pool / v_pool : [Nb, KV, page, hd]
    table           : [B, mb] int32     logical block -> physical block

and each grid step's K/V tile is gathered *through the block table* — the
table rides as a scalar-prefetch ref (the same side-ref idiom as the
PR-6 ``k_scale``/``v_scale`` plumbing) and the BlockSpec index map picks
``tab_ref[b, kb]`` as the pool row for logical block ``kb``.  Masking
stays logical: position ``kb * page + r`` is compared against the valid
prefix / ancestor mask exactly as in the dense kernels, so physical
block 0 (the null block every unallocated logical block aliases) is
read but always masked out.

Composes with the int8 path: per-row scales live in blocked pools
``[Nb, KV, page]`` and ride the same table-indexed maps.

``paged_flash_attention_lse`` reuses the dense ``_flash_kernel`` body
unchanged — grid axis 3 already iterates K/V tiles in logical order, so
``block_k = page`` makes its position arithmetic the logical positions;
only the index maps change.  The tree half needs a restructure: the
dense tree kernel is single-tile, but a paged tree is one tile *per
block*, so ``_paged_tree_kernel`` is the running-accumulation
(init / accumulate / finalize) form of the same masked softmax.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash import NEG_INF, _CompilerParams, _flash_kernel


def _paged_flash_kernel(plen_ref, tab_ref, *args, **kw):
    # tab_ref is consumed by the BlockSpec index maps only
    del tab_ref
    _flash_kernel(plen_ref, *args, **kw)


@functools.partial(jax.jit, static_argnames=("window", "interpret", "scale",
                                             "causal"))
def paged_flash_attention_lse(q, k_pool, v_pool, table, kv_len, qpos=None, *,
                              k_scale=None, v_scale=None, scale=None,
                              window: int = 0, causal: bool = False,
                              interpret: bool = True):
    """q: [B,H,n,hd]; k_pool/v_pool: [Nb,KV,page,hd]; table: [B,mb] int32;
    kv_len: () or per-row [B] int32 valid prefix.  k_scale/v_scale
    [Nb,KV,page] mark the pools as per-row symmetric int8.  Returns
    (o [B,H,n,hd], m [B,H,n,128], l [B,H,n,128]) like the dense kernel.
    """
    quant = k_scale is not None
    b, h, n, hd = q.shape
    kvh, page = k_pool.shape[1], k_pool.shape[2]
    mb = table.shape[1]
    rep = h // kvh
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    if qpos is None:
        qpos = jnp.zeros((n,), jnp.int32)
    qpos = jnp.asarray(qpos, jnp.int32)
    if qpos.ndim == 1:
        qpos = jnp.broadcast_to(qpos[None], (b, n))
    qpos2 = jnp.broadcast_to(qpos[:, None, :, None],
                             (b, 1, n, 128)).astype(jnp.int32)
    plen = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,))
    table = jnp.asarray(table, jnp.int32)

    grid = (b, h, 1, mb)
    kernel = functools.partial(_paged_flash_kernel, scale=scale,
                               block_k=page, window=window, causal=causal,
                               quant=quant)
    out_shape = [
        jax.ShapeDtypeStruct((b, h, n, hd), q.dtype),
        jax.ShapeDtypeStruct((b, h, n, 128), jnp.float32),
        jax.ShapeDtypeStruct((b, h, n, 128), jnp.float32),
    ]
    # the paged gather: pool row = table[batch, logical block]
    kv_spec = pl.BlockSpec(
        (1, 1, page, hd),
        lambda i, j, qi, kb, plen_ref, tab_ref: (tab_ref[i, kb], j // rep,
                                                 0, 0))
    scale_specs, scale_args = [], []
    if quant:
        scale_specs = [pl.BlockSpec(
            (1, 1, page),
            lambda i, j, qi, kb, plen_ref, tab_ref: (tab_ref[i, kb],
                                                     j // rep, 0))] * 2
        scale_args = [k_scale.astype(jnp.float32),
                      v_scale.astype(jnp.float32)]
    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, n, hd),
                             lambda i, j, qi, kb, *_: (i, j, 0, 0)),
                kv_spec,
                kv_spec,
                *scale_specs,
                pl.BlockSpec((1, 1, n, 128),
                             lambda i, j, qi, kb, *_: (i, 0, 0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, n, hd),
                             lambda i, j, qi, kb, *_: (i, j, 0, 0)),
                pl.BlockSpec((1, 1, n, 128),
                             lambda i, j, qi, kb, *_: (i, j, 0, 0)),
                pl.BlockSpec((1, 1, n, 128),
                             lambda i, j, qi, kb, *_: (i, j, 0, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((n, hd), jnp.float32),
                pltpu.VMEM((n, 128), jnp.float32),
                pltpu.VMEM((n, 128), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(plen, table, q, k_pool, v_pool, *scale_args, qpos2)
    return o, m, l


def _paged_tree_kernel(tab_ref, q_ref, k_ref, v_ref, mask_ref, *rest,
                       scale, quant):
    # running-accumulation form of the dense tree kernel: one grid step
    # per logical tree block, (acc, m, l) carried in VMEM scratch
    del tab_ref
    if quant:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref, ms_ref, ls_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref, ms_ref, ls_ref = rest
    kb = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [n, hd]
    k = k_ref[0, 0].astype(jnp.float32)                  # [page, hd]
    v = v_ref[0, 0].astype(jnp.float32)
    if quant:
        k = k * ks_ref[0, 0][:, None]
        v = v * vs_ref[0, 0][:, None]
    mask = mask_ref[0] != 0                              # [n, page]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = ms_ref[:, :1]
    l_prev = ls_ref[:, :1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    ms_ref[...] = jnp.broadcast_to(m_new, ms_ref.shape)
    ls_ref[...] = jnp.broadcast_to(l_new, ls_ref.shape)

    @pl.when(kb == nb - 1)
    def _finalize():
        l = ls_ref[:, :1]
        safe = jnp.maximum(l, 1e-30)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)
        m_ref[0, 0] = ms_ref[...].astype(m_ref.dtype)
        l_ref[0, 0] = ls_ref[...].astype(l_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "scale"))
def paged_tree_block_attention(q, k_pool, v_pool, table, tree_mask, *,
                               k_scale=None, v_scale=None, scale=None,
                               interpret: bool = True):
    """Paged tree-suffix attention: q [B,H,n,hd]; k/v pools
    [Nb,KV,page,hd] indexed by ``table`` [B,mb]; tree_mask [n,T] or
    per-row [B,n,T] bool over the *logical* tree positions
    (T <= mb * page; the tail of the last block is force-masked).
    Returns (o, m[.,128], l[.,128]) stats for LSE combination."""
    quant = k_scale is not None
    b, h, n, hd = q.shape
    kvh, page = k_pool.shape[1], k_pool.shape[2]
    mb = table.shape[1]
    rep = h // kvh
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    if tree_mask.ndim == 2:
        tree_mask = tree_mask[None]
    t = tree_mask.shape[-1]
    mask_i8 = jnp.broadcast_to(tree_mask, (b, n, t)).astype(jnp.int8)
    pad = mb * page - t
    if pad:
        mask_i8 = jnp.pad(mask_i8, ((0, 0), (0, 0), (0, pad)))
    table = jnp.asarray(table, jnp.int32)

    kv_spec = pl.BlockSpec(
        (1, 1, page, hd),
        lambda i, j, kb, tab_ref: (tab_ref[i, kb], j // rep, 0, 0))
    scale_specs, scale_args = [], []
    if quant:
        scale_specs = [pl.BlockSpec(
            (1, 1, page),
            lambda i, j, kb, tab_ref: (tab_ref[i, kb], j // rep, 0))] * 2
        scale_args = [k_scale.astype(jnp.float32),
                      v_scale.astype(jnp.float32)]
    out_shape = [
        jax.ShapeDtypeStruct((b, h, n, hd), q.dtype),
        jax.ShapeDtypeStruct((b, h, n, 128), jnp.float32),
        jax.ShapeDtypeStruct((b, h, n, 128), jnp.float32),
    ]
    o, m, l = pl.pallas_call(
        functools.partial(_paged_tree_kernel, scale=scale, quant=quant),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, h, mb),
            in_specs=[
                pl.BlockSpec((1, 1, n, hd), lambda i, j, kb, *_: (i, j, 0,
                                                                  0)),
                kv_spec,
                kv_spec,
                # the mask indexes LOGICAL blocks (not through the table)
                pl.BlockSpec((1, n, page), lambda i, j, kb, *_: (i, 0, kb)),
                *scale_specs,
            ],
            out_specs=[
                pl.BlockSpec((1, 1, n, hd), lambda i, j, kb, *_: (i, j, 0,
                                                                  0)),
                pl.BlockSpec((1, 1, n, 128), lambda i, j, kb, *_: (i, j, 0,
                                                                   0)),
                pl.BlockSpec((1, 1, n, 128), lambda i, j, kb, *_: (i, j, 0,
                                                                   0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((n, hd), jnp.float32),
                pltpu.VMEM((n, 128), jnp.float32),
                pltpu.VMEM((n, 128), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(table, q, k_pool, v_pool, mask_i8, *scale_args)
    return o, m, l
