"""Per-block symmetric int8 quantization + the fused dequant-matmul kernel.

Two quantization granularities serve the serving path:

  * **KV rows** (``quantize_rows``): one f32 scale per cache row, i.e. per
    (batch, position, kv-head) slice, reducing over ``head_dim``.  This is
    tile-granular with respect to the attention kernels' ``block_k`` K/V
    tiling — every ``block_k``-row tile of int8 K/V pairs with the same
    ``block_k``-row tile of scales, so the scales ride the Pallas kernels
    as side refs with identical index maps and the dequant fuses into the
    QK^T / PV loads (fp32 accumulate, as before).
  * **weights** (``quantize_weight``): one f32 scale per *output channel*
    (the trailing axes of the projection), reducing over the contraction
    axes.  A quantized weight is the dict ``{"q8": int8, "scale": f32}``
    where the contraction axes are the first ``q8.ndim - scale.ndim`` axes
    — the convention ``ops.quant_matmul`` applies at every projection call
    site.

Symmetric scheme: ``scale = amax / 127`` (zero slices get scale 1 so the
round-trip is exact zeros, never NaN), ``q = clip(round(x / scale))``,
``dequant = q * scale``.  Round-trip error is bounded by ``scale / 2 =
amax / 254`` per element.

The fused dequant-matmul kernel streams int8 weight tiles through VMEM,
accumulates x @ w in fp32 over ``block_k`` contraction tiles, and applies
the per-out-channel scales once at the final tile — int8 bytes on the
memory bus, fp32 math on the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Q_MAX = 127.0

# jax renamed pltpu.TPUCompilerParams -> pltpu.CompilerParams; accept either.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def quantize_rows(x, axis: int = -1):
    """Symmetric int8 with one scale per slice along ``axis``.

    Returns ``(q int8, scale f32)`` where ``q`` keeps ``x``'s shape and
    ``scale`` drops ``axis``.  All-zero slices quantize to exact zeros
    (scale 1), so padded/unwritten cache rows round-trip bit-exactly.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    s = jnp.where(amax > 0, amax / Q_MAX, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -Q_MAX, Q_MAX)
    return q.astype(jnp.int8), jnp.squeeze(s, axis=axis)


def dequantize_rows(q, scale, axis: int = -1):
    """Inverse of ``quantize_rows``: broadcast ``scale`` back over
    ``axis`` (f32 result)."""
    return q.astype(jnp.float32) * jnp.expand_dims(scale, axis)


def quantize_weight(w, n_in: int):
    """Per-out-channel symmetric int8: the first ``n_in`` axes of ``w``
    are the contraction axes (reduced for the amax), the rest are output
    channels.  Returns ``{"q8": int8 [*w.shape], "scale": f32
    [*w.shape[n_in:]]}`` — the dict convention every quantized projection
    call site dispatches on.
    """
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=tuple(range(n_in)))
    s = jnp.where(amax > 0, amax / Q_MAX, 1.0)
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / s), -Q_MAX, Q_MAX)
    return {"q8": q.astype(jnp.int8), "scale": s}


def is_quantized(w) -> bool:
    """True for the ``{"q8", "scale"}`` quantized-weight dict."""
    return isinstance(w, dict) and "q8" in w


def dequantize_weight(w):
    """f32 view of a quantized weight dict (scale broadcasts over the
    trailing output-channel axes)."""
    return w["q8"].astype(jnp.float32) * w["scale"]


# ---------------------------------------------------------------------------
# fused dequant-matmul Pallas kernel
# ---------------------------------------------------------------------------

def _dq_matmul_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref):
    kb = pl.program_id(2)
    nkb = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                   # [bm, bk]
    w = w_ref[...].astype(jnp.float32)                   # [bk, bn] (int8 in)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kb == nkb - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] * s_ref[0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def dequant_matmul_kernel(x, w_q, w_scale, *, block_m: int = 128,
                          block_n: int = 128, block_k: int = 128,
                          interpret: bool = True):
    """x [M, K] f32 @ int8 w_q [K, N] with per-out-channel f32 scales [N]
    -> [M, N] f32.  The weight stays int8 on the bus; the scale applies
    once per output tile after the fp32 accumulation (same association as
    ``ref.dequant_matmul_ref``)."""
    m0, kdim0 = x.shape
    _, n0 = w_q.shape
    bm, bn, bk = (min(block_m, m0), min(block_n, n0), min(block_k, kdim0))
    mp, np_, kp = ((-m0) % bm, (-n0) % bn, (-kdim0) % bk)
    if mp or kp:
        x = jnp.pad(x, ((0, mp), (0, kp)))
    if kp or np_:
        w_q = jnp.pad(w_q, ((0, kp), (0, np_)))
    if np_:
        w_scale = jnp.pad(w_scale, ((0, np_),))
    m, n, kdim = m0 + mp, n0 + np_, kdim0 + kp

    out = pl.pallas_call(
        _dq_matmul_kernel,
        grid=(m // bm, n // bn, kdim // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
            pl.BlockSpec((bk, bn), lambda i, j, kb: (kb, j)),
            pl.BlockSpec((1, bn), lambda i, j, kb: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x.astype(jnp.float32), w_q, w_scale.reshape(1, -1))
    return out[:m0, :n0]
