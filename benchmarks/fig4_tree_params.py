"""Fig. 4 — tree-parameter sweep: acceptance and tokens/timestep as a
function of max layer width w and max children per node c."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core.pipedec import PipeDecConfig, PipeDecEngine


def _sweep(target, draft, prompts, widths, branches, n_stages, new_tokens,
           tag, rows, verbose):
    for c in branches:
        for w in widths:
            t0 = time.perf_counter()
            accs, tps = [], []
            for p in prompts:
                eng = PipeDecEngine(
                    target, draft,
                    PipeDecConfig(n_stages=n_stages, width=w, branch=c),
                    max_len=256)
                _, st = eng.generate(p, new_tokens)
                accs.append(st.acceptance)
                tps.append(st.tokens_per_timestep)
            dt = (time.perf_counter() - t0) * 1e6 / len(prompts)
            acc, t = float(np.mean(accs)), float(np.mean(tps))
            rows.append((f"fig4{tag}_w{w}_c{c}", dt,
                         f"acc={acc:.3f};tps={t:.3f}"))
            if verbose:
                print(f"  {tag or 'strong'} w={w:3d} c={c}: "
                      f"acceptance={acc:.3f} tokens/timestep={t:.3f}")


def run(verbose: bool = True, widths=(2, 4, 8, 16), branches=(2, 4),
        n_stages: int = 6, new_tokens: int = 32):
    prompts = common.eval_prompts(n=2, length=32)
    rows = []
    if verbose:
        print("# Fig4: acceptance / tokens-per-timestep vs (w, c)")
    target, draft = common.trained_pair()
    _sweep(target, draft, prompts, widths, branches, n_stages, new_tokens,
           "", rows, verbose)
    # weak-pair ablation: an under-trained draft reproduces the paper's
    # rising-accuracy trend (the strong pair saturates at acceptance ≈ 1)
    wt, wd = common.trained_pair(steps=40)
    _sweep(wt, wd, prompts, widths, branches, n_stages, new_tokens,
           "_weak", rows, verbose)
    return rows


if __name__ == "__main__":
    run()
