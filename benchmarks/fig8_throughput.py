"""Fig. 8 — throughput vs concurrency: PipeDec serialises tasks (latency
priority) while PP/STPP overlap batches; modelled with the same roofline
stage times as Fig. 5, acceptance from real runs."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from benchmarks.fig5_latency import hardware, measure_acceptance
from repro.core import sim


def run(verbose: bool = True, n_stages: int = 14, w: int = 16):
    t0 = time.perf_counter()
    tps, acc, stpp_acc = measure_acceptance(n_stages, w=w)
    hw = hardware(n_stages, w)
    rows = []
    if verbose:
        print("# Fig8: throughput (tokens/s, modelled) vs concurrency")
    for batch in (1, 2, 4, 8):
        thr_pp = sim.pp_throughput(hw, batch)
        thr_pd = sim.pipedec_throughput(hw, batch, tps)
        thr_st = sim.stpp_throughput(hw, batch, depth=4,
                                     mean_accepted=stpp_acc)
        rows.append((f"fig8_batch{batch}",
                     (time.perf_counter() - t0) * 1e6,
                     f"pp={thr_pp:.1f};stpp={thr_st:.1f};"
                     f"pipedec={thr_pd:.1f}"))
        if verbose:
            print(f"  batch={batch}: PP {thr_pp:8.1f}  STPP {thr_st:8.1f}  "
                  f"PipeDec {thr_pd:8.1f} tok/s")
    return rows


if __name__ == "__main__":
    run()
