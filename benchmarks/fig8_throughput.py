"""Fig. 8 — throughput vs concurrency: PipeDec serialises tasks (latency
priority), PP/STPP overlap batches, and SpecPipe-DB keeps several requests'
trees in every pipeline timestep (dynamic batching — the paper's
multi-request mode, 1.64–2.08× vLLM); modelled with the same roofline stage
times as Fig. 5, acceptance from real runs.

``db_batch_scale`` prices the batch-stacked verify pass — since the fused
dispatch landed (``ModelBundle.tree_verify_rows``: ONE batched tree-verify
per model per timestep over the slot-stacked KV arena) this is the pass
``serving.dynbatch.SpecPipeDBEngine`` actually executes, not just the
priced regime.  The ``specpipe_db_sharded`` curve prices the same schedule
on the pipelined deployment (``serving.executor``: per-hop ppermute
transfer explicit) in its steady-state overlapped regime —
``flush=False``, ONE ring tick / stage-hop per timestep, which
``OverlappedShardedExecutor`` now executes — and ``_flush`` the
synchronous-flush variant (``ShardedPipelineExecutor``: ``n_stages`` hops
per timestep inside one dispatch; the bit-exact reference schedule).

Besides printing, ``run()`` writes a machine-readable ``BENCH_fig8.json``
(modelled curves + small *measured* SpecPipe-DB engine runs — local
fused, sharded flush, and sharded overlapped with per-timestep
dispatch/hop counts showing 1 tick per timestep) so the perf trajectory
is tracked across PRs.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common
from benchmarks.fig5_latency import hardware, measure_acceptance
from repro import configs as reg
from repro.core import sim


def db_batch_scale(w: int):
    """Stage-time inflation from stacking ``batch`` requests' width-w tree
    layers in one verify pass — from the same roofline as the stage times
    (memory-bound verify ⇒ strongly sub-linear)."""
    tgt = reg.get_config("pipedec-target")
    base = common.layer_decode_time(tgt, width=w, batch=1)
    return lambda batch: common.layer_decode_time(tgt, width=w,
                                                  batch=batch) / base


def measure_db_engine(n_stages: int, w: int, c: int = 4, *,
                      slots: int = 3, new_tokens: int = 24):
    """Small REAL SpecPipe-DB run (local fused executor): measured
    tokens/timestep, per-request timesteps-per-token (TBT in timestep
    units), and the executor dispatch counters the fusion tests pin."""
    from repro.core.pipedec import PipeDecConfig
    from repro.serving import Request, SpecPipeDBEngine

    target, draft = common.trained_pair()
    prompts = common.eval_prompts(n=4, length=32)
    eng = SpecPipeDBEngine(
        target, draft, PipeDecConfig(n_stages=n_stages, width=w, branch=c),
        max_len=256, max_slots=slots)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, new_tokens, arrival_t=2 * uid))
    res = eng.run()
    tbt = [1.0 / max(s.tokens_per_timestep, 1e-9)
           for s in (r.stats for r in res.values())]
    return {
        "slots": slots,
        "requests": len(prompts),
        "new_tokens": new_tokens,
        "tokens_per_timestep": round(eng.stats.tokens_per_timestep, 4),
        "timesteps": eng.stats.timesteps,
        "peak_occupancy": eng.stats.peak_occupancy,
        "timesteps_per_token_mean": round(float(np.mean(tbt)), 4),
        "dispatch_counts": dict(eng.executor.calls),
        "verify_dispatches_total": sum(eng.stats.verify_dispatches),
    }


def measure_sharded_engines(w: int, c: int = 4, *, slots: int = 3,
                            new_tokens: int = 16):
    """Small REAL runs of the sharded executor schedules on the host
    mesh (one pipeline stage per device; CI's sharded-mesh job runs this
    under a forced 8-device count).  The per-timestep dispatch counts are
    what separates the two pricing regimes: the flush schedule spans
    ``n_stages`` ring hops per timestep inside its one dispatch
    (``flush=True``), the overlapped schedule exactly ONE
    (``flush=False`` — the paper's steady-state wall-clock).

    The overlapped schedule is measured TWICE — gated ctrl (default) and
    ungated (``gate_ctrl=False``, every tick pays the commit-scatter +
    prune-gather) — recording the measured ctrl-active rate
    (``ctrl_active_ticks / pipeline_tick``) and the mean wall-clock cost
    per tick of each, i.e. the per-tick price of gating the in-ring ctrl
    (the ``ctrl_rate``/``t_ctrl`` terms of
    ``sim.specpipe_db_sharded_timestep``).  Admission prefill rides the
    tick on every overlapped run (``prefill_in_ring`` dispatches; zero
    separate ``prefill`` calls) — the CI ``bench-smoke`` job gates on
    these schedule metrics."""
    import jax

    from repro.core.pipedec import PipeDecConfig
    from repro.serving import (AsyncPipelineExecutor,
                               OverlappedShardedExecutor, Request,
                               ShardedPipelineExecutor, SpecPipeDBEngine)

    n_stages = len(jax.devices())
    target, draft = common.trained_pair()
    prompts = common.eval_prompts(n=4, length=32)
    # the overlapped ring length is pcfg.n_stages, so the measured pair
    # shares one pcfg sized to the mesh (outputs must also bit-match)
    pcfg = PipeDecConfig(n_stages=n_stages, width=w, branch=c)
    out = {"mesh_stages": n_stages, "slots": slots,
           "requests": len(prompts), "new_tokens": new_tokens}
    results = {}
    variants = (
        ("flush", ShardedPipelineExecutor, {}),
        ("overlapped", OverlappedShardedExecutor, {}),
        ("overlapped_ungated", OverlappedShardedExecutor,
         {"gate_ctrl": False}),
        # paged arenas + chunked prefill: the 32-token prompts exceed the
        # 16-token prefill lane, so every admission streams through the
        # ring in 2 chunks — still ONE tick per timestep, zero separate
        # prefill dispatches, outputs bit-identical to the dense runs
        ("overlapped_paged", OverlappedShardedExecutor,
         {"paged": True, "page": 16, "prefill_cap": 16}),
        # the async free-running schedule: per-stage actor threads + a
        # disaggregated draft actor — no host lockstep at all, measured
        # by the same workload and pinned bit-identical to the flush
        ("async", AsyncPipelineExecutor, {}),
    )
    for name, cls, kw in variants:
        ex = cls(target, draft, slots=slots, max_len=256,
                 tree_capacity=pcfg.tree_buffer_capacity,
                 capacity=pcfg.capacity, n_stages=n_stages, **kw)
        eng = SpecPipeDBEngine(target, draft, pcfg, max_len=256,
                               max_slots=slots, executor=ex)
        if name.startswith("overlapped") or name == "async":
            # warm-up run so the timed pass prices the steady-state tick,
            # not its one-off jit compile
            for uid, p in enumerate(prompts):
                eng.submit(Request(uid, p, new_tokens, arrival_t=2 * uid))
            eng.run()
            ex.calls.clear()
        prefill_before = target.calls["prefill"] + draft.calls["prefill"]
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid, p, new_tokens, arrival_t=2 * uid))
        t0 = time.perf_counter()
        results[name] = eng.run()
        run_s = time.perf_counter() - t0
        steps = max(eng.stats.timesteps, 1)
        if name.startswith("overlapped") or name == "async":
            ticks = ex.calls["pipeline_tick"]
            hops = ticks                       # one stage-hop per tick
        else:
            ticks = ex.calls["pipeline_verify"]
            hops = ticks * n_stages            # each flush spans all stages
        out[name] = {
            "timesteps": eng.stats.timesteps,
            "tokens_per_timestep": round(eng.stats.tokens_per_timestep, 4),
            "dispatch_counts": dict(ex.calls),
            "ticks_per_timestep": round(ticks / steps, 4),
            "hops_per_timestep": round(hops / steps, 4),
        }
        if name.startswith("overlapped"):
            out[name]["ctrl_active_rate"] = round(
                ex.calls["ctrl_active_ticks"] / max(ticks, 1), 4)
            out[name]["tick_cost_s"] = round(run_s / max(ticks, 1), 6)
            out[name]["separate_prefill_dispatches"] = (
                target.calls["prefill"] + draft.calls["prefill"]
                - prefill_before)
        elif name == "async":
            ctr = ex.counters()
            out[name]["timestep_cost_s"] = round(run_s / steps, 6)
            # entry messages < timesteps: empty timesteps push NOTHING
            # (the async pipe has no dead ticks); per-stage layer steps
            # account for every entry at every stage
            out[name]["entry_msgs"] = ex.calls["entry_msgs"]
            out[name]["ctrl_msgs"] = ex.calls["ctrl_msgs"]
            out[name]["stage_steps"] = ex.calls["stage_steps"]
            out[name]["max_draft_lead"] = ctr["max_draft_lead"]
            out[name]["max_inbox_depth"] = max(
                s["max_depth"] for s in ctr["stages"])
            out[name]["stage_busy_s"] = [round(s["busy_s"], 4)
                                         for s in ctr["stages"]]
            out[name]["stage_idle_s"] = [round(s["idle_s"], 4)
                                         for s in ctr["stages"]]
            ex.shutdown()
    assert all(
        np.array_equal(results["flush"][u].tokens, results[v][u].tokens)
        for u in results["flush"]
        for v in ("overlapped", "overlapped_ungated", "overlapped_paged",
                  "async")), \
        "schedules must agree token-for-token"
    assert out["async"]["stage_steps"] == \
        out["async"]["entry_msgs"] * n_stages, \
        "every entry message must step every stage exactly once"
    assert out["overlapped"]["separate_prefill_dispatches"] == 0, \
        "overlapped admissions must prefill in-ring"
    assert out["overlapped_paged"]["separate_prefill_dispatches"] == 0, \
        "chunked prefill must keep long prompts in-ring"
    assert out["overlapped_paged"]["dispatch_counts"]["prefill_chunks"] \
        > len(prompts), "32-token prompts must chunk past the 16-token lane"
    assert out["overlapped_ungated"]["ctrl_active_rate"] == 1.0
    out["bit_identical"] = True
    return out


def measure_paged_capacity(*, page: int = 16, max_len: int = 256,
                           tree_capacity: int = 64, dense_slots: int = 3,
                           prompt_len: int = 32, new_tokens: int = 24):
    """Paged-vs-dense KV capacity at a FIXED HBM budget (the tentpole
    claim of the paged arena): a dense slot pins ``max_len`` model rows
    plus the full tree capacity no matter how short the request, while
    the paged allocator backs only the request's *horizon*
    (prompt + budget + tree slack) in ``page``-row blocks.  The budget is
    ``dense_slots`` dense slots' worth of bytes; the paged count is
    measured by ACTUALLY admitting requests through the real
    ``PagedKVArena`` fit-check until its pools run dry.  CI bench-smoke
    gates the slots ratio at >= 1.5x and the bytes-per-active-token
    ratio below 1."""
    import jax

    from repro.models import transformer as tf
    from repro.serving import KVArena, PagedKVArena, Request

    target, draft = common.trained_pair()
    dense_bps = KVArena(target, draft, slots=1, max_len=max_len,
                        tree_capacity=tree_capacity).bytes_per_slot()
    budget = dense_slots * dense_bps

    def row_bytes(fn, rows):
        """Bytes per length-row of one cache's paged (KV) leaves."""
        shapes = jax.eval_shape(lambda: fn(1, rows))
        leaves = jax.tree_util.tree_flatten_with_path(
            shapes, is_leaf=lambda x: x is None)[0]
        return sum(leaf.size * leaf.dtype.itemsize // rows
                   for path, leaf in leaves
                   if leaf is not None and getattr(path[-1], "key", None)
                   in tf.CACHE_LEN_AXIS_FROM_END)

    model_row_b = row_bytes(target.init_cache, max_len) \
        + row_bytes(draft.init_cache, max_len)
    tree_row_b = row_bytes(target.init_tree_caches, tree_capacity) \
        + row_bytes(draft.init_tree_caches, tree_capacity)

    horizon = min(max_len, prompt_len + new_tokens + tree_capacity)
    bm = -(-horizon // page)
    bt = -(-tree_capacity // page)
    req_bytes = (bm * model_row_b + bt * tree_row_b) * page
    # split the byte budget across the two pools in per-request proportion
    model_share = bm * model_row_b / (bm * model_row_b + bt * tree_row_b)
    model_blocks = int(budget * model_share // (model_row_b * page))
    tree_blocks = int(budget * (1 - model_share) // (tree_row_b * page))

    arena = PagedKVArena(target, draft, slots=8 * dense_slots,
                         max_len=max_len, tree_capacity=tree_capacity,
                         page=page, model_blocks=model_blocks,
                         tree_blocks=tree_blocks)
    req = Request(0, np.zeros(prompt_len, np.int32), new_tokens)
    paged_slots = 0
    while arena.fits(req):
        arena.bind(arena.alloc(), req)
        paged_slots += 1
    return {
        "page": page, "max_len": max_len, "tree_capacity": tree_capacity,
        "prompt_len": prompt_len, "new_tokens": new_tokens,
        "horizon_rows": horizon,
        "budget_bytes": budget,
        "dense_slots": dense_slots,
        "dense_bytes_per_slot": dense_bps,
        "paged_bytes_per_request": req_bytes,
        "paged_slots": paged_slots,
        "slots_ratio": round(paged_slots / dense_slots, 4),
        # bytes the arena pins per token the request can actually use
        "dense_bytes_per_active_token": round(dense_bps / horizon, 1),
        "paged_bytes_per_active_token": round(req_bytes / horizon, 1),
        "bytes_per_active_token_ratio": round(req_bytes / dense_bps, 4),
        "page_counters": arena.pages.counters(),
    }


def measure_arena_bytes(*, max_len: int = 256, tree_capacity: int = 64):
    """fp32 vs int8 KV-arena bytes per slot (``KVArena.bytes_per_slot``
    is ``jax.eval_shape`` over the init closures — no allocation): the
    quantized serving path's capacity story, gated by CI bench-smoke at
    ratio ≤ 0.55 (≥1.9x slots at an equal byte budget)."""
    from repro.serving import KVArena
    target, draft = common.trained_pair()
    q_target, q_draft = target.quantize(), draft.quantize()

    def bps(t, d):
        return KVArena(t, d, slots=1, max_len=max_len,
                       tree_capacity=tree_capacity).bytes_per_slot()

    fp32_b, int8_b = bps(target, draft), bps(q_target, q_draft)
    return {"max_len": max_len, "tree_capacity": tree_capacity,
            "fp32": fp32_b, "int8": int8_b,
            "ratio": round(int8_b / fp32_b, 4),
            "slots_multiplier": fp32_b // int8_b}


def run(verbose: bool = True, n_stages: int = 14, w: int = 16,
        out_json: str = "BENCH_fig8.json", quick: bool = False):
    """``quick=True`` is the CI bench-smoke mode: it shrinks the
    acceptance sweep and the local-engine run but keeps the SHARDED
    measured workload identical, so the schedule metrics the smoke gate
    diffs — ticks/hops per timestep, ctrl-active rate, in-ring prefill
    counts — are deterministic and comparable against the committed
    full-mode ``BENCH_fig8.json``."""
    t0 = time.perf_counter()
    acc_tokens = 24 if quick else 48
    tps, acc, stpp_acc = measure_acceptance(n_stages, w=w,
                                            new_tokens=acc_tokens)
    hw = hardware(n_stages, w)
    scale = db_batch_scale(w)
    rows = []

    measured = measure_db_engine(n_stages, w,
                                 new_tokens=12 if quick else 24)
    if verbose:
        print(f"  measured DB engine: "
              f"{measured['tokens_per_timestep']:.2f} tokens/timestep, "
              f"{measured['verify_dispatches_total']} fused dispatches in "
              f"{measured['timesteps']} timesteps")
    arena = measure_arena_bytes()
    if verbose:
        print(f"  arena bytes/slot: int8 {arena['int8']} vs fp32 "
              f"{arena['fp32']} ({arena['ratio']:.3f}x -> "
              f"{arena['slots_multiplier']}x slots)")
    paged_cap = measure_paged_capacity()
    if verbose:
        print(f"  paged capacity: {paged_cap['paged_slots']} slots vs "
              f"{paged_cap['dense_slots']} dense at the same byte budget "
              f"({paged_cap['slots_ratio']:.2f}x); "
              f"{paged_cap['paged_bytes_per_active_token']:.0f} vs "
              f"{paged_cap['dense_bytes_per_active_token']:.0f} "
              f"bytes/active token")
    sharded = measure_sharded_engines(w)
    over, ung = sharded["overlapped"], sharded["overlapped_ungated"]
    if verbose:
        print(f"  measured sharded ({sharded['mesh_stages']} stage(s)): "
              f"flush {sharded['flush']['hops_per_timestep']:.2f} vs "
              f"overlapped {over['hops_per_timestep']:.2f} "
              f"ring hops/timestep "
              f"({over['ticks_per_timestep']:.2f} ticks/timestep); "
              f"outputs bit-identical")
        print(f"  gated ctrl: active on {over['ctrl_active_rate']:.0%} of "
              f"ticks, {over['tick_cost_s']*1e3:.2f} ms/tick vs "
              f"{ung['tick_cost_s']*1e3:.2f} ms/tick ungated; "
              f"{over['dispatch_counts'].get('prefill_in_ring', 0)} "
              f"prefills rode the ring "
              f"({over['separate_prefill_dispatches']} separate)")
        pg = sharded["overlapped_paged"]
        print(f"  paged overlapped: "
              f"{pg['ticks_per_timestep']:.2f} ticks/timestep with "
              f"{pg['dispatch_counts'].get('prefill_chunks', 0)} prefill "
              f"chunks over "
              f"{pg['dispatch_counts'].get('prefill_in_ring', 0)} "
              f"admissions (chunked prefill), outputs bit-identical")
        asy = sharded["async"]
        print(f"  async free-running: {asy['entry_msgs']} entry msgs over "
              f"{asy['timesteps']} timesteps "
              f"({asy['timestep_cost_s']*1e3:.2f} ms/timestep vs "
              f"{over['tick_cost_s']*1e3:.2f} lockstep), draft lead up to "
              f"{asy['max_draft_lead']}, max inbox depth "
              f"{asy['max_inbox_depth']}, outputs bit-identical")

    # modelled curves.  The sim's ctrl term is priced with the MEASURED
    # active rate; t_ctrl is modelled as one stage's tree-buffer pass
    # (the commit-scatter + prune-gather touches the same rows a width-w
    # layer writes), NOT extracted from the gated-vs-ungated wall-clock
    # delta — that delta is (1 - rate) * t_ctrl of a single tick and
    # drowns in run-to-run noise on these tiny models (the raw measured
    # tick costs stay in measured_engine_sharded, unmodelled).
    ctrl_rate = over["ctrl_active_rate"]
    t_ctrl = hw.t_stage_width
    curves = []
    if verbose:
        print("# Fig8: throughput (tokens/s, modelled) vs concurrency")
    for batch in (1, 2, 4, 8):
        thr_pp = sim.pp_throughput(hw, batch)
        thr_pd = sim.pipedec_throughput(hw, batch, tps)
        thr_st = sim.stpp_throughput(hw, batch, depth=4,
                                     mean_accepted=stpp_acc)
        thr_db = sim.specpipe_db_throughput(hw, batch, tps,
                                            batch_scale=scale)
        tbt_db = sim.specpipe_db_tbt(hw, batch, tps, batch_scale=scale)
        thr_sh = sim.specpipe_db_sharded_throughput(hw, batch, tps,
                                                    batch_scale=scale)
        thr_gated = sim.specpipe_db_sharded_throughput(
            hw, batch, tps, batch_scale=scale,
            ctrl_rate=ctrl_rate, t_ctrl=t_ctrl)
        thr_ungated = sim.specpipe_db_sharded_throughput(
            hw, batch, tps, batch_scale=scale, ctrl_rate=1.0,
            t_ctrl=t_ctrl)
        thr_fl = sim.specpipe_db_sharded_throughput(
            hw, batch, tps, batch_scale=scale, flush=True)
        tbt_sh = sim.specpipe_db_sharded_tbt(hw, batch, tps,
                                             batch_scale=scale)
        thr_async = sim.specpipe_db_async_throughput(
            hw, batch, tps, batch_scale=scale,
            ctrl_rate=ctrl_rate, t_ctrl=t_ctrl)
        tbt_async = sim.specpipe_db_async_tbt(
            hw, batch, tps, batch_scale=scale,
            ctrl_rate=ctrl_rate, t_ctrl=t_ctrl)
        curves.append({
            "batch": batch, "pp": thr_pp, "stpp": thr_st,
            "pipedec": thr_pd, "specpipe_db": thr_db,
            "specpipe_db_tbt_s": tbt_db,
            "specpipe_db_sharded": thr_sh,
            "specpipe_db_sharded_gated_ctrl": thr_gated,
            "specpipe_db_sharded_ungated_ctrl": thr_ungated,
            "specpipe_db_sharded_flush": thr_fl,
            "specpipe_db_sharded_tbt_s": tbt_sh,
            "specpipe_db_async": thr_async,
            "specpipe_db_async_tbt_s": tbt_async,
        })
        rows.append((f"fig8_batch{batch}",
                     (time.perf_counter() - t0) * 1e6,
                     f"pp={thr_pp:.1f};stpp={thr_st:.1f};"
                     f"pipedec={thr_pd:.1f};specpipe_db={thr_db:.1f};"
                     f"sharded={thr_sh:.1f};sharded_flush={thr_fl:.1f};"
                     f"db_tbt_ms={tbt_db*1e3:.2f}"))
        if verbose:
            print(f"  batch={batch}: PP {thr_pp:8.1f}  STPP {thr_st:8.1f}  "
                  f"PipeDec {thr_pd:8.1f}  SpecPipe-DB {thr_db:8.1f}  "
                  f"sharded {thr_sh:8.1f} (flush {thr_fl:8.1f}) tok/s "
                  f"(TBT {tbt_db*1e3:.2f} ms)")

    payload = {
        "n_stages": n_stages, "width": w, "quick": quick,
        "acceptance": {"pipedec_tokens_per_timestep": tps,
                       "pipedec_acceptance": acc,
                       "stpp_mean_accepted": stpp_acc},
        "modelled_ctrl_terms": {"ctrl_rate_measured": ctrl_rate,
                                "t_ctrl_s_modelled": t_ctrl},
        "modelled_tokens_per_s": curves,
        "measured_engine": measured,
        "measured_engine_sharded": sharded,
        "arena_bytes_per_slot": arena,
        "paged_capacity": paged_cap,
    }
    if out_json:
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=2)
        rows.append(("fig8_json", (time.perf_counter() - t0) * 1e6,
                     os.path.abspath(out_json)))
        if verbose:
            print(f"  wrote {os.path.abspath(out_json)}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI bench-smoke mode: smaller measured runs "
                         "(schedule metrics unchanged)")
    ap.add_argument("--out", default="BENCH_fig8.json")
    args = ap.parse_args()
    run(quick=args.quick, out_json=args.out)
