"""Fig. 8 — throughput vs concurrency: PipeDec serialises tasks (latency
priority), PP/STPP overlap batches, and SpecPipe-DB keeps several requests'
trees in every pipeline timestep (dynamic batching — the paper's
multi-request mode, 1.64–2.08× vLLM); modelled with the same roofline stage
times as Fig. 5, acceptance from real runs.

``db_batch_scale`` prices the batch-stacked verify pass — since the fused
dispatch landed (``ModelBundle.tree_verify_rows``: ONE batched tree-verify
per model per timestep over the slot-stacked KV arena) this is the pass
``serving.dynbatch.SpecPipeDBEngine`` actually executes, not just the
priced regime."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from benchmarks.fig5_latency import hardware, measure_acceptance
from repro import configs as reg
from repro.core import sim


def db_batch_scale(w: int):
    """Stage-time inflation from stacking ``batch`` requests' width-w tree
    layers in one verify pass — from the same roofline as the stage times
    (memory-bound verify ⇒ strongly sub-linear)."""
    tgt = reg.get_config("pipedec-target")
    base = common.layer_decode_time(tgt, width=w, batch=1)
    return lambda batch: common.layer_decode_time(tgt, width=w,
                                                  batch=batch) / base


def run(verbose: bool = True, n_stages: int = 14, w: int = 16):
    t0 = time.perf_counter()
    tps, acc, stpp_acc = measure_acceptance(n_stages, w=w)
    hw = hardware(n_stages, w)
    scale = db_batch_scale(w)
    rows = []
    if verbose:
        print("# Fig8: throughput (tokens/s, modelled) vs concurrency")
    for batch in (1, 2, 4, 8):
        thr_pp = sim.pp_throughput(hw, batch)
        thr_pd = sim.pipedec_throughput(hw, batch, tps)
        thr_st = sim.stpp_throughput(hw, batch, depth=4,
                                     mean_accepted=stpp_acc)
        thr_db = sim.specpipe_db_throughput(hw, batch, tps,
                                            batch_scale=scale)
        tbt_db = sim.specpipe_db_tbt(hw, batch, tps, batch_scale=scale)
        rows.append((f"fig8_batch{batch}",
                     (time.perf_counter() - t0) * 1e6,
                     f"pp={thr_pp:.1f};stpp={thr_st:.1f};"
                     f"pipedec={thr_pd:.1f};specpipe_db={thr_db:.1f};"
                     f"db_tbt_ms={tbt_db*1e3:.2f}"))
        if verbose:
            print(f"  batch={batch}: PP {thr_pp:8.1f}  STPP {thr_st:8.1f}  "
                  f"PipeDec {thr_pd:8.1f}  SpecPipe-DB {thr_db:8.1f} tok/s "
                  f"(TBT {tbt_db*1e3:.2f} ms)")
    return rows


if __name__ == "__main__":
    run()
