"""Fig. 8 — throughput vs concurrency: PipeDec serialises tasks (latency
priority), PP/STPP overlap batches, and SpecPipe-DB keeps several requests'
trees in every pipeline timestep (dynamic batching — the paper's
multi-request mode, 1.64–2.08× vLLM); modelled with the same roofline stage
times as Fig. 5, acceptance from real runs.

``db_batch_scale`` prices the batch-stacked verify pass — since the fused
dispatch landed (``ModelBundle.tree_verify_rows``: ONE batched tree-verify
per model per timestep over the slot-stacked KV arena) this is the pass
``serving.dynbatch.SpecPipeDBEngine`` actually executes, not just the
priced regime.  The ``specpipe_db_sharded`` curve prices the same schedule
on the pipelined deployment (``serving.executor``: per-hop ppermute
transfer explicit) in its steady-state overlapped regime —
``flush=False``, ONE ring tick / stage-hop per timestep, which
``OverlappedShardedExecutor`` now executes — and ``_flush`` the
synchronous-flush variant (``ShardedPipelineExecutor``: ``n_stages`` hops
per timestep inside one dispatch; the bit-exact reference schedule).

Besides printing, ``run()`` writes a machine-readable ``BENCH_fig8.json``
(modelled curves + small *measured* SpecPipe-DB engine runs — local
fused, sharded flush, and sharded overlapped with per-timestep
dispatch/hop counts showing 1 tick per timestep) so the perf trajectory
is tracked across PRs.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common
from benchmarks.fig5_latency import hardware, measure_acceptance
from repro import configs as reg
from repro.core import sim


def db_batch_scale(w: int):
    """Stage-time inflation from stacking ``batch`` requests' width-w tree
    layers in one verify pass — from the same roofline as the stage times
    (memory-bound verify ⇒ strongly sub-linear)."""
    tgt = reg.get_config("pipedec-target")
    base = common.layer_decode_time(tgt, width=w, batch=1)
    return lambda batch: common.layer_decode_time(tgt, width=w,
                                                  batch=batch) / base


def measure_db_engine(n_stages: int, w: int, c: int = 4, *,
                      slots: int = 3, new_tokens: int = 24):
    """Small REAL SpecPipe-DB run (local fused executor): measured
    tokens/timestep, per-request timesteps-per-token (TBT in timestep
    units), and the executor dispatch counters the fusion tests pin."""
    from repro.core.pipedec import PipeDecConfig
    from repro.serving import Request, SpecPipeDBEngine

    target, draft = common.trained_pair()
    prompts = common.eval_prompts(n=4, length=32)
    eng = SpecPipeDBEngine(
        target, draft, PipeDecConfig(n_stages=n_stages, width=w, branch=c),
        max_len=256, max_slots=slots)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, new_tokens, arrival_t=2 * uid))
    res = eng.run()
    tbt = [1.0 / max(s.tokens_per_timestep, 1e-9)
           for s in (r.stats for r in res.values())]
    return {
        "slots": slots,
        "requests": len(prompts),
        "new_tokens": new_tokens,
        "tokens_per_timestep": round(eng.stats.tokens_per_timestep, 4),
        "timesteps": eng.stats.timesteps,
        "peak_occupancy": eng.stats.peak_occupancy,
        "timesteps_per_token_mean": round(float(np.mean(tbt)), 4),
        "dispatch_counts": dict(eng.executor.calls),
        "verify_dispatches_total": sum(eng.stats.verify_dispatches),
    }


def measure_sharded_engines(w: int, c: int = 4, *, slots: int = 3,
                            new_tokens: int = 16):
    """Small REAL runs of BOTH sharded executor schedules on the host
    mesh (one pipeline stage per device; CI's sharded-mesh job runs this
    under a forced 8-device count).  The per-timestep dispatch counts are
    what separates the two pricing regimes: the flush schedule spans
    ``n_stages`` ring hops per timestep inside its one dispatch
    (``flush=True``), the overlapped schedule exactly ONE
    (``flush=False`` — the paper's steady-state wall-clock)."""
    import jax

    from repro.core.pipedec import PipeDecConfig
    from repro.serving import (OverlappedShardedExecutor, Request,
                               ShardedPipelineExecutor, SpecPipeDBEngine)

    n_stages = len(jax.devices())
    target, draft = common.trained_pair()
    prompts = common.eval_prompts(n=4, length=32)
    # the overlapped ring length is pcfg.n_stages, so the measured pair
    # shares one pcfg sized to the mesh (outputs must also bit-match)
    pcfg = PipeDecConfig(n_stages=n_stages, width=w, branch=c)
    out = {"mesh_stages": n_stages, "slots": slots,
           "requests": len(prompts), "new_tokens": new_tokens}
    results = {}
    for name, cls in (("flush", ShardedPipelineExecutor),
                      ("overlapped", OverlappedShardedExecutor)):
        ex = cls(target, draft, slots=slots, max_len=256,
                 tree_capacity=pcfg.tree_buffer_capacity,
                 capacity=pcfg.capacity, n_stages=n_stages)
        eng = SpecPipeDBEngine(target, draft, pcfg, max_len=256,
                               max_slots=slots, executor=ex)
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid, p, new_tokens, arrival_t=2 * uid))
        results[name] = eng.run()
        steps = max(eng.stats.timesteps, 1)
        if name == "overlapped":
            ticks = ex.calls["pipeline_tick"]
            hops = ticks                       # one stage-hop per tick
        else:
            ticks = ex.calls["pipeline_verify"]
            hops = ticks * n_stages            # each flush spans all stages
        out[name] = {
            "timesteps": eng.stats.timesteps,
            "tokens_per_timestep": round(eng.stats.tokens_per_timestep, 4),
            "dispatch_counts": dict(ex.calls),
            "ticks_per_timestep": round(ticks / steps, 4),
            "hops_per_timestep": round(hops / steps, 4),
        }
    assert all(
        np.array_equal(results["flush"][u].tokens,
                       results["overlapped"][u].tokens)
        for u in results["flush"]), "schedules must agree token-for-token"
    out["bit_identical"] = True
    return out


def run(verbose: bool = True, n_stages: int = 14, w: int = 16,
        out_json: str = "BENCH_fig8.json"):
    t0 = time.perf_counter()
    tps, acc, stpp_acc = measure_acceptance(n_stages, w=w)
    hw = hardware(n_stages, w)
    scale = db_batch_scale(w)
    rows = []
    curves = []
    if verbose:
        print("# Fig8: throughput (tokens/s, modelled) vs concurrency")
    for batch in (1, 2, 4, 8):
        thr_pp = sim.pp_throughput(hw, batch)
        thr_pd = sim.pipedec_throughput(hw, batch, tps)
        thr_st = sim.stpp_throughput(hw, batch, depth=4,
                                     mean_accepted=stpp_acc)
        thr_db = sim.specpipe_db_throughput(hw, batch, tps,
                                            batch_scale=scale)
        tbt_db = sim.specpipe_db_tbt(hw, batch, tps, batch_scale=scale)
        thr_sh = sim.specpipe_db_sharded_throughput(hw, batch, tps,
                                                    batch_scale=scale)
        thr_fl = sim.specpipe_db_sharded_throughput(
            hw, batch, tps, batch_scale=scale, flush=True)
        tbt_sh = sim.specpipe_db_sharded_tbt(hw, batch, tps,
                                             batch_scale=scale)
        curves.append({
            "batch": batch, "pp": thr_pp, "stpp": thr_st,
            "pipedec": thr_pd, "specpipe_db": thr_db,
            "specpipe_db_tbt_s": tbt_db,
            "specpipe_db_sharded": thr_sh,
            "specpipe_db_sharded_flush": thr_fl,
            "specpipe_db_sharded_tbt_s": tbt_sh,
        })
        rows.append((f"fig8_batch{batch}",
                     (time.perf_counter() - t0) * 1e6,
                     f"pp={thr_pp:.1f};stpp={thr_st:.1f};"
                     f"pipedec={thr_pd:.1f};specpipe_db={thr_db:.1f};"
                     f"sharded={thr_sh:.1f};sharded_flush={thr_fl:.1f};"
                     f"db_tbt_ms={tbt_db*1e3:.2f}"))
        if verbose:
            print(f"  batch={batch}: PP {thr_pp:8.1f}  STPP {thr_st:8.1f}  "
                  f"PipeDec {thr_pd:8.1f}  SpecPipe-DB {thr_db:8.1f}  "
                  f"sharded {thr_sh:8.1f} (flush {thr_fl:8.1f}) tok/s "
                  f"(TBT {tbt_db*1e3:.2f} ms)")

    measured = measure_db_engine(n_stages, w)
    if verbose:
        print(f"  measured DB engine: "
              f"{measured['tokens_per_timestep']:.2f} tokens/timestep, "
              f"{measured['verify_dispatches_total']} fused dispatches in "
              f"{measured['timesteps']} timesteps")
    sharded = measure_sharded_engines(w)
    if verbose:
        print(f"  measured sharded ({sharded['mesh_stages']} stage(s)): "
              f"flush {sharded['flush']['hops_per_timestep']:.2f} vs "
              f"overlapped {sharded['overlapped']['hops_per_timestep']:.2f} "
              f"ring hops/timestep "
              f"({sharded['overlapped']['ticks_per_timestep']:.2f} "
              f"ticks/timestep); outputs bit-identical")
    payload = {
        "n_stages": n_stages, "width": w,
        "acceptance": {"pipedec_tokens_per_timestep": tps,
                       "pipedec_acceptance": acc,
                       "stpp_mean_accepted": stpp_acc},
        "modelled_tokens_per_s": curves,
        "measured_engine": measured,
        "measured_engine_sharded": sharded,
    }
    if out_json:
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=2)
        rows.append(("fig8_json", (time.perf_counter() - t0) * 1e6,
                     os.path.abspath(out_json)))
        if verbose:
            print(f"  wrote {os.path.abspath(out_json)}")
    return rows


if __name__ == "__main__":
    run()
