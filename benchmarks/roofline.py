"""Roofline table from the dry-run results (EXPERIMENTS.md §Roofline).

Reads ``dryrun_results.jsonl`` (produced by ``python -m
repro.launch.dryrun --all --out dryrun_results.jsonl``) and prints the
per-(arch × shape × mesh) three-term roofline with the dominant
bottleneck and MODEL_FLOPS/HLO_FLOPs ratio.
"""
from __future__ import annotations

import json
import os
import time
from collections import OrderedDict

RESULTS = os.path.join(os.path.dirname(__file__), "..",
                       "dryrun_results.jsonl")


def load(path: str = RESULTS):
    rows = OrderedDict()
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            rows[(r["arch"], r["shape"], r["mesh"])] = r  # last write wins
    return rows


def fmt(v: float) -> str:
    return f"{v:.2e}"


def markdown_table(rows) -> str:
    out = ["| arch | shape | mesh | t_compute | t_memory | t_collective |"
           " bottleneck | useful |",
           "|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in rows.items():
        out.append(
            f"| {a} | {s} | {m} | {fmt(r['t_compute_s'])} "
            f"| {fmt(r['t_memory_s'])} | {fmt(r['t_collective_s'])} "
            f"| {r['bottleneck']} | {r['useful_ratio']:.2f} |")
    return "\n".join(out)


def run(verbose: bool = True):
    t0 = time.perf_counter()
    rows = load()
    out = []
    if not rows:
        out.append(("roofline_table", 0.0, "missing:dryrun_results.jsonl"))
        if verbose:
            print("# Roofline: run the dry-run first")
        return out
    if verbose:
        print(f"# Roofline: {len(rows)} (arch × shape × mesh) rows")
        print(markdown_table(rows))
    bottlenecks = {}
    for r in rows.values():
        bottlenecks[r["bottleneck"]] = bottlenecks.get(r["bottleneck"], 0) + 1
    dt = (time.perf_counter() - t0) * 1e6
    out.append(("roofline_table", dt,
                ";".join(f"{k}={v}" for k, v in sorted(bottlenecks.items()))))
    return out


if __name__ == "__main__":
    run()
