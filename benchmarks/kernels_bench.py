"""Kernel micro-benchmarks (CPU interpret mode: correctness-grade timing;
the numbers that matter on hardware come from the dry-run roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters: int = 3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(verbose: bool = True):
    rng = np.random.default_rng(0)
    b, h, kv, n, hd, lmax, t = 1, 8, 2, 32, 128, 2048, 64
    q = jnp.asarray(rng.normal(size=(b, h, n, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(b, kv, lmax, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(b, kv, lmax, hd)), jnp.float32)
    kt = jnp.asarray(rng.normal(size=(b, kv, t, hd)), jnp.float32)
    vt = jnp.asarray(rng.normal(size=(b, kv, t, hd)), jnp.float32)
    mask = jnp.asarray(rng.random((n, t)) > 0.4)

    us_kernel = _time(lambda: ops.tree_attention(q, kp, vp, kt, vt, mask,
                                                 1024))
    us_ref = _time(lambda: ref.tree_attention_ref(q, kp, vp, kt, vt, mask,
                                                  1024))
    dq = q[:, :, :1]
    us_dec = _time(lambda: ops.decode_attention(dq, kp, vp, 1024))
    us_dref = _time(lambda: ref.decode_attention_ref(dq, kp, vp, 1024))
    rows = [
        ("tree_attention_pallas_interp", us_kernel, f"ref_us={us_ref:.0f}"),
        ("decode_attention_pallas_interp", us_dec, f"ref_us={us_dref:.0f}"),
    ]

    # --- int8 quant paths: bytes moved + time vs the fp32 baselines ------
    from repro.kernels.quant import quantize_rows, quantize_weight
    kpq, kps = quantize_rows(kp)
    vpq, vps = quantize_rows(vp)
    ktq, kts = quantize_rows(kt)
    vtq, vts = quantize_rows(vt)

    def nbytes(*xs):
        return sum(x.size * x.dtype.itemsize for x in xs)

    fp32_kv_b = nbytes(kp, vp, kt, vt)
    int8_kv_b = nbytes(kpq, vpq, ktq, vtq, kps, vps, kts, vts)
    us_qtree = _time(lambda: ops.tree_attention(
        q, kpq, vpq, ktq, vtq, mask, 1024, k_scale=kps, v_scale=vps,
        kt_scale=kts, vt_scale=vts))
    us_qdec = _time(lambda: ops.decode_attention(dq, kpq, vpq, 1024,
                                                 k_scale=kps, v_scale=vps))
    rows += [
        ("tree_attention_int8_interp", us_qtree,
         f"kv_bytes={int8_kv_b} (fp32 {fp32_kv_b}, "
         f"{int8_kv_b / fp32_kv_b:.3f}x)"),
        ("decode_attention_int8_interp", us_qdec,
         f"fp32_us={us_dec:.0f}"),
    ]

    m, k, nn = 64, 512, 512
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, nn)), jnp.float32)
    wq = quantize_weight(w, 1)
    w_b, wq_b = nbytes(w), nbytes(wq["q8"], wq["scale"])
    us_mm = _time(lambda: x @ w)
    us_dqk = _time(lambda: ops.dequant_matmul(x, wq["q8"], wq["scale"],
                                              use_kernel=True))
    us_dqr = _time(lambda: ops.dequant_matmul(x, wq["q8"], wq["scale"],
                                              use_kernel=False))
    rows += [
        ("dequant_matmul_pallas_interp", us_dqk,
         f"jnp_oracle_us={us_dqr:.0f} fp32_matmul_us={us_mm:.0f} "
         f"w_bytes={wq_b} (fp32 {w_b}, {wq_b / w_b:.3f}x)"),
    ]
    if verbose:
        print("# Kernels (interpret mode)")
        for name, us, extra in rows:
            print(f"  {name}: {us:.0f}us ({extra})")
    return rows


if __name__ == "__main__":
    run()
