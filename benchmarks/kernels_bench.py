"""Kernel micro-benchmarks (CPU interpret mode: correctness-grade timing;
the numbers that matter on hardware come from the dry-run roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters: int = 3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(verbose: bool = True):
    rng = np.random.default_rng(0)
    b, h, kv, n, hd, lmax, t = 1, 8, 2, 32, 128, 2048, 64
    q = jnp.asarray(rng.normal(size=(b, h, n, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(b, kv, lmax, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(b, kv, lmax, hd)), jnp.float32)
    kt = jnp.asarray(rng.normal(size=(b, kv, t, hd)), jnp.float32)
    vt = jnp.asarray(rng.normal(size=(b, kv, t, hd)), jnp.float32)
    mask = jnp.asarray(rng.random((n, t)) > 0.4)

    us_kernel = _time(lambda: ops.tree_attention(q, kp, vp, kt, vt, mask,
                                                 1024))
    us_ref = _time(lambda: ref.tree_attention_ref(q, kp, vp, kt, vt, mask,
                                                  1024))
    dq = q[:, :, :1]
    us_dec = _time(lambda: ops.decode_attention(dq, kp, vp, 1024))
    us_dref = _time(lambda: ref.decode_attention_ref(dq, kp, vp, 1024))
    rows = [
        ("tree_attention_pallas_interp", us_kernel, f"ref_us={us_ref:.0f}"),
        ("decode_attention_pallas_interp", us_dec, f"ref_us={us_dref:.0f}"),
    ]
    if verbose:
        print("# Kernels (interpret mode)")
        for name, us, extra in rows:
            print(f"  {name}: {us:.0f}us ({extra})")
    return rows


if __name__ == "__main__":
    run()
