"""Fig. 7 — stochastic decoding: PipeDec acceptance/latency under the
paper's sampling parameters (temperature 0.6, top-p 0.9, top-k 80) vs
greedy, averaged over repeats."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common
from repro.core.pipedec import PipeDecConfig, PipeDecEngine
from repro.core.speculative import SamplingParams


def run(verbose: bool = True, n_stages: int = 6, w: int = 16, c: int = 4,
        repeats: int = 3, new_tokens: int = 32):
    target, draft = common.trained_pair()
    prompts = common.eval_prompts(n=2, length=32)
    rows = []
    if verbose:
        print("# Fig7: greedy vs stochastic decoding")
    for name, sp in (("greedy", SamplingParams()),
                     ("stochastic", SamplingParams(temperature=0.6,
                                                   top_p=0.9, top_k=80))):
        t0 = time.perf_counter()
        accs, tps = [], []
        reps = 1 if name == "greedy" else repeats
        for r in range(reps):
            for i, p in enumerate(prompts):
                eng = PipeDecEngine(
                    target, draft,
                    PipeDecConfig(n_stages=n_stages, width=w, branch=c,
                                  sampling=sp), max_len=256)
                _, st = eng.generate(p, new_tokens,
                                     key=jax.random.PRNGKey(100 * r + i))
                accs.append(st.acceptance)
                tps.append(st.tokens_per_timestep)
        dt = (time.perf_counter() - t0) * 1e6 / max(len(accs), 1)
        acc, t = float(np.mean(accs)), float(np.mean(tps))
        rows.append((f"fig7_{name}", dt, f"acc={acc:.3f};tps={t:.3f}"))
        if verbose:
            print(f"  {name:10s}: acceptance={acc:.3f} "
                  f"tokens/timestep={t:.3f}")
    return rows


if __name__ == "__main__":
    run()
