"""Fig. 6 — predictive accuracy across datasets (radar chart analog).

The paper evaluates 6 datasets (HumanEval/DROP/MMLU/WMT14/TriviaQA/GSM8K);
here 6 synthetic corpora with different transition structures play that
role: seed 3 shares the training distribution (in-domain), the others are
increasingly out-of-distribution.  PipeDec's dynamic tree holds acceptance
above STPP's static tree on every "dataset", as in the paper's radar."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core.baselines import STPPConfig, STPPEngine
from repro.core.pipedec import PipeDecConfig, PipeDecEngine
from repro.data import ByteCorpus, DataConfig, synthetic_corpus


def run(verbose: bool = True, n_stages: int = 6, w: int = 16, c: int = 4,
        new_tokens: int = 24):
    target, draft = common.trained_pair()
    rows = []
    if verbose:
        print("# Fig6: acceptance per dataset (PipeDec vs STPP)")
    for seed in (3, 11, 23, 37, 51, 77):
        t0 = time.perf_counter()
        corpus = ByteCorpus(synthetic_corpus(1 << 13, seed=seed),
                            DataConfig(seq_len=24, batch_size=1))
        prompt = corpus.example(0)[0]
        eng = PipeDecEngine(target, draft,
                            PipeDecConfig(n_stages=n_stages, width=w,
                                          branch=c), max_len=256)
        _, pst = eng.generate(prompt, new_tokens)
        stpp = STPPEngine(target, draft,
                          STPPConfig(depth=4, width=w, branch=c),
                          max_len=256)
        _, sst = stpp.generate(prompt, new_tokens)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig6_ds{seed}", dt,
                     f"pipedec_acc={pst.acceptance:.3f};"
                     f"stpp_acc_len={sst.mean_accepted:.2f}"))
        if verbose:
            print(f"  dataset seed={seed:2d}: PipeDec acc="
                  f"{pst.acceptance:.3f}  STPP accepted/round="
                  f"{sst.mean_accepted:.2f}")
    return rows


if __name__ == "__main__":
    run()
