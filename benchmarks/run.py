"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus human-readable detail).
Figures:
  fig3  draft top-k "scale effect"          (paper Fig. 3)
  fig4  tree-parameter sweep                (paper Fig. 4, Fig. 6 acceptance)
  fig5  PP / STPP / PipeDec latency         (paper Fig. 5)
  fig7  stochastic decoding                 (paper Fig. 7)
  fig8  throughput vs concurrency           (paper Fig. 8)
  roofline  dry-run roofline table          (EXPERIMENTS.md §Roofline)
  kernels   Pallas kernel micro-bench
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (fig3_topk, fig4_tree_params, fig5_latency,
                            fig6_accuracy, fig7_stochastic, fig8_throughput,
                            kernels_bench, roofline)
    modules = [fig3_topk, fig4_tree_params, fig5_latency, fig6_accuracy,
               fig7_stochastic, fig8_throughput, roofline, kernels_bench]
    rows = []
    for mod in modules:
        try:
            rows.extend(mod.run(verbose=True))
        except Exception as e:  # keep the harness alive; report the failure
            rows.append((mod.__name__.split(".")[-1], 0.0,
                         f"ERROR:{type(e).__name__}:{e}"))
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if any(str(r[2]).startswith("ERROR") for r in rows):
        sys.exit(1)


if __name__ == "__main__":
    main()
