"""Shared benchmark plumbing: a trained draft/target pair (cached on disk)
and roofline-derived stage-time models for the wall-clock figures."""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.core.speculative import ModelBundle
from repro.data import ByteCorpus, DataConfig, batch_iterator, synthetic_corpus
from repro.launch.analysis import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.models import transformer as tf
from repro.models.config import ModelConfig

CACHE = os.path.join(os.path.dirname(__file__), ".bench_cache")

TARGET_CFG = ModelConfig(name="bench-target", family="dense", num_layers=4,
                         d_model=256, num_heads=8, num_kv_heads=2, d_ff=704,
                         vocab_size=260)
DRAFT_CFG = ModelConfig(name="bench-draft", family="dense", num_layers=2,
                        d_model=128, num_heads=4, num_kv_heads=2, d_ff=352,
                        vocab_size=260, tie_embeddings=True)


def _train(cfg: ModelConfig, steps: int, seed: int):
    from repro.launch.train import train
    # seed=0 for BOTH: identical corpus (the draft/target premise)
    params, losses = train(cfg, steps=steps, batch=8, seq=64, lr=2e-3,
                           seed=0, log_every=0, corpus_bytes=1 << 17)
    return params, losses


def trained_pair(steps: int = 400):
    """Returns (target ModelBundle, draft ModelBundle), cached on disk.

    Both models are trained on the SAME synthetic Markov corpus, so the
    draft genuinely predicts the target (realistic acceptance rates) —
    the paper's LLaMA-1B/70B relationship at laptop scale.
    """
    path = f"{CACHE}_pair_{steps}.npz"
    if os.path.exists(path):
        blob = load_pytree(path)
        tp, dp = blob["target"], blob["draft"]
        tp = jax.tree.map(jnp.asarray, tp)
        dp = jax.tree.map(jnp.asarray, dp)
    else:
        tp, tl = _train(TARGET_CFG, steps, seed=0)
        dp, dl = _train(DRAFT_CFG, steps, seed=1)
        save_pytree(path, {"target": tp, "draft": dp})
    return ModelBundle(tp, TARGET_CFG), ModelBundle(dp, DRAFT_CFG)


def eval_prompts(n: int = 6, length: int = 32, seed: int = 3):
    """Held-out prompts from the same corpus family."""
    text = synthetic_corpus(1 << 14, seed=seed)
    corpus = ByteCorpus(text, DataConfig(seq_len=length, batch_size=1))
    return [corpus.example(i)[0] for i in range(n)]


# --------------------------------------------------------------------------
# roofline-derived hardware model for the paper's deployment (Fig. 5/8)
# --------------------------------------------------------------------------
def layer_decode_time(cfg: ModelConfig, *, width: int, kv_len: int = 2048,
                      batch: int = 1) -> float:
    """Dominant roofline term for ONE decoder layer verifying ``width``
    tokens (decode is memory-bound: params + KV stream from HBM)."""
    d, ff, hd = cfg.d_model, cfg.d_ff, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    p_layer = (h * hd + 2 * kv * hd) * d + h * hd * d + 3 * d * ff
    bytes_layer = 2 * p_layer + 2 * kv_len * kv * hd * 2 * batch
    flops_layer = 2 * p_layer * width * batch
    return max(bytes_layer / HBM_BW, flops_layer / PEAK_FLOPS)


def model_decode_time(cfg: ModelConfig, *, width: int,
                      kv_len: int = 2048) -> float:
    return cfg.num_layers * layer_decode_time(cfg, width=width,
                                              kv_len=kv_len)


def activation_bytes(cfg: ModelConfig, width: int) -> float:
    return width * cfg.d_model * 2.0  # bf16 activations between stages
