"""Fig. 3 — the "scale effect": top-k accuracy of the draft model's
predictions against the target's greedy choice, k ∈ {1..8}."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.models import transformer as tf


def run(verbose: bool = True):
    target, draft = common.trained_pair()
    prompts = common.eval_prompts(n=4, length=48)
    ks = [1, 2, 4, 8]
    hits = {k: 0 for k in ks}
    total = 0
    t0 = time.perf_counter()
    for p in prompts:
        tl, _ = tf.forward(target.params, target.cfg, jnp.asarray(p)[None])
        dl, _ = tf.forward(draft.params, draft.cfg, jnp.asarray(p)[None])
        t_arg = np.asarray(jnp.argmax(tl[0], -1))           # [S]
        d_top = np.asarray(jax.lax.top_k(dl[0], max(ks))[1])  # [S, 8]
        for k in ks:
            hits[k] += int((d_top[:, :k] == t_arg[:, None]).any(-1).sum())
        total += len(t_arg)
    dt = (time.perf_counter() - t0) * 1e6
    rows = []
    accs = {k: hits[k] / total for k in ks}
    if verbose:
        print("# Fig3: draft top-k containment of target argmax")
        for k in ks:
            print(f"  top-{k}: {accs[k]:.3f}")
    for k in ks:
        rows.append((f"fig3_topk_{k}", dt / len(ks), f"acc={accs[k]:.3f}"))
    assert accs[max(ks)] >= accs[min(ks)], "top-k accuracy must be monotone"
    return rows


if __name__ == "__main__":
    run()
