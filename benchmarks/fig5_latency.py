"""Fig. 5 — single-task decode latency: PP vs STPP vs PipeDec at 7/14/21
pipeline stages.

Acceptance statistics come from REAL engine runs on the trained pair;
wall-clock pricing uses the roofline-derived stage times of the paper's
own deployment (LLaMA-3.1-70B target / LLaMA-3.2-1B draft, §4.1) so the
reported speedups are directly comparable to the paper's 4.46–7.79× (PP)
and 2.2–2.69× (STPP).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro import configs as reg
from repro.core import sim
from repro.core.baselines import STPPConfig, STPPEngine
from repro.core.pipedec import PipeDecConfig, PipeDecEngine


def measure_acceptance(n_stages: int, w: int = 16, c: int = 4,
                       new_tokens: int = 48):
    target, draft = common.trained_pair()
    prompts = common.eval_prompts(n=2, length=32)
    tps, acc = [], []
    for p in prompts:
        eng = PipeDecEngine(target, draft,
                            PipeDecConfig(n_stages=n_stages, width=w,
                                          branch=c), max_len=256)
        _, st = eng.generate(p, new_tokens)
        tps.append(st.tokens_per_timestep)
        acc.append(st.acceptance)
    stpp = STPPEngine(target, draft, STPPConfig(depth=4, width=w, branch=c),
                      max_len=256)
    mean_acc = []
    for p in prompts:
        _, ss = stpp.generate(p, new_tokens)
        mean_acc.append(ss.mean_accepted)
    return float(np.mean(tps)), float(np.mean(acc)), float(np.mean(mean_acc))


def hardware(n_stages: int, w: int):
    tgt = reg.get_config("pipedec-target")
    drf = reg.get_config("pipedec-draft")
    lps = tgt.num_layers / n_stages
    return sim.StageHardware(
        n_stages=n_stages,
        t_stage_one=common.layer_decode_time(tgt, width=1) * lps,
        t_stage_width=common.layer_decode_time(tgt, width=w) * lps,
        t_comm=common.activation_bytes(tgt, w) / common.ICI_BW,
        t_draft=common.model_decode_time(drf, width=w),
        t_sync=2e-5)


def run(verbose: bool = True, w: int = 16, c: int = 4):
    rows = []
    if verbose:
        print("# Fig5: latency/token (modelled) — PP vs STPP vs PipeDec")
    for stages in (7, 14, 21):
        t0 = time.perf_counter()
        tps, acc, stpp_acc = measure_acceptance(stages, w=w, c=c)
        hw = hardware(stages, w)
        lat_pp = sim.pp_latency_per_token(hw)
        lat_pd = sim.pipedec_latency_per_token(hw, tps)
        lat_st = sim.stpp_latency_per_token(hw, depth=4,
                                            mean_accepted=stpp_acc)
        dt = (time.perf_counter() - t0) * 1e6
        sp_pp = lat_pp / lat_pd
        sp_st = lat_st / lat_pd
        rows.append((f"fig5_{stages}stage", dt,
                     f"pp_ms={lat_pp*1e3:.2f};stpp_ms={lat_st*1e3:.2f};"
                     f"pipedec_ms={lat_pd*1e3:.2f};"
                     f"speedup_vs_pp={sp_pp:.2f};speedup_vs_stpp={sp_st:.2f}"))
        if verbose:
            print(f"  {stages:2d} stages: PP {lat_pp*1e3:7.2f} ms/tok  "
                  f"STPP {lat_st*1e3:7.2f}  PipeDec {lat_pd*1e3:7.2f}  "
                  f"({sp_pp:.2f}x vs PP, {sp_st:.2f}x vs STPP; "
                  f"acc={acc:.2f}, tps={tps:.2f})")
    return rows


if __name__ == "__main__":
    run()
