.PHONY: test test-fast bench-fig8 example-serve

# Tier-1 verify: full suite (property tests skip gracefully without
# hypothesis; TPU-lowering tests skip off-TPU — see tests/README.md)
test:
	PYTHONPATH=src python -m pytest -q

# quick signal: skip the slowest end-to-end modules
test-fast:
	PYTHONPATH=src python -m pytest -q --ignore=tests/test_system.py \
		--ignore=tests/test_dryrun.py

bench-fig8:
	PYTHONPATH=src:. python benchmarks/fig8_throughput.py

example-serve:
	PYTHONPATH=src python examples/serve_pipedec.py
