"""shard_map pipeline tick: lowering + numerical equivalence vs the
single-device tree-verify step (1-stage CPU mesh).  The ring and stage
caches are slot-batched (leading B axis) since the executor-layer PR —
B=1 here is the single-request deployment.

Since the overlapped-execution PR the tick is ingest-first: stage 0
adopts AND processes the entry on the same tick, so an entry at tick t
exits at tick ``t + n_stages - 1`` (the engine's ``Flight.exit_t``) and
``make_pipeline_verify`` needs exactly ``n_stages`` ticks — both pinned
here.  The tick also carries the overlapped schedule's pruning-
propagation inputs (per-slot tree ``version`` metadata, a ``kill`` mask,
and the in-ring commit/remap ctrl channel); the ctrl application is
pinned bit-identical to the central ``commit_tree_nodes`` +
``remap_tree_cache_rows`` path the flush executor uses.  Multi-stage
in-flight behaviour (stale layers behind a kill) runs on a REAL 8-device
mesh via ``repro.launch.sharded_check`` (see tests/test_executor_sharded
.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import pipeline as pl
from repro.models import transformer as tf
from repro.models.layers import embed


def _setup(cfg, n_stages=1):
    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    mesh = jax.make_mesh((1, n_stages), ("data", "model"))
    pcfg = pl.PipelineConfig(n_stages=n_stages, width=4, tree_capacity=16,
                             max_len=32)
    sp, valid = pl.stage_params(cfg, params, n_stages)
    return params, mesh, pcfg, sp, valid


def _reference(cfg, params, pcfg):
    """Prefill, then one reference tree-verify of a root layer."""
    cache = tf.init_cache(cfg, 1, 32)
    prompt = jnp.asarray([[5, 3, 2, 7]], jnp.int32)
    logits0, cache = tf.prefill(params, cfg, prompt, cache)
    root = jnp.argmax(logits0, -1)  # [1]

    tcaps = tf.init_tree_caches(cfg, 1, pcfg.tree_capacity + pcfg.width)
    mask = np.zeros((4, pcfg.tree_capacity + pcfg.width), bool)
    mask[0, 0] = True
    tokens = jnp.zeros((1, 4), jnp.int32).at[0, 0].set(root[0])
    positions = jnp.asarray([[4, 0, 0, 0]], jnp.int32)
    ref_logits, _ = tf.tree_verify_step(params, cfg, tokens, positions,
                                        jnp.asarray(mask), cache, 4, tcaps, 0)
    return cache, tokens, positions, mask, ref_logits


def _stage_model_kv(cache):
    """Copy a prefilled (stacked) model cache into 1-stage layout
    ([S=1, B=1, rows, ...] per in-stage layer)."""
    stacked = cache["stack"][0]  # unit has one sublayer: {k,v} [reps,1,...]
    reps = len(jax.tree.leaves(stacked)[0])
    return [jax.tree.map(lambda t: t[l][None], stacked)
            for l in range(reps)]


def _entry(params, tokens, positions, mask, batch=1):
    cat = lambda a: jnp.concatenate([a] * batch, 0)
    return {
        "act": cat(embed(params["embed"], tokens)),
        "positions": cat(positions),
        "mask": cat(jnp.asarray(mask)[None]),
        "write_idx": jnp.zeros((batch,), jnp.int32),
        "model_len": jnp.full((batch,), 4, jnp.int32),
        "valid": jnp.ones((batch,), bool),
        "version": jnp.zeros((batch,), jnp.int32),
    }


def test_tick_matches_tree_verify(tiny_dense):
    """Ingest-first semantics: ONE tick ingests, processes AND exits the
    entry on a 1-stage mesh (entry at t exits at t + n_stages - 1)."""
    cfg = tiny_dense
    params, mesh, pcfg, sp, valid = _setup(cfg)
    _, tree_kv = pl.init_stage_caches(cfg, pcfg)
    ring = pl.init_ring(cfg, pcfg)
    tick = pl.make_pipedec_tick(cfg, pcfg, mesh)

    cache, tokens, positions, mask, ref_logits = _reference(cfg, params,
                                                            pcfg)
    model_kv = _stage_model_kv(cache)
    entry = _entry(params, tokens, positions, mask)
    with mesh:
        _, _, _, exit_out = jax.jit(tick)(sp, valid, model_kv, tree_kv,
                                          ring, entry)

    got = exit_out["act"]  # [1, w, d] final hidden of the exiting layer
    got_logits = tf._logits(params, cfg, got)[0]
    np.testing.assert_allclose(np.asarray(got_logits[0]),
                               np.asarray(ref_logits[0, 0]),
                               rtol=2e-4, atol=2e-4)
    assert bool(exit_out["valid"][0])
    assert int(exit_out["version"][0]) == 0


def test_tick_version_rides_to_exit(tiny_dense):
    """The per-slot tree version frozen at entry is returned at exit —
    the overlapped executor's proof that a resolved future belongs to the
    slot's current tree."""
    cfg = tiny_dense
    params, mesh, pcfg, sp, valid = _setup(cfg)
    _, tree_kv = pl.init_stage_caches(cfg, pcfg)
    ring = pl.init_ring(cfg, pcfg)
    tick = pl.make_pipedec_tick(cfg, pcfg, mesh)
    cache, tokens, positions, mask, _ = _reference(cfg, params, pcfg)
    model_kv = _stage_model_kv(cache)
    entry = dict(_entry(params, tokens, positions, mask),
                 version=jnp.full((1,), 7, jnp.int32))
    with mesh:
        _, _, _, exit_out = jax.jit(tick)(sp, valid, model_kv, tree_kv,
                                          ring, entry)
    assert bool(exit_out["valid"][0])
    assert int(exit_out["version"][0]) == 7


def test_pipeline_verify_flush_matches_tree_verify(tiny_dense):
    """``make_pipeline_verify`` (the sharded flush executor's
    one-dispatch schedule) reproduces the reference tree-verify logits,
    and invalid rows leave the tree caches bit-untouched."""
    cfg = tiny_dense
    params, mesh, pcfg, sp, valid = _setup(cfg)
    _, tree_kv = pl.init_stage_caches(cfg, pcfg, batch=2)
    verify = pl.make_pipeline_verify(cfg, pcfg, mesh)

    cache, tokens, positions, mask, ref_logits = _reference(cfg, params,
                                                            pcfg)
    model_kv1 = _stage_model_kv(cache)
    # batch 2: row 0 live, row 1 invalid (rides along fully masked)
    model_kv = [jax.tree.map(
        lambda t: jnp.concatenate([t, jnp.zeros_like(t)], axis=1), c)
        for c in model_kv1]
    entry = _entry(params, tokens, positions, mask, batch=2)
    entry["valid"] = jnp.asarray([True, False])
    with mesh:
        exit_act, exit_valid, new_tkv = jax.jit(verify)(
            sp, valid, model_kv, tree_kv, entry)

    got_logits = tf._logits(params, cfg, exit_act)
    np.testing.assert_allclose(np.asarray(got_logits[0, 0]),
                               np.asarray(ref_logits[0, 0]),
                               rtol=2e-4, atol=2e-4)
    assert bool(exit_valid[0]) and not bool(exit_valid[1])
    # the invalid row's tree-cache rows are bit-unchanged (zeros)
    for c_new, c_old in zip(new_tkv, tree_kv):
        jax.tree.map(lambda n, o: np.testing.assert_array_equal(
            np.asarray(n[:, 1]), np.asarray(o[:, 1])), c_new, c_old)
    # the live row DID write its layer into the tree cache
    wrote = any(
        bool(jnp.any(n[:, 0] != o[:, 0]))
        for c_new, c_old in zip(new_tkv, tree_kv)
        for n, o in zip(jax.tree.leaves(c_new), jax.tree.leaves(c_old)))
    assert wrote


def test_pipeline_verify_runs_exactly_n_stages_ticks(tiny_dense,
                                                     monkeypatch):
    """The flush dispatch is exactly ``n_stages`` hops — the old trailing
    dead-entry tick (ingest-after-process semantics) is gone."""
    cfg = tiny_dense
    counts = {"ticks": 0}
    real = pl.make_pipedec_tick

    def counting(*args, **kwargs):
        tick = real(*args, **kwargs)

        def wrapped(*a, **k):
            counts["ticks"] += 1
            return tick(*a, **k)

        return wrapped

    monkeypatch.setattr(pl, "make_pipedec_tick", counting)
    params, mesh, pcfg, sp, valid = _setup(cfg)
    _, tree_kv = pl.init_stage_caches(cfg, pcfg)
    verify = pl.make_pipeline_verify(cfg, pcfg, mesh)
    cache, tokens, positions, mask, _ = _reference(cfg, params, pcfg)
    model_kv = _stage_model_kv(cache)
    entry = _entry(params, tokens, positions, mask)
    with mesh:
        _, exit_valid, _ = verify(sp, valid, model_kv, tree_kv, entry)
    assert bool(exit_valid[0]), "the layer must complete within the flush"
    assert counts["ticks"] == pcfg.n_stages


def test_tick_ctrl_matches_central_commit_and_remap(tiny_dense):
    """In-ring pruning propagation == the flush executor's central path:
    a ctrl message (commit mask/length + prune index_map) applied by the
    tick produces bit-identical model/tree caches to
    ``commit_tree_nodes`` + ``remap_tree_cache_rows`` applied directly,
    and an identity ctrl is a bit-exact no-op."""
    cfg = tiny_dense
    params, mesh, pcfg, sp, valid = _setup(cfg)
    _, tree_kv = pl.init_stage_caches(cfg, pcfg)
    tick = pl.make_pipedec_tick(cfg, pcfg, mesh)
    cache, tokens, positions, mask, _ = _reference(cfg, params, pcfg)
    model_kv = _stage_model_kv(cache)
    ring = pl.init_ring(cfg, pcfg, ctrl=True)
    cap = pcfg.tree_capacity
    identity = jnp.arange(cap, dtype=jnp.int32)[None]
    no_ctrl = {"commit": jnp.zeros((1,), bool),
               "commit_len": jnp.zeros((1,), jnp.int32),
               "index_map": identity,
               "clear": jnp.zeros((1,), bool),
               "active": jnp.ones((), bool)}
    kill0 = jnp.zeros((1,), bool)
    entry = _entry(params, tokens, positions, mask)
    dead = dict(entry, valid=jnp.zeros((1,), bool))

    with mesh:
        # tick 1 writes the root layer's KV into tree row 0 (identity
        # ctrl riding along — with the gate OPEN — must be a bit-exact
        # no-op)
        model_kv0 = [jax.tree.map(lambda t: t.copy(), c) for c in model_kv]
        model_kv, tree_kv, ring, _ = jax.jit(tick)(
            sp, valid, model_kv, tree_kv, ring, entry, kill0, no_ctrl)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), model_kv, model_kv0)

        # a prune keeping old row 0 at new row 0 and dropping the rest,
        # plus a commit of row 0 at model_len=4
        imap = jnp.full((cap,), -1, jnp.int32).at[0].set(0)
        ctrl = {"commit": jnp.ones((1,), bool),
                "commit_len": jnp.full((1,), 4, jnp.int32),
                "index_map": imap[None],
                "clear": jnp.zeros((1,), bool),
                "active": jnp.ones((), bool)}
        got_kv, got_tkv, _, _ = jax.jit(tick)(
            sp, valid, model_kv, tree_kv, ring, dead, kill0, ctrl)

    node0 = jnp.zeros((1,), jnp.int32)
    want_kv = [tf.commit_tree_nodes(cfg, mkv, tkv, node0,
                                    jnp.full((1,), 4, jnp.int32),
                                    jnp.ones((1,), bool))
               for mkv, tkv in zip(model_kv, tree_kv)]
    want_tkv = [tf.remap_tree_cache_rows(c, imap[None]) for c in tree_kv]
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), got_kv, want_kv)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), got_tkv, want_tkv)


def test_tick_ctrl_gate_skips_inactive_message(tiny_dense):
    """The gated ctrl channel: with ``active=False`` the stage skips the
    commit-scatter + prune-gather entirely — even a (mis-addressed)
    non-identity message leaves every cache bit-untouched, proving the
    ``lax.cond`` short-circuits rather than applying an identity op."""
    cfg = tiny_dense
    params, mesh, pcfg, sp, valid = _setup(cfg)
    _, tree_kv = pl.init_stage_caches(cfg, pcfg)
    tick = pl.make_pipedec_tick(cfg, pcfg, mesh)
    cache, tokens, positions, mask, _ = _reference(cfg, params, pcfg)
    model_kv = _stage_model_kv(cache)
    ring = pl.init_ring(cfg, pcfg, ctrl=True)
    cap = pcfg.tree_capacity
    kill0 = jnp.zeros((1,), bool)
    entry = _entry(params, tokens, positions, mask)
    dead = dict(entry, valid=jnp.zeros((1,), bool))
    no_ctrl = {"commit": jnp.zeros((1,), bool),
               "commit_len": jnp.zeros((1,), jnp.int32),
               "index_map": jnp.arange(cap, dtype=jnp.int32)[None],
               "clear": jnp.zeros((1,), bool),
               "active": jnp.ones((), bool)}
    with mesh:
        model_kv, tree_kv, ring, _ = jax.jit(tick)(
            sp, valid, model_kv, tree_kv, ring, entry, kill0, no_ctrl)
        # a REAL commit+prune message, but with the gate closed
        imap = jnp.full((cap,), -1, jnp.int32).at[0].set(0)
        gated = {"commit": jnp.ones((1,), bool),
                 "commit_len": jnp.full((1,), 4, jnp.int32),
                 "index_map": imap[None],
                 "clear": jnp.zeros((1,), bool),
                 "active": jnp.zeros((), bool)}
        got_kv, got_tkv, _, _ = jax.jit(tick)(
            sp, valid, model_kv, tree_kv, ring, dead, kill0, gated)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), got_kv, model_kv)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), got_tkv, tree_kv)


def test_tick_prefill_lane_matches_prefill(tiny_dense):
    """Prefill-in-ring: a prompt entering the tick's prefill lane exits
    with last-position logits and stage model-cache rows BIT-IDENTICAL
    to ``tf.prefill``, while off slots stay untouched and the tree exit
    stays dead for the joining slot."""
    cfg = tiny_dense
    params, mesh, pcfg, sp, valid = _setup(cfg)
    pcap = 8
    model_kv, tree_kv = pl.init_stage_caches(cfg, pcfg, batch=2)
    ring = pl.init_ring(cfg, pcfg, batch=2, ctrl=True, prefill_cap=pcap)
    tick = pl.make_pipedec_tick(cfg, pcfg, mesh, prefill_cap=pcap)

    prompt = np.asarray([5, 3, 2, 7, 11], np.int32)
    ptok = np.zeros((2, pcap), np.int32)
    ptok[0, :len(prompt)] = prompt
    pentry = {"act": embed(params["embed"], jnp.asarray(ptok)),
              "len": jnp.asarray([len(prompt), 0], jnp.int32),
              "on": jnp.asarray([True, False]),
              "off": jnp.zeros((2,), jnp.int32)}
    w = pcfg.width
    dead_entry = {
        "act": jnp.zeros((2, w, cfg.d_model)),
        "positions": jnp.zeros((2, w), jnp.int32),
        "mask": jnp.zeros((2, w, pcfg.tree_capacity + w), bool),
        "write_idx": jnp.zeros((2,), jnp.int32),
        "model_len": jnp.zeros((2,), jnp.int32),
        "valid": jnp.zeros((2,), bool),
        "version": jnp.zeros((2,), jnp.int32),
    }
    cap = pcfg.tree_capacity
    no_ctrl = {"commit": jnp.zeros((2,), bool),
               "commit_len": jnp.zeros((2,), jnp.int32),
               "index_map": jnp.broadcast_to(
                   jnp.arange(cap, dtype=jnp.int32), (2, cap)),
               "clear": jnp.zeros((2,), bool),
               "active": jnp.zeros((), bool)}
    kill0 = jnp.zeros((2,), bool)
    with mesh:
        model_kv, tree_kv, ring, ex = jax.jit(tick)(
            sp, valid, model_kv, tree_kv, ring, dead_entry, kill0,
            no_ctrl, pentry)

    assert bool(ex["p_valid"][0]) and not bool(ex["p_valid"][1])
    assert not bool(ex["valid"][0]), "tree exit stays dead while joining"
    got = tf._logits(params, cfg, ex["p_last"][0:1])
    ref_cache = tf.init_cache(cfg, 1, 32)
    ref_logits, ref_cache = tf.prefill(params, cfg,
                                       jnp.asarray(prompt)[None], ref_cache)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref_logits))
    # stage model-cache rows [0, len) of the joining slot == the scan
    # prefill's rows; the off slot's rows are bit-untouched (zeros)
    stacked = ref_cache["stack"][0]
    reps = len(jax.tree.leaves(stacked)[0])
    for l in range(reps):
        want = jax.tree.map(lambda t, l=l: np.asarray(t[l][0, :len(prompt)]),
                            stacked)
        got_rows = jax.tree.map(
            lambda t: np.asarray(t[0, 0, :len(prompt)]), model_kv[l])
        jax.tree.map(np.testing.assert_array_equal, got_rows, want)
        for leaf in jax.tree.leaves(
                jax.tree.map(lambda t: np.asarray(t[0, 1]), model_kv[l])):
            assert not leaf.any(), "off slot must stay untouched"


def test_remap_tree_cache_rows_matches_per_row_reference(tiny_dense):
    """The batched gather (``remap_rows`` seam) equals the per-slot
    ``core.speculative.remap_tree_caches`` loop, identity rows
    included."""
    from repro.core.speculative import remap_tree_caches

    cfg = tiny_dense
    cap, slack, slots = 11, 4, 3
    tkv = jax.tree.map(
        lambda t: jax.random.normal(jax.random.PRNGKey(1), t.shape),
        tf.init_tree_caches(cfg, slots, cap + slack))
    rng = np.random.default_rng(0)
    imaps = np.tile(np.arange(cap, dtype=np.int32), (slots, 1))
    # slot 0: a real prune (drop half the rows, compact the rest)
    keep = np.sort(rng.choice(cap, size=cap // 2, replace=False))
    imaps[0] = -1
    imaps[0][keep] = np.arange(len(keep))
    # slot 1: identity (untouched); slot 2: reversal
    imaps[2] = np.arange(cap, dtype=np.int32)[::-1]

    got = tf.remap_tree_cache_rows(tkv, jnp.asarray(imaps))
    for slot in range(slots):
        want_row = remap_tree_caches(
            tf.slice_cache_rows(tkv, slot, 1), jnp.asarray(imaps[slot]),
            cap)
        got_row = tf.slice_cache_rows(got, slot, 1)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), got_row, want_row)
    # the identity slot is bit-untouched
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a[1]), np.asarray(b[1])),
        tf.slice_cache_rows(got, 1, 1), tf.slice_cache_rows(tkv, 1, 1))
