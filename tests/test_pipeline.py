"""shard_map pipeline tick: lowering + numerical equivalence vs the
single-device tree-verify step (1-stage CPU mesh).  The ring and stage
caches are slot-batched (leading B axis) since the executor-layer PR —
B=1 here is the single-request deployment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import pipeline as pl
from repro.models import transformer as tf
from repro.models.layers import embed


def _setup(cfg, n_stages=1):
    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    mesh = jax.make_mesh((1, n_stages), ("data", "model"))
    pcfg = pl.PipelineConfig(n_stages=n_stages, width=4, tree_capacity=16,
                             max_len=32)
    sp, valid = pl.stage_params(cfg, params, n_stages)
    return params, mesh, pcfg, sp, valid


def _reference(cfg, params, pcfg):
    """Prefill, then one reference tree-verify of a root layer."""
    cache = tf.init_cache(cfg, 1, 32)
    prompt = jnp.asarray([[5, 3, 2, 7]], jnp.int32)
    logits0, cache = tf.prefill(params, cfg, prompt, cache)
    root = jnp.argmax(logits0, -1)  # [1]

    tcaps = tf.init_tree_caches(cfg, 1, pcfg.tree_capacity + pcfg.width)
    mask = np.zeros((4, pcfg.tree_capacity + pcfg.width), bool)
    mask[0, 0] = True
    tokens = jnp.zeros((1, 4), jnp.int32).at[0, 0].set(root[0])
    positions = jnp.asarray([[4, 0, 0, 0]], jnp.int32)
    ref_logits, _ = tf.tree_verify_step(params, cfg, tokens, positions,
                                        jnp.asarray(mask), cache, 4, tcaps, 0)
    return cache, tokens, positions, mask, ref_logits


def _stage_model_kv(cache):
    """Copy a prefilled (stacked) model cache into 1-stage layout
    ([S=1, B=1, rows, ...] per in-stage layer)."""
    stacked = cache["stack"][0]  # unit has one sublayer: {k,v} [reps,1,...]
    reps = len(jax.tree.leaves(stacked)[0])
    return [jax.tree.map(lambda t: t[l][None], stacked)
            for l in range(reps)]


def test_tick_matches_tree_verify(tiny_dense):
    cfg = tiny_dense
    params, mesh, pcfg, sp, valid = _setup(cfg)
    _, tree_kv = pl.init_stage_caches(cfg, pcfg)
    ring = pl.init_ring(cfg, pcfg)
    tick = pl.make_pipedec_tick(cfg, pcfg, mesh)

    cache, tokens, positions, mask, ref_logits = _reference(cfg, params,
                                                            pcfg)
    model_kv = _stage_model_kv(cache)
    x_in = embed(params["embed"], tokens)  # [1, w, d]
    entry = {
        "act": x_in, "positions": positions,
        "mask": jnp.asarray(mask)[None],
        "write_idx": jnp.zeros((1,), jnp.int32),
        "model_len": jnp.full((1,), 4, jnp.int32),
        "valid": jnp.ones((1,), bool),
    }
    with mesh:
        # tick 1: ring empty, entry ingested into stage 0
        tkv1, ring1, exit1 = jax.jit(tick)(sp, valid, model_kv, tree_kv,
                                           ring, entry)
        assert not bool(exit1["valid"][0])
        # tick 2: stage 0 processes the ingested layer; it exits
        entry2 = dict(entry)
        entry2["valid"] = jnp.zeros((1,), bool)
        _, _, exit_out = jax.jit(tick)(sp, valid, model_kv, tkv1, ring1,
                                       entry2)

    got = exit_out["act"]  # [1, w, d] final hidden of the exiting layer
    got_logits = tf._logits(params, cfg, got)[0]
    np.testing.assert_allclose(np.asarray(got_logits[0]),
                               np.asarray(ref_logits[0, 0]),
                               rtol=2e-4, atol=2e-4)
    assert bool(exit_out["valid"][0])


def test_pipeline_verify_flush_matches_tree_verify(tiny_dense):
    """``make_pipeline_verify`` (the sharded executor's one-dispatch
    flush) reproduces the reference tree-verify logits, and invalid rows
    leave the tree caches bit-untouched."""
    cfg = tiny_dense
    params, mesh, pcfg, sp, valid = _setup(cfg)
    _, tree_kv = pl.init_stage_caches(cfg, pcfg, batch=2)
    verify = pl.make_pipeline_verify(cfg, pcfg, mesh)

    cache, tokens, positions, mask, ref_logits = _reference(cfg, params,
                                                            pcfg)
    model_kv1 = _stage_model_kv(cache)
    # batch 2: row 0 live, row 1 invalid (rides along fully masked)
    model_kv = [jax.tree.map(
        lambda t: jnp.concatenate([t, jnp.zeros_like(t)], axis=1), c)
        for c in model_kv1]
    entry = {
        "act": jnp.concatenate([embed(params["embed"], tokens)] * 2, 0),
        "positions": jnp.concatenate([positions] * 2, 0),
        "mask": jnp.concatenate([jnp.asarray(mask)[None]] * 2, 0),
        "write_idx": jnp.zeros((2,), jnp.int32),
        "model_len": jnp.full((2,), 4, jnp.int32),
        "valid": jnp.asarray([True, False]),
    }
    with mesh:
        exit_act, exit_valid, new_tkv = jax.jit(verify)(
            sp, valid, model_kv, tree_kv, entry)

    got_logits = tf._logits(params, cfg, exit_act)
    np.testing.assert_allclose(np.asarray(got_logits[0, 0]),
                               np.asarray(ref_logits[0, 0]),
                               rtol=2e-4, atol=2e-4)
    assert bool(exit_valid[0]) and not bool(exit_valid[1])
    # the invalid row's tree-cache rows are bit-unchanged (zeros)
    for c_new, c_old in zip(new_tkv, tree_kv):
        jax.tree.map(lambda n, o: np.testing.assert_array_equal(
            np.asarray(n[:, 1]), np.asarray(o[:, 1])), c_new, c_old)
    # the live row DID write its layer into the tree cache
    wrote = any(
        bool(jnp.any(n[:, 0] != o[:, 0]))
        for c_new, c_old in zip(new_tkv, tree_kv)
        for n, o in zip(jax.tree.leaves(c_new), jax.tree.leaves(c_old)))
    assert wrote
