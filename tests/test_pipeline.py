"""shard_map pipeline tick: lowering + numerical equivalence vs the
single-device tree-verify step (1-stage CPU mesh)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import pipeline as pl
from repro.models import transformer as tf
from repro.models.layers import embed


def test_tick_matches_tree_verify(tiny_dense):
    cfg = tiny_dense
    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    pcfg = pl.PipelineConfig(n_stages=1, width=4, tree_capacity=16,
                             max_len=32)
    sp, valid = pl.stage_params(cfg, params, 1)
    model_kv, tree_kv = pl.init_stage_caches(cfg, pcfg)
    ring = pl.init_ring(cfg, pcfg)
    tick = pl.make_pipedec_tick(cfg, pcfg, mesh)

    # prefill on the reference path, then present one tree layer
    cache = tf.init_cache(cfg, 1, 32)
    prompt = jnp.asarray([[5, 3, 2, 7]], jnp.int32)
    logits0, cache = tf.prefill(params, cfg, prompt, cache)
    root = jnp.argmax(logits0, -1)  # [1]

    # reference verify
    tcaps = tf.init_tree_caches(cfg, 1, pcfg.tree_capacity + pcfg.width)
    mask = np.zeros((4, pcfg.tree_capacity + pcfg.width), bool)
    mask[0, 0] = True
    tokens = jnp.zeros((1, 4), jnp.int32).at[0, 0].set(root[0])
    positions = jnp.asarray([[4, 0, 0, 0]], jnp.int32)
    ref_logits, _ = tf.tree_verify_step(params, cfg, tokens, positions,
                                        jnp.asarray(mask), cache, 4, tcaps, 0)

    # pipeline tick: copy the prefilled model cache into stage layout
    # (list over in-stage layers of [S=1, B, rows, ...])
    stacked = cache["stack"][0]  # unit has one sublayer: {k,v} [reps,1,...]
    reps = len(jax.tree.leaves(stacked)[0])
    model_kv = [jax.tree.map(lambda t: t[l][None], stacked)
                for l in range(reps)]
    x_in = embed(params["embed"], tokens)[0]  # [w, d]
    entry = {
        "act": x_in, "positions": positions[0],
        "mask": jnp.asarray(mask), "write_idx": jnp.asarray(0, jnp.int32),
        "model_len": jnp.asarray(4, jnp.int32),
        "valid": jnp.asarray(True),
    }
    with mesh:
        # tick 1: ring empty, entry ingested into stage 0
        tkv1, ring1, exit1 = jax.jit(tick)(sp, valid, model_kv, tree_kv,
                                           ring, entry)
        assert not bool(exit1["valid"])
        # tick 2: stage 0 processes the ingested layer; it exits
        entry2 = dict(entry)
        entry2["valid"] = jnp.asarray(False)
        _, _, exit_out = jax.jit(tick)(sp, valid, model_kv, tkv1, ring1,
                                       entry2)

    got = exit_out["act"]  # [w, d] final hidden of the exiting layer
    got_logits = tf._logits(params, cfg, got[None])[0]
    np.testing.assert_allclose(np.asarray(got_logits[0]),
                               np.asarray(ref_logits[0, 0]),
                               rtol=2e-4, atol=2e-4)
    assert bool(exit_out["valid"])
