"""PipeDec across architecture families (MoE / VLM / enc-dec use the full
tree path; SSM / hybrid use chain-mode — DESIGN.md §Arch-applicability)."""
import jax
import numpy as np
import pytest

from repro import configs as reg
from repro.core.baselines import generate_autoregressive
from repro.core.chain import ChainConfig, ChainSpecEngine
from repro.core.pipedec import PipeDecConfig, PipeDecEngine
from repro.core.speculative import ModelBundle
from repro.models import transformer as tf
from repro.models.config import ModelConfig


def _draft_for(vocab: int) -> ModelConfig:
    return ModelConfig(name="fam-draft", family="dense", num_layers=1,
                       d_model=64, num_heads=2, num_kv_heads=1, d_ff=128,
                       vocab_size=vocab)


@pytest.mark.parametrize("arch", ["moonshot_v1_16b_a3b", "qwen2_5_32b",
                                  "internvl2_26b", "deepseek_v2_236b"])
def test_pipedec_tree_lossless_on_family(arch):
    """Tree speculative decoding is exact for MoE / MLA / dense / VLM
    (VLM decodes text-only here; the prefix path is covered by smoke)."""
    cfg = reg.get_config(arch, smoke=True)
    if cfg.moe is not None:
        import dataclasses
        # dropless capacity: batched tree verify vs single-token decode must
        # route identically for exact equality
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(
                cfg.moe.num_experts)))
    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    target = ModelBundle(params, cfg)
    dcfg = _draft_for(cfg.vocab_size)
    draft = ModelBundle(tf.init_model(jax.random.PRNGKey(5), dcfg), dcfg)

    prompt = np.array([7, 3, 11, 2], np.int32)
    ar = generate_autoregressive(target, prompt, 10, max_len=64)
    eng = PipeDecEngine(target, draft,
                        PipeDecConfig(n_stages=3, width=4, branch=2),
                        max_len=64)
    out, stats = eng.generate(prompt, 10)
    assert np.array_equal(ar, out), arch
    assert stats.commits >= 10


@pytest.mark.parametrize("arch", ["mamba2_130m", "recurrentgemma_9b"])
def test_chain_spec_lossless_on_recurrent(arch):
    """Chain-mode speculative decoding (PipeDec w=1 + state checkpointing)
    is exact for attention-free / hybrid-recurrent architectures."""
    cfg = reg.get_config(arch, smoke=True)
    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    target = ModelBundle(params, cfg)
    dcfg = _draft_for(cfg.vocab_size)
    draft = ModelBundle(tf.init_model(jax.random.PRNGKey(5), dcfg), dcfg)

    prompt = np.array([9, 1, 4, 4], np.int32)
    ar = generate_autoregressive(target, prompt, 12, max_len=64)
    eng = ChainSpecEngine(target, draft, ChainConfig(n_stages=3),
                          max_len=64)
    out, stats = eng.generate(prompt, 12)
    assert np.array_equal(ar, out), arch
    assert stats.commits >= 12


def test_chain_spec_self_draft_rate(tiny_ssm):
    """Self-draft chain decoding approaches 1 token/timestep (pipeline full
    of one task — the paper's idea carried to attention-free models)."""
    params = tf.init_model(jax.random.PRNGKey(0), tiny_ssm)
    target = ModelBundle(params, tiny_ssm)
    prompt = np.array([5, 5, 2], np.int32)
    eng = ChainSpecEngine(target, target, ChainConfig(n_stages=4),
                          max_len=64)
    out, stats = eng.generate(prompt, 16)
    assert stats.acceptance == 1.0
    assert stats.tokens_per_timestep > 0.7
