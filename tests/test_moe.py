"""MoE unit + property tests: routing conservation, dropless equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models import moe as M
from repro.models.config import ModelConfig, MoEConfig


def mk_cfg(e=4, k=2, cf=8.0, shared=1):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=32, num_heads=2,
        num_kv_heads=2, d_ff=64, vocab_size=64,
        moe=MoEConfig(num_experts=e, experts_per_token=k, d_ff_expert=16,
                      num_shared_experts=shared, capacity_factor=cf))


def dense_reference(params, cfg, x):
    """All-experts dense evaluation weighted by top-k gates (dropless)."""
    mo = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    idx, gate, _ = M.route(params, cfg, xf)
    h_gate = jax.nn.silu(jnp.einsum("td,edf->tef", xf, params["w_gate"]))
    h_up = jnp.einsum("td,edf->tef", xf, params["w_up"])
    h = jnp.einsum("tef,efd->ted", h_gate * h_up, params["w_down"])
    weights = jnp.zeros((xf.shape[0], mo.num_experts), xf.dtype)
    weights = jnp.take_along_axis(
        weights.at[jnp.arange(xf.shape[0])[:, None], idx].set(gate),
        jnp.arange(mo.num_experts)[None], axis=1)
    y = jnp.einsum("te,ted->td", weights, h)
    if mo.num_shared_experts:
        from repro.models.layers import mlp
        y = y + mlp(params["shared"], xf, "swiglu")
    return y.reshape(b, s, d)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), e=st.sampled_from([2, 4, 8]),
       k=st.integers(1, 2))
def test_sorted_dispatch_matches_dense_reference(seed, e, k):
    cfg = mk_cfg(e=e, k=k, cf=float(e))  # cf = E => dropless
    params = M.init_moe(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 6, 32))
    got, _ = M.moe_forward(params, cfg, x)
    ref = dense_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_router_gates_normalised():
    cfg = mk_cfg()
    params = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 32))
    idx, gate, aux = M.route(params, cfg, x)
    np.testing.assert_allclose(np.asarray(gate.sum(-1)), 1.0, rtol=1e-5)
    assert idx.shape == (8, 2)
    assert float(aux) > 0.0  # load-balance loss well-defined


def test_capacity_drops_overflow():
    cfg = mk_cfg(e=2, k=1, cf=0.01, shared=0)  # capacity ~minimum
    params = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
    y, _ = M.moe_forward(params, cfg, x)
    # some token outputs must be exactly zero (dropped, no shared expert)
    norms = np.asarray(jnp.linalg.norm(y[0], axis=-1))
    assert (norms == 0.0).any()
    assert (norms > 0.0).any()


def test_aux_loss_increases_with_imbalance():
    cfg = mk_cfg(e=4, k=1, shared=0)
    params = M.init_moe(jax.random.PRNGKey(0), cfg)
    xf = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    _, _, aux_random = M.route(params, cfg, xf)
    # force collapse: bias router to expert 0
    biased = dict(params)
    biased["router"] = params["router"].at[:, 0].add(100.0)
    _, _, aux_collapsed = M.route(biased, cfg, xf)
    assert float(aux_collapsed) > float(aux_random)
