"""REPRO_USE_PALLAS_ATTN=1 path: kernel-backed decode / tree-verify must
match the jnp path exactly (the kernels run in interpret mode on CPU).
Plus the dispatch-policy seams: per-call ``interpret=`` overrides resolved
at call time (no reimport), and the ``USE_PALLAS_QUANT`` kernel-vs-oracle
policy for the fused dequant-matmul."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models import attention as A
from repro.models import transformer as tf


def test_kernel_decode_matches_jnp(tiny_dense):
    cfg = tiny_dense
    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128)
    cache = tf.init_cache(cfg, 2, 16)
    logits, cache = tf.prefill(params, cfg, toks, cache)
    tok = jnp.argmax(logits, -1)

    ref, _ = tf.decode_step(params, cfg, tok,
                            jax.tree.map(lambda x: x, cache), 8)
    old = A.USE_PALLAS_ATTN
    try:
        A.USE_PALLAS_ATTN = True
        got, _ = tf.decode_step(params, cfg, tok,
                                jax.tree.map(lambda x: x, cache), 8)
    finally:
        A.USE_PALLAS_ATTN = old
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_kernel_tree_verify_matches_jnp(tiny_dense):
    cfg = tiny_dense
    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0, 128)
    cache = tf.init_cache(cfg, 1, 16)
    logits, cache = tf.prefill(params, cfg, toks, cache)
    root = jnp.argmax(logits, -1)

    tcap = 8
    node_tokens = jnp.zeros((1, 4), jnp.int32).at[0, 0].set(root[0])
    positions = jnp.asarray([[6, 0, 0, 0]], jnp.int32)
    mask = np.zeros((4, tcap), bool)
    mask[0, 0] = True

    def go():
        tcaches = tf.init_tree_caches(cfg, 1, tcap)
        lg, _ = tf.tree_verify_step(params, cfg, node_tokens, positions,
                                    jnp.asarray(mask), cache, 6, tcaches, 0)
        return np.asarray(lg)

    ref = go()
    old = A.USE_PALLAS_ATTN
    try:
        A.USE_PALLAS_ATTN = True
        got = go()
    finally:
        A.USE_PALLAS_ATTN = old
    np.testing.assert_allclose(got[:, 0], ref[:, 0], rtol=2e-4, atol=2e-4)


def test_interpret_resolved_per_call_not_at_import():
    """ops.INTERPRET is only the *default*: reassigning it (or passing
    interpret=) takes effect without reimporting the module — the env var
    must not be frozen into the dispatchers at import time."""
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(1, 2, 1, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 16, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 16, 32)).astype(np.float32))
    want = ref.decode_attention_ref(q, k, v, 12)

    old = ops.INTERPRET
    try:
        # on CPU, interpret=False would fail inside pallas_call — the
        # per-call override must rescue a flipped module default...
        ops.INTERPRET = False
        out = ops.decode_attention(q, k, v, 12, block_k=16, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        # ...and reassigning the module default (no reimport) must be
        # honoured too
        ops.INTERPRET = True
        out = ops.decode_attention(q, k, v, 12, block_k=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
    finally:
        ops.INTERPRET = old


def test_quant_matmul_policy_kernel_vs_oracle():
    """use_kernel=None follows USE_PALLAS_QUANT; both backends agree and
    flipping the module flag needs no reimport."""
    from repro.kernels.quant import quantize_weight
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(3, 5, 24)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(24, 10)).astype(np.float32))
    wq = quantize_weight(w, 1)
    want = ref.dequant_matmul_ref(x.reshape(-1, 24), wq["q8"],
                                  wq["scale"]).reshape(3, 5, 10)

    oracle = ops.quant_matmul(x, wq, use_kernel=False)
    kernel = ops.quant_matmul(x, wq, use_kernel=True)
    np.testing.assert_allclose(np.asarray(oracle), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kernel), np.asarray(want),
                               rtol=1e-5, atol=1e-5)

    old = ops.USE_PALLAS_QUANT
    try:
        ops.USE_PALLAS_QUANT = True
        flagged = ops.quant_matmul(x, wq)      # default follows the flag
        np.testing.assert_allclose(np.asarray(flagged), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
    finally:
        ops.USE_PALLAS_QUANT = old


def test_quant_matmul_higher_rank_contraction():
    """Attention projections contract >1 axis (e.g. w_o [H, hd, D]): the
    dict convention (first q8.ndim - scale.ndim axes contract) must
    reproduce the einsum on the dequantized weight."""
    from repro.kernels.quant import dequantize_weight, quantize_weight
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(2, 3, 4, 8)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(4, 8, 16)).astype(np.float32))
    wq = quantize_weight(w, 2)
    assert wq["scale"].shape == (16,)
    got = ops.quant_matmul(x, wq, use_kernel=False)
    want = jnp.einsum("bshd,hdo->bso", x, dequantize_weight(wq))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("use_pallas_attn", [False, True])
def test_kernel_paths_match_on_quantized_model(tiny_dense, use_pallas_attn):
    """Quantized tiny model: the Pallas-attention path (fused in-kernel KV
    dequant) must match the jnp path (dense dequant) on decode."""
    import dataclasses
    cfg = dataclasses.replace(tiny_dense, quant="int8")
    params = tf.init_model(jax.random.PRNGKey(0), tiny_dense)
    from repro.core.speculative import ModelBundle
    qb = ModelBundle(params, tiny_dense).quantize()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128)

    def go(flag):
        old = A.USE_PALLAS_ATTN
        try:
            A.USE_PALLAS_ATTN = flag
            cache = tf.init_cache(cfg, 2, 16)
            logits, cache = tf.prefill(qb.params, cfg, toks, cache)
            assert cache["stack"][0]["k"].dtype == jnp.int8
            tok = jnp.argmax(logits, -1)
            out, _ = tf.decode_step(qb.params, cfg, tok, cache, 8)
            return np.asarray(out)
        finally:
            A.USE_PALLAS_ATTN = old

    ref_out = go(False)
    if use_pallas_attn:
        got = go(True)
        np.testing.assert_allclose(got, ref_out, rtol=2e-4, atol=2e-4)
    else:
        assert np.isfinite(ref_out).all()
