"""REPRO_USE_PALLAS_ATTN=1 path: kernel-backed decode / tree-verify must
match the jnp path exactly (the kernels run in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as A
from repro.models import transformer as tf


def test_kernel_decode_matches_jnp(tiny_dense):
    cfg = tiny_dense
    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128)
    cache = tf.init_cache(cfg, 2, 16)
    logits, cache = tf.prefill(params, cfg, toks, cache)
    tok = jnp.argmax(logits, -1)

    ref, _ = tf.decode_step(params, cfg, tok,
                            jax.tree.map(lambda x: x, cache), 8)
    old = A.USE_PALLAS_ATTN
    try:
        A.USE_PALLAS_ATTN = True
        got, _ = tf.decode_step(params, cfg, tok,
                                jax.tree.map(lambda x: x, cache), 8)
    finally:
        A.USE_PALLAS_ATTN = old
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_kernel_tree_verify_matches_jnp(tiny_dense):
    cfg = tiny_dense
    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0, 128)
    cache = tf.init_cache(cfg, 1, 16)
    logits, cache = tf.prefill(params, cfg, toks, cache)
    root = jnp.argmax(logits, -1)

    tcap = 8
    node_tokens = jnp.zeros((1, 4), jnp.int32).at[0, 0].set(root[0])
    positions = jnp.asarray([[6, 0, 0, 0]], jnp.int32)
    mask = np.zeros((4, tcap), bool)
    mask[0, 0] = True

    def go():
        tcaches = tf.init_tree_caches(cfg, 1, tcap)
        lg, _ = tf.tree_verify_step(params, cfg, node_tokens, positions,
                                    jnp.asarray(mask), cache, 6, tcaches, 0)
        return np.asarray(lg)

    ref = go()
    old = A.USE_PALLAS_ATTN
    try:
        A.USE_PALLAS_ATTN = True
        got = go()
    finally:
        A.USE_PALLAS_ATTN = old
    np.testing.assert_allclose(got[:, 0], ref[:, 0], rtol=2e-4, atol=2e-4)
