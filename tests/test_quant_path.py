"""Quantized serving path (int8 KV arena + int8 weights).

Regression strategy: quantized outputs are NOT bitwise fp32 outputs (int8
noise legitimately flips near-ties), so the pins are (a) *within-quant*
bit-identity — the quant DB engine must bit-match the quant single-request
engine, exactly like the fp32 equivalence pin, (b) the DBStats
accepted/proposed acceptance counters, and (c) the arena-bytes contract:
an int8 slot costs ≤0.55x the fp32 slot, i.e. ≥1.9x the slots at an equal
byte budget (ISSUE 8 acceptance criteria; the measured ratio is 0.3125).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pipedec import PipeDecConfig, PipeDecEngine
from repro.core.speculative import QUANT_WEIGHTS, ModelBundle
from repro.kernels.quant import dequantize_weight, is_quantized
from repro.models import transformer as tf
from repro.serving import KVArena, Request, SpecPipeDBEngine

PCFG = PipeDecConfig(n_stages=3, width=4, branch=2)
MAX_LEN = 128

QUANT_BYTES_RATIO_MAX = 0.55
QUANT_SLOTS_MULT_MIN = 1.9


@pytest.fixture(scope="module")
def bundles(tiny_dense, tiny_draft):
    tp = tf.init_model(jax.random.PRNGKey(0), tiny_dense)
    dp = tf.init_model(jax.random.PRNGKey(9), tiny_draft)
    return ModelBundle(tp, tiny_dense), ModelBundle(dp, tiny_draft)


@pytest.fixture(scope="module")
def qbundles(bundles):
    target, draft = bundles
    return target.quantize(), draft.quantize()


def _mk_reqs(seed, n, arrivals=None, max_new=None):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        prompt = rng.integers(0, 100, size=int(rng.integers(3, 8)))
        reqs.append(Request(
            i, prompt.astype(np.int32),
            int(max_new[i]) if max_new else int(rng.integers(3, 7)),
            arrival_t=int(arrivals[i]) if arrivals else 0))
    return reqs


def test_quantize_bundle_structure(bundles, qbundles):
    """quantize() swaps exactly the projection weights for {"q8","scale"}
    dicts (original shapes, per-out-channel scales), flips cfg.quant, and
    leaves the fp32 bundle untouched."""
    target, _ = bundles
    q_target, _ = qbundles
    assert q_target.cfg.quant == "int8" and target.cfg.quant == ""

    flat = jax.tree_util.tree_leaves_with_path(
        q_target.params, is_leaf=is_quantized)
    n_quant = 0
    for path, leaf in flat:
        name = getattr(path[-1], "key", None)
        if is_quantized(leaf):
            n_quant += 1
            assert name in QUANT_WEIGHTS
            assert leaf["q8"].dtype == jnp.int8
            assert leaf["scale"].dtype == jnp.float32
            # dequantized view stays close to the fp32 original
            orig = target.params
            for p in path[:-1]:
                orig = orig[p.key] if hasattr(p, "key") else orig[p.idx]
            orig = orig[name]
            assert leaf["q8"].shape == orig.shape
            amax = np.max(np.abs(np.asarray(orig)))
            # stacked leaves keep a leading reps dim on q8 AND scale
            nin = leaf["q8"].ndim - leaf["scale"].ndim
            stacked = leaf["scale"].shape != leaf["q8"].shape[nin:]
            deq = jax.vmap(dequantize_weight)(leaf) if stacked \
                else dequantize_weight(leaf)
            np.testing.assert_allclose(np.asarray(deq), np.asarray(orig),
                                       atol=amax / 254 + 1e-7)
        else:
            assert name not in QUANT_WEIGHTS, \
                f"projection {name} left unquantized"
    assert n_quant > 0
    # the fp32 params were not mutated
    assert not any(is_quantized(x) for x in
                   jax.tree_util.tree_leaves(target.params,
                                             is_leaf=is_quantized))


def test_quant_cache_layout_int8(qbundles):
    """The quantized bundle's caches carry int8 k/v plus f32 per-row
    scales, and all name-driven slot helpers flow the scale leaves."""
    q_target, _ = qbundles
    cache = q_target.init_cache(1, 16)
    sub = cache["stack"][0]
    assert sub["k"].dtype == jnp.int8 and sub["v"].dtype == jnp.int8
    assert sub["k_scale"].dtype == jnp.float32
    assert sub["k_scale"].shape == sub["k"].shape[:-1]
    assert set(tf.CACHE_LEN_AXIS_FROM_END) >= {"k_scale", "v_scale"}


def test_quant_db_bitmatches_quant_single(qbundles):
    """The strong pin: the quant DB engine (slot contention, staggered
    arrivals, fused dispatch) bit-matches the quant single-request engine
    per uid — quantization must not break the DB equivalence contract."""
    q_target, q_draft = qbundles
    reqs = _mk_reqs(3, 4, arrivals=[0, 1, 3, 5], max_new=[4, 5, 3, 4])
    single = PipeDecEngine(q_target, q_draft, PCFG, max_len=MAX_LEN)
    want = {r.uid: single.generate(r.prompt, r.max_new_tokens)[0]
            for r in reqs}

    eng = SpecPipeDBEngine(q_target, q_draft, PCFG, max_len=MAX_LEN,
                           max_slots=2)
    for r in reqs:
        eng.submit(r)
    res = eng.run()
    assert set(res) == set(want)
    for uid, tokens in want.items():
        np.testing.assert_array_equal(res[uid].tokens, tokens,
                                      err_msg=f"uid={uid}")
    assert eng.stats.peak_occupancy == 2


def test_quant_run_is_deterministic(qbundles):
    q_target, q_draft = qbundles
    reqs = _mk_reqs(4, 3)
    outs = []
    for _ in range(2):
        eng = SpecPipeDBEngine(q_target, q_draft, PCFG, max_len=MAX_LEN,
                               max_slots=2)
        for r in reqs:
            eng.submit(r)
        outs.append(eng.run())
    for uid in outs[0]:
        np.testing.assert_array_equal(outs[0][uid].tokens,
                                      outs[1][uid].tokens)


def test_quant_arena_bytes_gates(bundles, qbundles):
    """ISSUE 8 acceptance: an int8 slot ≤0.55x fp32 bytes, so an equal
    byte budget admits ≥1.9x the slots."""
    target, draft = bundles
    q_target, q_draft = qbundles
    fp32_b = KVArena(target, draft, slots=1, max_len=MAX_LEN,
                     tree_capacity=16).bytes_per_slot()
    int8_b = KVArena(q_target, q_draft, slots=1, max_len=MAX_LEN,
                     tree_capacity=16).bytes_per_slot()
    assert int8_b / fp32_b <= QUANT_BYTES_RATIO_MAX, (int8_b, fp32_b)
    assert fp32_b // int8_b >= QUANT_SLOTS_MULT_MIN


def test_dbstats_acceptance_counters(bundles):
    """Per-request accepted/proposed counters on DBStats: every retired
    uid records hits/(hits+misses) from its GenStats, and the aggregate
    acceptance_rate is the ratio of the totals."""
    target, draft = bundles
    reqs = _mk_reqs(5, 3, max_new=[4, 5, 3])
    eng = SpecPipeDBEngine(target, draft, PCFG, max_len=MAX_LEN,
                           max_slots=2)
    for r in reqs:
        eng.submit(r)
    res = eng.run()

    s = eng.stats
    for r in reqs:
        st = res[r.uid].stats
        assert s.accepted[r.uid] == st.hits
        assert s.proposed[r.uid] == st.hits + st.misses
        if s.proposed[r.uid]:
            assert s.acceptance_of(r.uid) == pytest.approx(
                st.hits / (st.hits + st.misses))
    assert s.total_accepted == sum(s.accepted.values())
    assert s.total_proposed == sum(s.proposed.values())
    assert 0.0 <= s.acceptance_rate <= 1.0
    assert s.acceptance_rate == pytest.approx(
        s.total_accepted / s.total_proposed)


def test_quant_acceptance_tracks_fp32(bundles, qbundles):
    """Acceptance-rate regression currency: on the same workload the quant
    engine's aggregate acceptance stays within the committed tolerance of
    fp32 (sharded_check --quant gates 0.15; random tiny models sit well
    inside it)."""
    rates = {}
    for name, (t, d) in (("fp32", bundles), ("int8", qbundles)):
        eng = SpecPipeDBEngine(t, d, PCFG, max_len=MAX_LEN, max_slots=2)
        for r in _mk_reqs(6, 3, max_new=[5, 4, 5]):
            eng.submit(r)
        eng.run()
        rates[name] = eng.stats.acceptance_rate
    assert abs(rates["int8"] - rates["fp32"]) <= 0.15, rates


def test_quantize_rejects_unsupported_arch(tiny_hybrid_ssm):
    """int8 serving is dense-attention only: recurrent/MLA/MoE bundles
    must fail loudly at quantize() time, not decode garbage."""
    bundle = ModelBundle(tf.init_model(jax.random.PRNGKey(1),
                                       tiny_hybrid_ssm), tiny_hybrid_ssm)
    with pytest.raises(AssertionError, match="dense attention only"):
        bundle.quantize()


def test_quant_flag_on_config_is_plumbed(tiny_dense):
    cfg = dataclasses.replace(tiny_dense, quant="int8")
    cache = tf.init_cache(cfg, 1, 8)
    assert cache["stack"][0]["k"].dtype == jnp.int8
    assert tf.init_cache(tiny_dense, 1, 8)["stack"][0]["k"].dtype \
        == jnp.float32
