"""PipeDec phase-level pins: pipeline-fill latency, expansion capacity
guard, flight-index dtype stability, and the batched per-row commit.

These pin the invariants the fused SpecPipe-DB dispatch relies on — the
DB engine drives the same gather-entry / apply-fused / exit-commit phases,
so a drift here silently changes the shared pipeline schedule.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tree as tree_lib
from repro.core.pipedec import (PipeDecConfig, PipeDecEngine,
                                remap_flight_indices)
from repro.core.speculative import ModelBundle, draft_candidates
from repro.models import transformer as tf

PCFG = PipeDecConfig(n_stages=3, width=4, branch=2)


@pytest.fixture(scope="module")
def bundles(tiny_dense, tiny_draft):
    tp = tf.init_model(jax.random.PRNGKey(0), tiny_dense)
    dp = tf.init_model(jax.random.PRNGKey(9), tiny_draft)
    return ModelBundle(tp, tiny_dense), ModelBundle(dp, tiny_draft)


# --------------------------------------------------------------------------
# entry→exit latency (the module docstring's schedule contract)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("stages", [1, 2, 4])
def test_pipeline_fill_latency(bundles, stages):
    """A layer entering at timestep t exits at t + n_stages - 1 (the entry
    timestep itself is stage 1), so the first post-prefill commit lands at
    local timestep n_stages exactly — pinned so the schedule can't drift."""
    target, draft = bundles
    eng = PipeDecEngine(target, draft,
                        PipeDecConfig(n_stages=stages, width=4, branch=2))
    st = eng.init_state(np.array([1, 5, 9], np.int32), 8)
    eng.step(st)
    if stages > 1:  # with 1 stage the entry exits within its own timestep
        assert len(st.flights) == 1
        assert st.flights[0].exit_t == 1 + stages - 1  # Flight contract
    while st.stats.commits == 0:
        eng.step(st)
    assert st.t == stages, "first commit == pipeline-fill latency"


# --------------------------------------------------------------------------
# expansion capacity guard (off-by-one regression)
# --------------------------------------------------------------------------
def test_tree_expand_truncates_at_capacity():
    """At ``n_nodes + w == cap + 1`` a full-width layer no longer fits:
    ``tree_expand`` silently clamps the lowest-ranked candidate — the
    behaviour the engine guard must defer around, never admit."""
    w, c = 2, 2
    pcfg = PipeDecConfig(n_stages=2, width=w, branch=c, max_depth=6)
    cap = pcfg.capacity
    t = tree_lib.tree_init(cap, 7)
    rng = np.random.default_rng(0)
    for _ in range(2):
        logits = jnp.asarray(rng.normal(size=(w, 32)), jnp.float32)
        tok, lp = draft_candidates(logits, jnp.ones((w,), bool), c)
        t = tree_lib.tree_expand(t, tok, lp, w)
    assert int(t.layer_size) == w  # full deepest layer to expand from

    # saturation: pretend the packed prefix holds cap + 1 - w nodes
    t_sat = t._replace(n_nodes=jnp.asarray(cap + 1 - w, jnp.int32))
    logits = jnp.asarray(rng.normal(size=(w, 32)), jnp.float32)
    tok, lp = draft_candidates(logits, jnp.ones((w,), bool), c)
    grown = tree_lib.tree_expand(t_sat, tok, lp, w)
    assert int(grown.layer_size) == w - 1, \
        "layer silently truncated at the buffer edge"


def test_expansion_guard_defers_at_saturation(bundles):
    """The engine guard admits a layer only when all ``w`` slots fit:
    ``n_nodes + w <= cap`` expands, ``n_nodes + w == cap + 1`` defers
    (the old ``<= cap + 1`` guard admitted the truncating expand above)."""
    target, draft = bundles
    w = 2
    pcfg = PipeDecConfig(n_stages=2, width=w, branch=2, max_depth=6)
    cap = pcfg.capacity
    eng = PipeDecEngine(target, draft, pcfg)
    tree = tree_lib.tree_init(cap, 3)

    ok = tree._replace(n_nodes=jnp.asarray(cap - w, jnp.int32))
    assert eng.can_expand(ok)
    exact = tree._replace(n_nodes=jnp.asarray(cap + 1 - w, jnp.int32))
    assert not eng.can_expand(exact), "off-by-one: truncating expand admitted"
    full = tree._replace(n_nodes=jnp.asarray(cap, jnp.int32))
    assert not eng.can_expand(full)


def test_deep_tree_small_capacity_stays_lossless(bundles):
    """Capacity-saturation end-to-end: a deep narrow tree (width 2, depth
    cap 8 ⇒ capacity 17) with a perfect draft drives n_nodes against the
    buffer edge; output must still match plain autoregressive decode."""
    from repro.core.baselines import generate_autoregressive
    target, _ = bundles
    prompt = np.array([3, 3, 8], np.int32)
    ar = generate_autoregressive(target, prompt, 12)
    eng = PipeDecEngine(target, target,
                        PipeDecConfig(n_stages=2, width=2, branch=2,
                                      max_depth=8))
    out, stats = eng.generate(prompt, 12)
    assert np.array_equal(ar, out)
    assert stats.commits >= 12


# --------------------------------------------------------------------------
# flight-index dtype stability
# --------------------------------------------------------------------------
def test_remap_flight_indices_int32():
    node_idx = np.array([0, 3, -1, 7], np.int32)
    imap = jnp.asarray([0, -1, 1, 2, -1, -1, -1, 3], jnp.int32)
    out = remap_flight_indices(node_idx, imap)
    assert out.dtype == np.int32
    np.testing.assert_array_equal(out, [0, 2, -1, 3])
    # second prune cycle keeps the dtype stable (was int64 before)
    out2 = remap_flight_indices(out, imap)
    assert out2.dtype == np.int32


def test_flight_indices_stay_int32_through_engine(bundles):
    """Every in-flight node-index buffer stays int32 across hit/prune
    cycles of a real decode."""
    target, _ = bundles
    eng = PipeDecEngine(target, target, PCFG)  # self-draft => hits/prunes
    st = eng.init_state(np.array([2, 7, 1], np.int32), 10)
    while not st.done:
        eng.step(st)
        for fl in st.flights:
            assert fl.node_idx.dtype == np.int32
        if st.last_draft is not None:
            assert st.last_draft[0].dtype == np.int32
    assert st.stats.hits > 0, "prune cycles actually exercised"


# --------------------------------------------------------------------------
# batched per-row commit == per-row loop of the scalar commit
# --------------------------------------------------------------------------
def test_commit_tree_nodes_matches_scalar_commit(tiny_dense):
    cfg = tiny_dense
    rows, max_len, tcap = 3, 16, 8
    key = jax.random.PRNGKey(4)

    def randomize(tree, salt):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        ks = jax.random.split(jax.random.fold_in(key, salt), len(leaves))
        return jax.tree_util.tree_unflatten(
            treedef, [jax.random.normal(k, l.shape, l.dtype)
                      for k, l in zip(ks, leaves)])

    cache = randomize(tf.init_cache(cfg, rows, max_len), 0)
    tcache = randomize(tf.init_tree_caches(cfg, rows, tcap), 1)
    node_idx = jnp.asarray([2, 0, 5], jnp.int32)
    model_len = jnp.asarray([4, 9, 1], jnp.int32)
    mask = jnp.asarray([True, False, True])

    got = tf.commit_tree_nodes(cfg, cache, tcache, node_idx, model_len,
                               mask)
    for r in range(rows):
        row_c = tf.slice_cache_rows(cache, r, 1)
        row_t = tf.slice_cache_rows(tcache, r, 1)
        if bool(mask[r]):
            want = tf.commit_tree_node(cfg, row_c, row_t,
                                       int(node_idx[r]), int(model_len[r]))
        else:
            want = row_c  # masked rows bit-unchanged
        got_row = tf.slice_cache_rows(got, r, 1)
        for (pw, lw), (pg, lg) in zip(
                jax.tree_util.tree_leaves_with_path(want),
                jax.tree_util.tree_leaves_with_path(got_row)):
            assert pw == pg
            np.testing.assert_array_equal(np.asarray(lw), np.asarray(lg),
                                          err_msg=f"row {r} {pw}")
