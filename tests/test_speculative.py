"""Losslessness + acceptance properties of PipeDec / STPP (paper's central
correctness claim: speculative output ≡ target-model autoregressive output).
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.baselines import (STPPConfig, STPPEngine,
                                  generate_autoregressive)
from repro.core.pipedec import PipeDecConfig, PipeDecEngine
from repro.core.speculative import ModelBundle, SamplingParams
from repro.models import transformer as tf


@pytest.fixture(scope="module")
def bundles(tiny_dense, tiny_draft):
    tp = tf.init_model(jax.random.PRNGKey(0), tiny_dense)
    dp = tf.init_model(jax.random.PRNGKey(9), tiny_draft)
    return ModelBundle(tp, tiny_dense), ModelBundle(dp, tiny_draft)


def test_pipedec_lossless_greedy(bundles):
    target, draft = bundles
    prompt = np.array([1, 5, 9, 3], np.int32)
    ar = generate_autoregressive(target, prompt, 16)
    for stages in (1, 2, 4):
        eng = PipeDecEngine(target, draft,
                            PipeDecConfig(n_stages=stages, width=4, branch=2))
        out, stats = eng.generate(prompt, 16)
        assert np.array_equal(ar, out), f"stages={stages}"
        assert stats.commits >= 16


def test_stpp_lossless_greedy(bundles):
    target, draft = bundles
    prompt = np.array([2, 7, 7, 1], np.int32)
    ar = generate_autoregressive(target, prompt, 12)
    eng = STPPEngine(target, draft, STPPConfig(depth=3, width=4, branch=2))
    out, stats = eng.generate(prompt, 12)
    assert np.array_equal(ar, out)
    assert stats.rounds >= 1


def test_self_draft_perfect_acceptance(bundles):
    """Draft == target => every prediction hits; ~1 token/timestep in the
    steady state (paper Fig. 1 right), and >1 accepted/round for STPP."""
    target, _ = bundles
    prompt = np.array([3, 3, 8], np.int32)
    # width 8: wide enough that the greedy path is never evicted from the
    # tree by cumulative-probability top-w selection (the paper's "scale
    # effect" — narrow trees lose deep greedy nodes and refill the pipeline)
    eng = PipeDecEngine(target, target,
                        PipeDecConfig(n_stages=4, width=8, branch=4))
    # 40-token horizon: long enough to amortise the pipeline fill and the
    # occasional depth-drift re-sync bubble (a short horizon sits at ~0.71
    # even with perfect acceptance; 40 -> ~0.83, 80 -> ~0.89)
    out, stats = eng.generate(prompt, 40)
    assert stats.acceptance == 1.0
    assert stats.tokens_per_timestep > 0.75  # 1 - pipeline-fill overhead

    stpp = STPPEngine(target, target, STPPConfig(depth=3, width=8, branch=4))
    _, sstats = stpp.generate(prompt, 40)
    # most rounds accept the full depth; occasional rounds lose the greedy
    # path to cumulative-probability top-w eviction (faithful STPP behaviour)
    assert sstats.mean_accepted >= 2.0


def test_random_draft_degrades_to_pipeline_rate(bundles):
    """A useless draft must never break losslessness; throughput degrades to
    ~1/n_stages tokens per timestep (vanilla PP behaviour)."""
    target, draft = bundles
    prompt = np.array([0, 1, 2], np.int32)
    ar = generate_autoregressive(target, prompt, 10)
    eng = PipeDecEngine(target, draft,
                        PipeDecConfig(n_stages=3, width=2, branch=1))
    out, stats = eng.generate(prompt, 10)
    assert np.array_equal(ar, out)
    if stats.acceptance == 0.0:
        assert abs(stats.tokens_per_timestep - 1 / 3) < 0.12


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 100), stages=st.integers(1, 5))
def test_pipedec_lossless_property(bundles, seed, stages):
    target, draft = bundles
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, 100, size=rng.integers(2, 8)).astype(np.int32)
    ar = generate_autoregressive(target, prompt, 8)
    eng = PipeDecEngine(target, draft,
                        PipeDecConfig(n_stages=stages, width=3, branch=2))
    out, _ = eng.generate(prompt, 8)
    assert np.array_equal(ar, out)


def test_stochastic_decoding_runs(bundles):
    """Fig. 7 setting: temperature 0.6, top-p 0.9, top-k 80 — sampling is
    drawn from the target only, so the engine stays valid (same-key
    equality is not expected; we assert structural health)."""
    target, draft = bundles
    sp = SamplingParams(temperature=0.6, top_p=0.9, top_k=80)
    prompt = np.array([4, 4, 2], np.int32)
    eng = PipeDecEngine(target, draft,
                        PipeDecConfig(n_stages=3, width=4, branch=2,
                                      sampling=sp))
    out, stats = eng.generate(prompt, 12, key=jax.random.PRNGKey(123))
    assert len(out) == 13
    assert stats.commits >= 12
    assert ((out >= 0) & (out < target.cfg.vocab_size)).all()
