"""Attention unit tests: variants, cache equivalence, tree-verify path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models import transformer as tf
from repro.models.config import MLAConfig, ModelConfig


def mk_cfg(**kw):
    base = dict(name="t", family="dense", num_layers=1, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64)
    base.update(kw)
    return ModelConfig(**base)


def test_gqa_attend_matches_mha_when_repeated():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 5, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 5, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 5, 2, 16)), jnp.float32)
    out = A.gqa_attend(q, k, v, A.causal_mask(5, 5, 0))
    k2 = jnp.repeat(k, 2, axis=2)
    v2 = jnp.repeat(v, 2, axis=2)
    out2 = A.gqa_attend(q, k2, v2, A.causal_mask(5, 5, 0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), rtol=1e-6)


def test_sliding_window_masks_old_tokens():
    cfg = mk_cfg()
    params = A.init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, 64))
    pos = jnp.arange(12)[None]
    full, _ = A.attn_forward(params, cfg, x, pos)
    win, _ = A.attn_forward(params, cfg, x, pos, window=4)
    # early positions (inside window) identical, late positions differ
    np.testing.assert_allclose(np.asarray(full[:, :4]), np.asarray(win[:, :4]),
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(full[:, -1]), np.asarray(win[:, -1]))


def test_chunked_causal_equals_dense():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
    dense = A.gqa_attend(q, k, v, A.causal_mask(64, 64, 0))
    old = A.CHUNK_Q
    try:
        A.CHUNK_Q = 16
        chunked = A.chunked_causal_attend(q, k, v)
    finally:
        A.CHUNK_Q = old
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               rtol=2e-5, atol=2e-5)
    # windowed variant too
    denw = A.gqa_attend(q, k, v, A.causal_mask(64, 64, 0, window=7))
    try:
        A.CHUNK_Q = 16
        chw = A.chunked_causal_attend(q, k, v, window=7)
    finally:
        A.CHUNK_Q = old
    np.testing.assert_allclose(np.asarray(denw), np.asarray(chw),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("mla", [False, True])
def test_decode_matches_full_forward(mla):
    cfg = mk_cfg(num_kv_heads=4,
                 mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0,
                               qk_nope_head_dim=16, qk_rope_head_dim=8,
                               v_head_dim=16) if mla else None)
    params = A.init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, 64))
    pos = jnp.broadcast_to(jnp.arange(9)[None], (2, 9))
    full, _ = A.attn_forward(params, cfg, x, pos)

    cache = A.init_kv_cache(cfg, 2, 16)
    pre, cache = A.attn_forward(params, cfg, x[:, :8], pos[:, :8],
                                cache=cache, cache_index=0)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, :8]),
                               rtol=2e-5, atol=2e-5)
    dec, cache = A.attn_decode(params, cfg, x[:, 8:9],
                               jnp.full((2,), 8, jnp.int32), cache, 8)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, 8:9]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("mla", [False, True])
def test_tree_verify_equals_path_decode(mla, tiny_dense, tiny_mla):
    """A linear chain presented as a 'tree' must reproduce sequential
    decode logits exactly (the heart of speculative losslessness)."""
    cfg = tiny_mla if mla else tiny_dense
    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    prompt = jnp.asarray([[3, 1, 4, 1, 5]], jnp.int32)

    cache = tf.init_cache(cfg, 1, 32)
    logits0, cache = tf.prefill(params, cfg, prompt, cache)
    chain = [int(jnp.argmax(logits0[0]))]

    # reference: sequential greedy decode
    ref_cache = jax.tree.map(lambda x: x, cache)
    ref_logits = []
    tok = chain[0]
    mlen = 5
    for i in range(3):
        lg, ref_cache = tf.decode_step(params, cfg,
                                       jnp.asarray([tok], jnp.int32),
                                       ref_cache, mlen)
        ref_logits.append(np.asarray(lg[0]))
        tok = int(jnp.argmax(lg[0]))
        chain.append(tok)
        mlen += 1

    # tree verify: present the same chain as a depth-3 path, one layer at a
    # time (each node list = one layer of width 1)
    tcap = 8
    tcaches = tf.init_tree_caches(cfg, 1, tcap)
    mask = np.zeros((1, tcap), bool)
    out_logits = []
    for d in range(3):
        mask[0, d] = True
        row = np.zeros((1, tcap), bool)
        row[0, : d + 1] = True
        lg, tcaches = tf.tree_verify_step(
            params, cfg, jnp.asarray([[chain[d]]], jnp.int32),
            jnp.asarray([[5 + d]], jnp.int32), jnp.asarray(row),
            cache, 5, tcaches, d)
        out_logits.append(np.asarray(lg[0, 0]))

    for got, ref in zip(out_logits, ref_logits):
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_commit_tree_node_moves_kv(tiny_dense):
    cfg = tiny_dense
    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    prompt = jnp.asarray([[3, 1, 4]], jnp.int32)
    cache = tf.init_cache(cfg, 1, 16)
    logits0, cache = tf.prefill(params, cfg, prompt, cache)
    tok = int(jnp.argmax(logits0[0]))

    # reference: decode writes KV at position 3
    ref_cache = jax.tree.map(lambda x: x, cache)
    _, ref_cache = tf.decode_step(params, cfg, jnp.asarray([tok], jnp.int32),
                                  ref_cache, 3)

    # tree path: verify node then commit row 0
    tcaches = tf.init_tree_caches(cfg, 1, 4)
    row = np.zeros((1, 4), bool)
    row[0, 0] = True
    _, tcaches = tf.tree_verify_step(
        params, cfg, jnp.asarray([[tok]], jnp.int32),
        jnp.asarray([[3]], jnp.int32), jnp.asarray(row), cache, 3,
        tcaches, 0)
    cache2 = tf.commit_tree_node(cfg, cache, tcaches, 0, 3)

    ref_k = np.asarray(ref_cache["stack"][0]["k"][:, :, :4])
    got_k = np.asarray(cache2["stack"][0]["k"][:, :, :4])
    np.testing.assert_allclose(got_k, ref_k, rtol=2e-5, atol=2e-5)
