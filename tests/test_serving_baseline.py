"""Regression pins for pre-DB serving semantics, so the dynamic-batching
refactor cannot silently change the baselines it is measured against."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import tree as tree_lib
from repro.core.pipedec import PipeDecConfig
from repro.core.speculative import ModelBundle
from repro.models import transformer as tf
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def target(tiny_dense):
    return ModelBundle(tf.init_model(jax.random.PRNGKey(0), tiny_dense),
                       tiny_dense)


def test_pp_bucketing_regression(target):
    """mode="pp" pins: requests are bucketed by prompt length, buckets are
    chunked into ``max_batch`` lockstep batches, every uid is answered, and
    outputs are independent of which batch a request lands in."""
    rng = np.random.default_rng(1)
    lengths = [4, 6, 4, 6, 4]
    reqs = [Request(i, rng.integers(0, 100, ln).astype(np.int32), 5)
            for i, ln in enumerate(lengths)]

    eng = ServingEngine(target, mode="pp", max_batch=2)
    for r in reqs:
        eng.submit(r)
    res = eng.run()
    assert set(res) == set(range(5))
    for r in res.values():
        assert len(r.tokens) == 6  # max_new_tokens + 1

    # requests in one lockstep batch share a wall-clock measurement, so the
    # latency values expose the batch partition: len-4 bucket -> {0,2},{4};
    # len-6 bucket -> {1,3}
    groups = {}
    for uid, r in res.items():
        groups.setdefault(r.latency_s, set()).add(uid)
    assert {frozenset(g) for g in groups.values()} == \
        {frozenset({0, 2}), frozenset({4}), frozenset({1, 3})}

    # batching must not change tokens: unbatched run bit-matches
    solo = ServingEngine(target, mode="pp", max_batch=1)
    for r in reqs:
        solo.submit(Request(r.uid, r.prompt, r.max_new_tokens))
    solo_res = solo.run()
    for uid in res:
        np.testing.assert_array_equal(res[uid].tokens, solo_res[uid].tokens)


def test_pp_mixed_token_budgets_truncated(target):
    """A batch decodes to the longest budget; shorter requests are cut back
    to their own max_new_tokens + 1."""
    rng = np.random.default_rng(2)
    eng = ServingEngine(target, mode="pp", max_batch=4)
    for i, new in enumerate([3, 7]):
        eng.submit(Request(i, rng.integers(0, 100, 5).astype(np.int32), new))
    res = eng.run()
    assert len(res[0].tokens) == 4 and len(res[1].tokens) == 8


# --------------------------------------------------------------------------
# PipeDecConfig depth-cap / capacity invariants (the DB engine sizes its
# TreeBatch and KV arenas from these)
# --------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(n_stages=st.integers(1, 24), width=st.integers(1, 32),
       max_depth=st.integers(0, 40))
def test_pipedec_config_depth_capacity_property(n_stages, width, max_depth):
    cfg = PipeDecConfig(n_stages=n_stages, width=width, max_depth=max_depth)
    if max_depth:
        assert cfg.depth_cap == max_depth
    else:
        assert cfg.depth_cap == n_stages + 4  # default: stages + slack
    assert cfg.capacity == 1 + width * cfg.depth_cap
    # the tree buffer can hold the root plus depth_cap full layers — the
    # expand deferral check (n_nodes + w <= capacity + 1) then guarantees
    # tree_expand never drops a layer for space
    assert cfg.capacity >= 1 + width


@settings(max_examples=15, deadline=None)
@given(width=st.integers(1, 6), depth=st.integers(1, 5),
       seed=st.integers(0, 10_000))
def test_tree_expand_respects_capacity(width, depth, seed):
    """n_nodes never exceeds capacity no matter the expansion sequence."""
    cfg = PipeDecConfig(n_stages=2, width=width, max_depth=depth)
    rng = np.random.default_rng(seed)
    tree = tree_lib.tree_init(cfg.capacity, 1)
    for _ in range(depth + 2):  # two layers beyond the cap
        lp = jax.numpy.asarray(rng.normal(size=(width, 3)),
                               jax.numpy.float32)
        tok = jax.numpy.asarray(rng.integers(0, 50, size=(width, 3)),
                                jax.numpy.int32)
        tree = tree_lib.tree_expand(tree, tok, lp, width)
        assert int(tree.n_nodes) <= cfg.capacity
        assert int(tree.layer_start) + int(tree.layer_size) <= cfg.capacity
