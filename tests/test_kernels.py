"""Pallas kernel sweeps (interpret mode) against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash import flash_attention_lse
from repro.kernels.quant import dequantize_rows, quantize_rows
from repro.kernels.tree_block import tree_block_attention


def rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=4e-2, atol=4e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,n,hd,lmax,t", [
    (1, 4, 2, 8, 64, 96, 16),
    (2, 2, 1, 4, 128, 64, 8),
    (1, 8, 8, 16, 32, 256, 32),
])
def test_tree_attention_sweep(b, h, kv, n, hd, lmax, t, dtype):
    rng = np.random.default_rng(hash((b, h, n)) % 2**31)
    q = rand(rng, (b, h, n, hd), dtype)
    kp = rand(rng, (b, kv, lmax, hd), dtype)
    vp = rand(rng, (b, kv, lmax, hd), dtype)
    kt = rand(rng, (b, kv, t, hd), dtype)
    vt = rand(rng, (b, kv, t, hd), dtype)
    mask = jnp.asarray(rng.random((n, t)) > 0.4).at[:, 0].set(True)
    plen = lmax // 2
    out = ops.tree_attention(q, kp, vp, kt, vt, mask, plen, block_k=32)
    want = ref.tree_attention_ref(q.astype(jnp.float32),
                                  kp.astype(jnp.float32),
                                  vp.astype(jnp.float32),
                                  kt.astype(jnp.float32),
                                  vt.astype(jnp.float32), mask, plen)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("b,h,kv,hd,lmax", [
    (1, 4, 2, 64, 128),
    (2, 8, 1, 128, 64),
])
def test_decode_attention_sweep(b, h, kv, hd, lmax, window, dtype):
    rng = np.random.default_rng(hash((b, h, hd, window)) % 2**31)
    q = rand(rng, (b, h, 1, hd), dtype)
    k = rand(rng, (b, kv, lmax, hd), dtype)
    v = rand(rng, (b, kv, lmax, hd), dtype)
    klen = lmax - 7
    out = ops.decode_attention(q, k, v, klen, window=window, block_k=32)
    want = ref.decode_attention_ref(q.astype(jnp.float32),
                                    k.astype(jnp.float32),
                                    v.astype(jnp.float32), klen,
                                    window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), **TOL[dtype])


def test_combine_lse_equals_joint_softmax():
    """Flash-decoding combination over two KV sources == joint softmax."""
    rng = np.random.default_rng(0)
    b, h, kv, n, hd = 1, 2, 2, 4, 32
    q = rand(rng, (b, h, n, hd), jnp.float32)
    k1 = rand(rng, (b, kv, 64, hd), jnp.float32)
    v1 = rand(rng, (b, kv, 64, hd), jnp.float32)
    k2 = rand(rng, (b, kv, 32, hd), jnp.float32)
    v2 = rand(rng, (b, kv, 32, hd), jnp.float32)
    p1 = flash_attention_lse(q, k1, v1, 64, block_k=32)
    mask = jnp.ones((n, 32), bool)
    p2 = tree_block_attention(q, k2, v2, mask)
    got = ops.combine_lse([p1, p2])
    want = ref.tree_attention_ref(q, k1, v1, k2, v2, mask, 64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_zero_length_prefix_safe():
    """past_len=0 must not produce NaNs (fresh-context tree attention)."""
    rng = np.random.default_rng(1)
    q = rand(rng, (1, 2, 4, 32), jnp.float32)
    k = rand(rng, (1, 2, 64, 32), jnp.float32)
    v = rand(rng, (1, 2, 64, 32), jnp.float32)
    o, m, l = flash_attention_lse(q, k, v, 0, block_k=32)
    assert np.isfinite(np.asarray(o)).all()
    assert (np.asarray(l[..., 0]) == 0).all()
    kt = rand(rng, (1, 2, 8, 32), jnp.float32)
    vt = rand(rng, (1, 2, 8, 32), jnp.float32)
    mask = jnp.ones((4, 8), bool)
    out = ops.tree_attention(q, k, v, kt, vt, mask, 0, block_k=32)
    want = ref.tree_attention_ref(q, k, v, kt, vt, mask, 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.tpu_kernel
def test_flash_compiles_for_tpu():
    """Actual TPU lowering (interpret=False) — auto-skipped off-TPU; the
    interpret-mode sweeps above cover the same math everywhere."""
    rng = np.random.default_rng(2)
    q = rand(rng, (1, 4, 8, 128), jnp.float32)
    k = rand(rng, (1, 2, 256, 128), jnp.float32)
    v = rand(rng, (1, 2, 256, 128), jnp.float32)
    o, m, l = flash_attention_lse(q, k, v, 200, block_k=128, interpret=False)
    assert np.isfinite(np.asarray(o)).all()


@pytest.mark.tpu_kernel
def test_tree_block_compiles_for_tpu():
    rng = np.random.default_rng(3)
    q = rand(rng, (1, 4, 8, 128), jnp.float32)
    kt = rand(rng, (1, 2, 16, 128), jnp.float32)
    vt = rand(rng, (1, 2, 16, 128), jnp.float32)
    mask = jnp.ones((8, 16), bool)
    o, m, l = tree_block_attention(q, kt, vt, mask, interpret=False)
    assert np.isfinite(np.asarray(o)).all()


# ---------------------------------------------------------------------------
# int8 quantization (KV rows + weights)
# ---------------------------------------------------------------------------

def test_quantize_rows_roundtrip_bound():
    """Round-trip error is bounded by scale/2 = amax/254 per element."""
    rng = np.random.default_rng(11)
    x = rand(rng, (2, 3, 17, 32), jnp.float32) * 3.0
    q, s = quantize_rows(x)
    assert q.dtype == jnp.int8 and s.shape == (2, 3, 17)
    back = dequantize_rows(q, s)
    amax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    assert (np.abs(np.asarray(back) - np.asarray(x))
            <= amax / 254 + 1e-7).all()
    # saturation: the per-row extrema map to exactly +/-127
    assert (np.max(np.abs(np.asarray(q)), axis=-1) == 127).all()


def test_quantize_rows_zero_rows_exact():
    """All-zero rows (padded/unwritten cache slots) round-trip bit-exactly
    with scale 1 — no NaN/inf from a zero amax."""
    x = jnp.zeros((1, 2, 4, 8), jnp.float32).at[0, 0, 0].set(1.0)
    q, s = quantize_rows(x)
    assert np.asarray(s)[0, 0, 1:].tolist() == [1.0, 1.0, 1.0]
    back = np.asarray(dequantize_rows(q, s))
    assert (back[0, 0, 1:] == 0).all() and (back[0, 1] == 0).all()
    np.testing.assert_allclose(back[0, 0, 0], np.asarray(x)[0, 0, 0],
                               atol=1 / 254)


@pytest.mark.parametrize("b,h,kv,n,hd,lmax,t", [
    (1, 4, 2, 8, 64, 96, 16),
    (2, 2, 1, 4, 32, 64, 8),
])
def test_tree_attention_quant_kernel_vs_ref(b, h, kv, n, hd, lmax, t):
    """int8 K/V with per-row scales, fused in-kernel dequant: the kernel
    path must match the quant oracle under per-row [B] past_len and
    per-row [B,n,T] tree masks (the fused SpecPipe-DB dispatch shape)."""
    rng = np.random.default_rng(hash((b, h, n, t)) % 2**31)
    q = rand(rng, (b, h, n, hd), jnp.float32)
    kp = rand(rng, (b, kv, lmax, hd), jnp.float32)
    vp = rand(rng, (b, kv, lmax, hd), jnp.float32)
    kt = rand(rng, (b, kv, t, hd), jnp.float32)
    vt = rand(rng, (b, kv, t, hd), jnp.float32)
    kpq, kps = quantize_rows(kp)
    vpq, vps = quantize_rows(vp)
    ktq, kts = quantize_rows(kt)
    vtq, vts = quantize_rows(vt)
    mask = jnp.asarray(
        rng.random((b, n, t)) > 0.4).at[:, :, 0].set(True)
    plen = jnp.asarray(rng.integers(1, lmax, size=b), jnp.int32)
    quant_kw = dict(k_scale=kps, v_scale=vps, kt_scale=kts, vt_scale=vts)
    out = ops.tree_attention(q, kpq, vpq, ktq, vtq, mask, plen,
                             block_k=32, **quant_kw)
    want = ref.tree_attention_quant_ref(q, kpq, vpq, ktq, vtq, mask, plen,
                                        **quant_kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # and the fused-dequant math matches fp32 attention over the
    # dequantized tensors (no separate approximation inside the kernel)
    full = ref.tree_attention_ref(q, dequantize_rows(kpq, kps),
                                  dequantize_rows(vpq, vps),
                                  dequantize_rows(ktq, kts),
                                  dequantize_rows(vtq, vts), mask, plen)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [0, 16])
def test_decode_attention_quant_kernel_vs_ref(window):
    rng = np.random.default_rng(13 + window)
    b, h, kv, hd, lmax = 2, 4, 2, 32, 64
    q = rand(rng, (b, h, 1, hd), jnp.float32)
    k = rand(rng, (b, kv, lmax, hd), jnp.float32)
    v = rand(rng, (b, kv, lmax, hd), jnp.float32)
    kq, ks = quantize_rows(k)
    vq, vs = quantize_rows(v)
    klen = lmax - 7
    out = ops.decode_attention(q, kq, vq, klen, window=window, block_k=32,
                               k_scale=ks, v_scale=vs)
    want = ref.decode_attention_quant_ref(q, kq, vq, klen, k_scale=ks,
                                          v_scale=vs, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("m,k,n", [(7, 33, 19), (128, 128, 128),
                                   (130, 96, 200)])
def test_dequant_matmul_kernel_vs_ref(m, k, n):
    """Fused Pallas dequant-matmul (incl. ragged shapes that pad to the
    block grid) against the jnp oracle."""
    from repro.kernels.quant import quantize_weight
    rng = np.random.default_rng(hash((m, k, n)) % 2**31)
    x = rand(rng, (m, k), jnp.float32)
    w = rand(rng, (k, n), jnp.float32)
    wq = quantize_weight(w, 1)
    out = ops.dequant_matmul(x, wq["q8"], wq["scale"], use_kernel=True,
                             block_m=64, block_n=64, block_k=32)
    want = ref.dequant_matmul_ref(x, wq["q8"], wq["scale"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_dequant_matmul_zero_channel_scale():
    """An all-zero output channel quantizes to scale 1 / q8 0 and the
    kernel must produce exact zeros for it (no NaN from a 0 scale)."""
    from repro.kernels.quant import quantize_weight
    rng = np.random.default_rng(17)
    x = rand(rng, (5, 16), jnp.float32)
    w = rng.normal(size=(16, 8)).astype(np.float32)
    w[:, 3] = 0.0
    wq = quantize_weight(jnp.asarray(w), 1)
    assert float(wq["scale"][3]) == 1.0
    out = np.asarray(ops.dequant_matmul(x, wq["q8"], wq["scale"],
                                        use_kernel=True, block_m=8,
                                        block_n=8, block_k=8))
    assert (out[:, 3] == 0).all()
    want = np.asarray(ref.dequant_matmul_ref(x, wq["q8"], wq["scale"]))
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 11])
@pytest.mark.parametrize("b,h,kv,s,hd", [(1, 4, 2, 96, 32), (2, 2, 1, 64, 64)])
def test_prefill_causal_flash_sweep(b, h, kv, s, hd, window, dtype):
    rng = np.random.default_rng(hash((b, s, window)) % 2**31)
    q = rand(rng, (b, h, s, hd), dtype)
    k = rand(rng, (b, kv, s, hd), dtype)
    v = rand(rng, (b, kv, s, hd), dtype)
    pos = jnp.arange(s)
    got = ops.prefill_attention(q, k, v, pos, window=window, block_k=32,
                                block_q=16)
    rep = h // kv
    kr = jnp.repeat(k.astype(jnp.float32), rep, 1)
    vr = jnp.repeat(v.astype(jnp.float32), rep, 1)
    lg = jnp.einsum("bhqd,bhsd->bhqs", q.astype(jnp.float32), kr) / np.sqrt(hd)
    m = pos[None, :] <= pos[:, None]
    if window:
        m &= pos[None, :] > pos[:, None] - window
    lg = jnp.where(m[None, None], lg, -jnp.inf)
    want = jnp.einsum("bhqs,bhsd->bhqd", jax.nn.softmax(lg, -1), vr)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), **TOL[dtype])
