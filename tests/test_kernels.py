"""Pallas kernel sweeps (interpret mode) against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash import flash_attention_lse
from repro.kernels.tree_block import tree_block_attention


def rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=4e-2, atol=4e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,n,hd,lmax,t", [
    (1, 4, 2, 8, 64, 96, 16),
    (2, 2, 1, 4, 128, 64, 8),
    (1, 8, 8, 16, 32, 256, 32),
])
def test_tree_attention_sweep(b, h, kv, n, hd, lmax, t, dtype):
    rng = np.random.default_rng(hash((b, h, n)) % 2**31)
    q = rand(rng, (b, h, n, hd), dtype)
    kp = rand(rng, (b, kv, lmax, hd), dtype)
    vp = rand(rng, (b, kv, lmax, hd), dtype)
    kt = rand(rng, (b, kv, t, hd), dtype)
    vt = rand(rng, (b, kv, t, hd), dtype)
    mask = jnp.asarray(rng.random((n, t)) > 0.4).at[:, 0].set(True)
    plen = lmax // 2
    out = ops.tree_attention(q, kp, vp, kt, vt, mask, plen, block_k=32)
    want = ref.tree_attention_ref(q.astype(jnp.float32),
                                  kp.astype(jnp.float32),
                                  vp.astype(jnp.float32),
                                  kt.astype(jnp.float32),
                                  vt.astype(jnp.float32), mask, plen)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("b,h,kv,hd,lmax", [
    (1, 4, 2, 64, 128),
    (2, 8, 1, 128, 64),
])
def test_decode_attention_sweep(b, h, kv, hd, lmax, window, dtype):
    rng = np.random.default_rng(hash((b, h, hd, window)) % 2**31)
    q = rand(rng, (b, h, 1, hd), dtype)
    k = rand(rng, (b, kv, lmax, hd), dtype)
    v = rand(rng, (b, kv, lmax, hd), dtype)
    klen = lmax - 7
    out = ops.decode_attention(q, k, v, klen, window=window, block_k=32)
    want = ref.decode_attention_ref(q.astype(jnp.float32),
                                    k.astype(jnp.float32),
                                    v.astype(jnp.float32), klen,
                                    window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), **TOL[dtype])


def test_combine_lse_equals_joint_softmax():
    """Flash-decoding combination over two KV sources == joint softmax."""
    rng = np.random.default_rng(0)
    b, h, kv, n, hd = 1, 2, 2, 4, 32
    q = rand(rng, (b, h, n, hd), jnp.float32)
    k1 = rand(rng, (b, kv, 64, hd), jnp.float32)
    v1 = rand(rng, (b, kv, 64, hd), jnp.float32)
    k2 = rand(rng, (b, kv, 32, hd), jnp.float32)
    v2 = rand(rng, (b, kv, 32, hd), jnp.float32)
    p1 = flash_attention_lse(q, k1, v1, 64, block_k=32)
    mask = jnp.ones((n, 32), bool)
    p2 = tree_block_attention(q, k2, v2, mask)
    got = ops.combine_lse([p1, p2])
    want = ref.tree_attention_ref(q, k1, v1, k2, v2, mask, 64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_zero_length_prefix_safe():
    """past_len=0 must not produce NaNs (fresh-context tree attention)."""
    rng = np.random.default_rng(1)
    q = rand(rng, (1, 2, 4, 32), jnp.float32)
    k = rand(rng, (1, 2, 64, 32), jnp.float32)
    v = rand(rng, (1, 2, 64, 32), jnp.float32)
    o, m, l = flash_attention_lse(q, k, v, 0, block_k=32)
    assert np.isfinite(np.asarray(o)).all()
    assert (np.asarray(l[..., 0]) == 0).all()
    kt = rand(rng, (1, 2, 8, 32), jnp.float32)
    vt = rand(rng, (1, 2, 8, 32), jnp.float32)
    mask = jnp.ones((4, 8), bool)
    out = ops.tree_attention(q, k, v, kt, vt, mask, 0, block_k=32)
    want = ref.tree_attention_ref(q, k, v, kt, vt, mask, 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.tpu_kernel
def test_flash_compiles_for_tpu():
    """Actual TPU lowering (interpret=False) — auto-skipped off-TPU; the
    interpret-mode sweeps above cover the same math everywhere."""
    rng = np.random.default_rng(2)
    q = rand(rng, (1, 4, 8, 128), jnp.float32)
    k = rand(rng, (1, 2, 256, 128), jnp.float32)
    v = rand(rng, (1, 2, 256, 128), jnp.float32)
    o, m, l = flash_attention_lse(q, k, v, 200, block_k=128, interpret=False)
    assert np.isfinite(np.asarray(o)).all()


@pytest.mark.tpu_kernel
def test_tree_block_compiles_for_tpu():
    rng = np.random.default_rng(3)
    q = rand(rng, (1, 4, 8, 128), jnp.float32)
    kt = rand(rng, (1, 2, 16, 128), jnp.float32)
    vt = rand(rng, (1, 2, 16, 128), jnp.float32)
    mask = jnp.ones((8, 16), bool)
    o, m, l = tree_block_attention(q, kt, vt, mask, interpret=False)
    assert np.isfinite(np.asarray(o)).all()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 11])
@pytest.mark.parametrize("b,h,kv,s,hd", [(1, 4, 2, 96, 32), (2, 2, 1, 64, 64)])
def test_prefill_causal_flash_sweep(b, h, kv, s, hd, window, dtype):
    rng = np.random.default_rng(hash((b, s, window)) % 2**31)
    q = rand(rng, (b, h, s, hd), dtype)
    k = rand(rng, (b, kv, s, hd), dtype)
    v = rand(rng, (b, kv, s, hd), dtype)
    pos = jnp.arange(s)
    got = ops.prefill_attention(q, k, v, pos, window=window, block_k=32,
                                block_q=16)
    rep = h // kv
    kr = jnp.repeat(k.astype(jnp.float32), rep, 1)
    vr = jnp.repeat(v.astype(jnp.float32), rep, 1)
    lg = jnp.einsum("bhqd,bhsd->bhqs", q.astype(jnp.float32), kr) / np.sqrt(hd)
    m = pos[None, :] <= pos[:, None]
    if window:
        m &= pos[None, :] > pos[:, None] - window
    lg = jnp.where(m[None, None], lg, -jnp.inf)
    want = jnp.einsum("bhqs,bhsd->bhqd", jax.nn.softmax(lg, -1), vr)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), **TOL[dtype])
