"""Property tests for the dynamic prediction tree (paper §3.3) against a
pure-Python reference implementation."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import tree as T


# --------------------------------------------------------------------------
# python reference tree
# --------------------------------------------------------------------------
class PyTree:
    def __init__(self, root_token):
        self.tokens = [root_token]
        self.logprob = [0.0]
        self.parent = [-1]
        self.depth = [0]
        self.layer = [0]  # node indices of deepest layer

    def expand(self, cands, w):
        """cands: list over deepest-layer nodes of [(token, logp), ...]."""
        scored = []
        for slot, node in enumerate(self.layer):
            for tok, lp in cands[slot]:
                scored.append((self.logprob[node] + lp, tok, node))
        scored.sort(key=lambda x: (-x[0]))
        take = scored[: w]
        new_layer = []
        for lp, tok, parent in take:
            if lp <= -1e29:
                continue
            self.tokens.append(tok)
            self.logprob.append(lp)
            self.parent.append(parent)
            self.depth.append(self.depth[parent] + 1)
            new_layer.append(len(self.tokens) - 1)
        self.layer = new_layer

    def ancestors(self, i):
        out = set()
        while i >= 0:
            out.add(i)
            i = self.parent[i]
        return out

    def subtree(self, r):
        return {i for i in range(len(self.tokens))
                if r in self.ancestors(i)}


def np_tree(tree):
    n = int(tree.n_nodes)
    return (np.asarray(tree.tokens)[:n], np.asarray(tree.logprob)[:n],
            np.asarray(tree.parent)[:n], np.asarray(tree.depth)[:n],
            np.asarray(tree.mask)[:n, :n])


cand_strategy = st.lists(
    st.tuples(st.integers(0, 30),
              st.floats(-5, 0, allow_nan=False)),
    min_size=1, max_size=4)


@settings(max_examples=30, deadline=None)
@given(layers=st.lists(st.lists(cand_strategy, min_size=4, max_size=4),
                       min_size=1, max_size=4),
       w=st.integers(2, 4))
def test_expand_matches_reference(layers, w):
    cap = 1 + w * (len(layers) + 1)
    jt = T.tree_init(cap, 7)
    pt = PyTree(7)
    c = 4
    for layer_cands in layers:
        # build [w, c] candidate arrays aligned with the deepest layer
        ct = np.zeros((w, c), np.int32)
        cp = np.full((w, c), float(T.NEG_INF), np.float32)
        # dedupe tokens per parent (top-k of a distribution has distinct ids)
        for slot in range(min(w, len(pt.layer))):
            seen = {}
            for tok, lp in layer_cands[slot % len(layer_cands)]:
                if tok not in seen or lp > seen[tok]:
                    seen[tok] = lp
            for j, (tok, lp) in enumerate(sorted(seen.items())[:c]):
                ct[slot, j] = tok
                cp[slot, j] = lp
        jt = T.tree_expand(jt, jnp.asarray(ct), jnp.asarray(cp), w)
        py_c = [[(int(ct[s, j]), float(cp[s, j])) for j in range(c)
                 if cp[s, j] > -1e29] for s in range(w)]
        pt.expand(py_c, w)

        tok, lp, par, dep, mask = np_tree(jt)
        assert len(tok) == len(pt.tokens)
        # same multiset of (token, parent-token, logprob) per layer
        def key(tokens, parents, lps, deps, toks_all):
            return sorted((int(deps[i]), int(tokens[i]),
                           round(float(lps[i]), 4)) for i in range(len(tokens)))
        assert key(tok, par, lp, dep, tok) == \
            key(np.array(pt.tokens), np.array(pt.parent),
                np.array(pt.logprob), np.array(pt.depth), None)
        # mask == ancestor-or-self closure of parent pointers
        for i in range(len(tok)):
            anc = {i}
            j = int(par[i])
            while j >= 0:
                anc.add(j)
                j = int(par[j])
            assert set(np.nonzero(mask[i])[0].tolist()) == anc


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), w=st.integers(2, 5),
       depth=st.integers(1, 4))
def test_prune_keeps_exact_subtree(seed, w, depth):
    rng = np.random.default_rng(seed)
    cap = 1 + w * (depth + 1)
    jt = T.tree_init(cap, 1)
    c = 3
    for _ in range(depth):
        ct = rng.integers(0, 50, size=(w, c)).astype(np.int32)
        cp = -rng.random((w, c)).astype(np.float32)
        ls = int(jt.layer_size)
        cp[ls:] = float(T.NEG_INF)
        jt = T.tree_expand(jt, jnp.asarray(ct), jnp.asarray(cp), w)

    tok, lp, par, dep, mask = np_tree(jt)
    children = [i for i in range(len(tok)) if par[i] == 0]
    if not children:
        return
    child = children[rng.integers(len(children))]
    keep = {i for i in range(len(tok)) if mask[i, child]}

    pruned, index_map = T.tree_prune_to_child(jt, child)
    imap = np.asarray(index_map)
    ptok, plp, ppar, pdep, pmask = np_tree(pruned)

    assert int(pruned.n_nodes) == len(keep)
    # index_map covers exactly the kept set, order-preserving
    kept_sorted = sorted(keep)
    for new_i, old_i in enumerate(kept_sorted):
        assert imap[old_i] == new_i
        assert ptok[new_i] == tok[old_i]
        assert pdep[new_i] == dep[old_i] - 1
        np.testing.assert_allclose(plp[new_i], lp[old_i] - lp[child],
                                   rtol=1e-5, atol=1e-5)
    dropped = set(range(len(tok))) - keep
    assert all(imap[i] == -1 for i in dropped)
    # new root
    assert ppar[0] == -1 and pdep[0] == 0
    # mask consistency after prune
    for i in range(len(keep)):
        anc = {i}
        j = int(ppar[i])
        while j >= 0:
            anc.add(j)
            j = int(ppar[j])
        assert set(np.nonzero(pmask[i])[0].tolist()) == anc


def test_find_child_and_init():
    jt = T.tree_init(16, 5)
    assert int(jt.n_nodes) == 1
    ct = jnp.asarray([[9, 11, 13]], jnp.int32)
    cp = jnp.log(jnp.asarray([[0.5, 0.3, 0.2]]))
    jt = T.tree_expand(jt, ct, cp, 1)  # w=1 keeps only best child
    assert int(jt.layer_size) == 1
    assert int(T.find_child_with_token(jt, 9)) == 1
    assert int(T.find_child_with_token(jt, 11)) == -1  # pruned by w


def test_capacity_overflow_drops_lowest():
    jt = T.tree_init(4, 0)  # room for 3 more nodes
    ct = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    cp = jnp.log(jnp.asarray([[0.4, 0.3, 0.2, 0.1]]))
    jt = T.tree_expand(jt, ct, cp, 4)
    assert int(jt.n_nodes) == 4  # capped at capacity
    toks = np.asarray(jt.tokens)[1:4]
    assert set(toks.tolist()) == {1, 2, 3}  # lowest-prob candidate dropped
