"""Priority/deadline-aware admission in DynamicBatchScheduler.

Priorities reorder admission on a saturated arena, but must never
deadlock or starve: the aging bound guarantees a queued request's
effective priority eventually outranks any bounded-priority fresh
traffic, and all-default-priority traffic stays exact FIFO (the
equivalence tests elsewhere depend on that)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pipedec import PipeDecConfig, PipeDecEngine
from repro.core.speculative import ModelBundle
from repro.models import transformer as tf
from repro.serving import (DynamicBatchScheduler, PagedKVArena, Request,
                           SlotPool, SpecPipeDBEngine)

PCFG = PipeDecConfig(n_stages=3, width=4, branch=2)


def _req(uid, arrival=0, priority=0, deadline=None):
    return Request(uid, np.asarray([1, 2, 3], np.int32), 4,
                   arrival_t=arrival, priority=priority,
                   deadline_t=deadline)


def test_priority_reorders_admission():
    """With one free slot, the high-priority late submission is admitted
    before earlier-submitted default-priority requests."""
    sched = DynamicBatchScheduler(SlotPool(1))
    sched.submit(_req(0))
    sched.submit(_req(1))
    sched.submit(_req(2, priority=5))
    admitted = sched.admit(now=0)
    assert [r.uid for r, _ in admitted] == [2]


def test_equal_priorities_are_exact_fifo():
    sched = DynamicBatchScheduler(SlotPool(4))
    for uid in (3, 1, 2, 0):
        sched.submit(_req(uid))
    admitted = sched.admit(now=0)
    assert [r.uid for r, _ in admitted] == [3, 1, 2, 0]


def test_not_yet_arrived_requests_wait():
    sched = DynamicBatchScheduler(SlotPool(2))
    sched.submit(_req(0, arrival=5, priority=9))
    sched.submit(_req(1, arrival=0))
    assert [r.uid for r, _ in sched.admit(now=0)] == [1]
    assert [r.uid for r, _ in sched.admit(now=5)] == [0]


def test_aging_bounds_starvation():
    """A default-priority request outranks fresher priority-p traffic
    after waiting aging*p timesteps — admission delay is bounded no
    matter how much high-priority work keeps arriving."""
    sched = DynamicBatchScheduler(SlotPool(1), aging=4)
    old = _req(0, arrival=0, priority=0)
    sched.submit(old)
    # fresh priority-1 stream: at now < aging the fresh request wins ...
    sched.submit(_req(1, arrival=2, priority=1))
    pool_req = sched.admit(now=2)
    assert [r.uid for r, _ in pool_req] == [1]
    sched.arena.free(pool_req[0][1])
    # ... but once `old` has waited aging*1 timesteps it ties priority 1
    # and wins on submission order
    sched.submit(_req(2, arrival=4, priority=1))
    assert [r.uid for r, _ in sched.admit(now=4)] == [0]


def test_equal_priority_aging_prefers_longer_waiting():
    """Among equal priorities, a request that has already waited ``aging``
    timesteps longer overtakes an earlier-submitted later arrival
    (FIFO-by-wait, not FIFO-by-submission, when submissions arrive out of
    arrival order — the documented aging semantics)."""
    sched = DynamicBatchScheduler(SlotPool(1), aging=8)
    sched.submit(_req(0, arrival=8))   # submitted first, arrives at 8
    sched.submit(_req(1, arrival=0))   # submitted second, waiting since 0
    assert [r.uid for r, _ in sched.admit(now=8)] == [1]


def test_resubmitting_same_request_object_is_safe():
    """Submission order is carried per entry, not keyed on object
    identity — duplicated traffic (same Request object twice) admits
    twice in FIFO order instead of corrupting the queue."""
    sched = DynamicBatchScheduler(SlotPool(2))
    r = _req(0)
    sched.submit(r)
    sched.submit(r)
    admitted = sched.admit(now=0)
    assert [x.uid for x, _ in admitted] == [0, 0]
    assert sched.pending == 0


def test_deadline_window_boosts_admission():
    """A deadline inside the aging window lifts an otherwise-equal
    request over earlier-submitted traffic; far deadlines don't."""
    sched = DynamicBatchScheduler(SlotPool(1), aging=8)
    sched.submit(_req(0))
    sched.submit(_req(1, deadline=100))             # far: no boost
    sched.submit(_req(2, deadline=4))               # inside aging window
    assert sched.effective_priority(sched.queue[2], now=0) == 1
    assert sched.effective_priority(sched.queue[1], now=0) == 0
    assert [r.uid for r, _ in sched.admit(now=0)] == [2]


@pytest.fixture(scope="module")
def bundles(tiny_dense, tiny_draft):
    tp = tf.init_model(jax.random.PRNGKey(0), tiny_dense)
    dp = tf.init_model(jax.random.PRNGKey(9), tiny_draft)
    return ModelBundle(tp, tiny_dense), ModelBundle(dp, tiny_draft)


# -- paged-arena preemption: swap-to-host + admission under page pressure --

def _paged_arena(bundles, **kw):
    """Tight paged arena: page=8, 32 model rows / 12 tree rows per slot.
    With model_blocks=3, tree_blocks=2 exactly ONE default request
    (horizon 3+4+12=19 -> 3 model blocks, full 12-row tree -> 2 blocks)
    fits at a time, regardless of free slots — page pressure, not slot
    pressure."""
    target, draft = bundles
    kw.setdefault("slots", 2)
    return PagedKVArena(target, draft, max_len=32, tree_capacity=12,
                        page=8, **kw)


def _fill(rows, seed):
    def leaf(x):
        v = jnp.arange(x.size, dtype=jnp.float32) % 7 + seed
        return v.reshape(x.shape).astype(x.dtype)
    return jax.tree.map(leaf, rows)


def test_swap_out_swap_in_resume_bit_identical(bundles):
    """Swap a slot's KV image to host, let ANOTHER request take its
    physical blocks, swap it back in (different block ids) — the dense
    row view the attention path reads must be bit-identical.  The table
    indirection makes the physical relocation invisible."""
    arena = _paged_arena(bundles, model_blocks=3, tree_blocks=2)
    r0 = _req(0)
    assert arena.fits(r0)
    s0 = arena.alloc()
    arena.bind(s0, r0)
    arena.store(s0, _fill(arena.caches(s0), seed=3))
    before = jax.tree.map(np.asarray, arena.caches(s0))

    blocks_before = arena.pages.model.in_use + arena.pages.tree.in_use
    arena.swap_out(s0)
    assert arena.pages.swaps == 1
    assert arena.pages.model.in_use + arena.pages.tree.in_use == 0, \
        "swap-out must release every physical block"

    # a second occupant claims the freed blocks and scribbles over them
    r1 = _req(1)
    assert arena.fits(r1)
    s1 = arena.alloc()
    arena.bind(s1, r1)
    arena.store(s1, _fill(arena.caches(s1), seed=11))
    assert not arena.swap_in(s0), "pool exhausted: swap-in must refuse"

    arena.free(s1)
    assert arena.swap_in(s0)
    assert arena.pages.model.in_use + arena.pages.tree.in_use == \
        blocks_before
    after = jax.tree.map(np.asarray, arena.caches(s0))
    jax.tree.map(np.testing.assert_array_equal, before, after)


def test_admission_preempts_lru_parked_slot(bundles):
    """When a request's page horizon does not fit, admission evicts the
    least-recently-touched *parked* slot (LRU swap-to-host) to make room
    — busy slots are never preempted."""
    arena = _paged_arena(bundles, slots=3, model_blocks=6, tree_blocks=4)
    sched = DynamicBatchScheduler(arena)
    sched.submit(_req(0))
    sched.submit(_req(1))
    admitted = sched.admit(now=0)
    assert [r.uid for r, _ in admitted] == [0, 1]
    slots = {r.uid: s for r, s in admitted}
    arena.park(slots[0])
    arena.park(slots[1])
    arena.touch(slots[0])          # slot of uid 1 is now the LRU victim

    sched.submit(_req(2))
    admitted = sched.admit(now=1)
    assert [r.uid for r, _ in admitted] == [2]
    assert arena.pages.preemptions == 1
    assert slots[1] in arena._swapped, "LRU parked slot must be the victim"
    assert slots[0] not in arena._swapped


def test_aging_bounds_starvation_under_page_pressure(bundles):
    """The anti-starvation bound must hold when the bottleneck is pages,
    not slots: a default-priority request that could not fit is requeued
    with its submission seq, keeps aging, and once its effective priority
    ties fresher priority-1 traffic it wins on submission order."""
    arena = _paged_arena(bundles, model_blocks=3, tree_blocks=2)
    sched = DynamicBatchScheduler(arena, aging=4)
    sched.submit(_req(0))
    pool_req = sched.admit(now=0)
    assert [r.uid for r, _ in pool_req] == [0]

    # free slots remain, but no pages: the queued request is NOT admitted
    sched.submit(_req(1, arrival=0))
    assert sched.admit(now=1) == []
    assert sched.pending == 1, "unfittable request must be requeued"

    # uid 0 retires; a fresh priority-1 request contends at now=4 — by
    # then uid 1 has waited aging*1 timesteps and ties, winning FIFO
    sched.retire(0, pool_req[0][1], now=3)
    sched.submit(_req(2, arrival=4, priority=1))
    assert [r.uid for r, _ in sched.admit(now=4)] == [1]


def test_priorities_never_deadlock_or_starve_in_engine(bundles):
    """Saturated arena (1 slot, mixed priorities): every request
    completes, outputs still bit-match the single-request engine, and
    queue delay respects the no-starvation bound."""
    target, draft = bundles
    reqs = [Request(i,
                    np.asarray([7 + i, 3, 2 * i + 1], np.int32), 3,
                    arrival_t=0, priority=[0, 3, 1, 3][i])
            for i in range(4)]
    single = PipeDecEngine(target, draft, PCFG, max_len=64)
    want = {r.uid: single.generate(r.prompt, r.max_new_tokens)[0]
            for r in reqs}

    eng = SpecPipeDBEngine(target, draft, PCFG, max_len=64, max_slots=1)
    for r in reqs:
        eng.submit(r)
    res = eng.run()

    assert set(res) == {r.uid for r in reqs}, "nobody starves"
    for uid, tokens in want.items():
        np.testing.assert_array_equal(res[uid].tokens, tokens)
    ss = eng.sched.stats
    # high-priority uids admitted before the default-priority uid 0
    assert ss.admitted_t[1] < ss.admitted_t[0]
    assert ss.admitted_t[3] < ss.admitted_t[0]
    bound = sum(q.max_new_tokens * (PCFG.n_stages + 2) + 17 for q in reqs)
    for r in reqs:
        assert ss.queue_delay(r.uid) <= bound
    assert eng.arena.n_used == 0
