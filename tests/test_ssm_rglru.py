"""SSD (Mamba-2) and RG-LRU recurrence tests: chunked/assoc-scan forms
against naive sequential recurrences, and decode-step equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import rglru as R
from repro.models import ssm as S
from repro.models.config import ModelConfig, RGLRUConfig, SSMConfig


def ssm_cfg(chunk=8):
    return ModelConfig(name="t", family="ssm", num_layers=1, d_model=32,
                       num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=64,
                       ssm=SSMConfig(d_state=8, head_dim=16, chunk=chunk))


def naive_ssd(x, dt, A, B, C, D):
    """Sequential reference of the SSD recurrence."""
    b, t, h, hd = x.shape
    n = B.shape[-1]
    state = np.zeros((b, h, hd, n), np.float32)
    ys = np.zeros_like(np.asarray(x))
    for i in range(t):
        decay = np.exp(np.asarray(dt[:, i]) * np.asarray(A))  # [b,h]
        inject = np.einsum("bh,bhd,bn->bhdn", np.asarray(dt[:, i]),
                           np.asarray(x[:, i]), np.asarray(B[:, i]))
        state = state * decay[:, :, None, None] + inject
        ys[:, i] = np.einsum("bhdn,bn->bhd", state, np.asarray(C[:, i])) \
            + np.asarray(x[:, i]) * np.asarray(D)[None, :, None]
    return ys, state


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500), t=st.sampled_from([8, 12, 24]))
def test_chunked_ssd_matches_naive(seed, t):
    rng = np.random.default_rng(seed)
    b, h, hd, n, q = 2, 3, 4, 5, 8
    x = jnp.asarray(rng.normal(size=(b, t, h, hd)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 1.0, size=(b, t, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, t, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, t, n)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(h,)), jnp.float32)
    pad = (-t) % q
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Bp = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
    Cp = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    y, final = S.ssd_chunked(xp, dtp, A, Bp, Cp, D, chunk=q)
    ref_y, ref_state = naive_ssd(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y[:, :t]), ref_y, rtol=2e-4,
                               atol=2e-4)
    if pad == 0:
        np.testing.assert_allclose(np.asarray(final), ref_state, rtol=2e-4,
                                   atol=2e-4)


def test_ssm_decode_continues_prefill():
    cfg = ssm_cfg()
    params = S.init_ssm(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 17, 32))
    full, _ = S.ssm_forward(params, cfg, x)
    pre, state = S.ssm_forward(params, cfg, x[:, :16])
    y, state = S.ssm_decode(params, cfg, x[:, 16:17], state)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, :16]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(full[:, 16:17]),
                               rtol=2e-3, atol=2e-3)


def hy_cfg():
    return ModelConfig(name="t", family="hybrid", num_layers=3, d_model=32,
                       num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=64,
                       rglru=RGLRUConfig(lru_width=32, window=4,
                                         pattern="rra"))


def test_rglru_scan_matches_naive():
    rng = np.random.default_rng(0)
    b, t, w = 2, 11, 8
    log_a = jnp.asarray(-rng.uniform(0.01, 1.0, size=(b, t, w)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(b, t, w)), jnp.float32)
    h = R.rglru_scan(log_a, u)
    ref = np.zeros((b, w), np.float32)
    for i in range(t):
        ref = np.exp(np.asarray(log_a[:, i])) * ref + np.asarray(u[:, i])
        np.testing.assert_allclose(np.asarray(h[:, i]), ref, rtol=2e-4,
                                   atol=2e-4)


def test_rglru_decode_continues_forward():
    cfg = hy_cfg()
    params = R.init_rglru(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, 32))
    full, _ = R.rglru_forward(params, cfg, x)
    pre, state = R.rglru_forward(params, cfg, x[:, :8])
    y, _ = R.rglru_decode(params, cfg, x[:, 8:9], state)
    np.testing.assert_allclose(np.asarray(y), np.asarray(full[:, 8:9]),
                               rtol=2e-3, atol=2e-3)


def test_rglru_state_decays():
    """RG-LRU gate: with saturated recurrence gate (r→1), |a| < 1 so the
    state contracts — no blowup over long sequences."""
    cfg = hy_cfg()
    params = R.init_rglru(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 32)) * 5.0
    out, state = R.rglru_forward(params, cfg, x)
    assert np.isfinite(np.asarray(out)).all()
    assert np.isfinite(np.asarray(state["h"])).all()
