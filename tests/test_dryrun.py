"""Dry-run smoke: one small (arch × shape × production-mesh) combination
lowers and compiles in a subprocess (512 fake devices must not leak into
this test process)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch,shape", [("whisper-base", "decode_32k")])
def test_dryrun_subprocess(arch, shape, tmp_path):
    out = tmp_path / "rows.jsonl"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--out", str(out)],
        capture_output=True, text=True, timeout=1200, env=env, cwd=REPO)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    rows = [json.loads(l) for l in out.read_text().splitlines()]
    assert len(rows) == 1
    r = rows[0]
    assert r["arch"] == arch and r["shape"] == shape
    assert r["chips"] == 256 and r["mesh"] == "16x16"
    assert r["hlo_flops"] > 0 and r["hlo_bytes"] > 0
    assert r["bottleneck"] in ("compute", "memory", "collective")


def test_devices_not_polluted():
    import jax
    assert len(jax.devices()) == 1, \
        "test process must never see the dry-run's 512 fake devices"
