"""Per-architecture smoke tests (deliverable f): instantiate the REDUCED
same-family variant of each assigned arch and run one forward + one train
step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as reg
from repro.launch.steps import make_train_step
from repro.models import frontends
from repro.models import transformer as tf
from repro.models.encdec import encode
from repro.optim import AdamWConfig, adamw_init


@pytest.mark.parametrize("arch", reg.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = reg.get_config(arch, smoke=True)
    assert cfg.num_layers <= 5 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    params = tf.init_model(jax.random.PRNGKey(0), cfg)

    B, S = 2, 16
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    batch = {"tokens": tokens, "labels": labels}
    fw = {}
    if cfg.family == "vlm":
        pe = frontends.stub_vision_prefix(cfg, B)
        batch["prefix_embeds"] = pe
        fw["prefix_embeds"] = pe
    enc_out = None
    if cfg.is_encdec:
        frames = frontends.stub_audio_frames(cfg, B)
        batch["frames"] = frames
        enc_out = encode(params["encoder"], cfg, frames)
        fw["enc_out"] = enc_out

    # forward: shape + finite
    logits, aux = tf.forward(params, cfg, tokens, **fw)
    exp_s = S + (cfg.prefix_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"NaN in {arch} forward"

    # one train step: loss finite, params updated
    step = make_train_step(cfg, AdamWConfig(lr=1e-3), remat=False)
    opt = adamw_init(params)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), f"NaN loss in {arch}"
    assert int(new_opt["step"]) == 1
    # at least one leaf changed
    changed = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, new_params))
    assert changed


@pytest.mark.parametrize("arch", reg.ARCH_IDS)
def test_smoke_decode_step(arch):
    """serve_step on the reduced config: one token, KV cache, finite."""
    cfg = reg.get_config(arch, smoke=True)
    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    enc_out = None
    fw = {}
    if cfg.is_encdec:
        frames = frontends.stub_audio_frames(cfg, B)
        enc_out = encode(params["encoder"], cfg, frames)
        fw["enc_out"] = enc_out
    pe = frontends.stub_vision_prefix(cfg, B) if cfg.family == "vlm" else None

    cache = tf.init_cache(cfg, B, 32)
    logits, cache = tf.prefill(params, cfg, tokens, cache, prefix_embeds=pe,
                               **fw)
    off = cfg.prefix_tokens if cfg.family == "vlm" else 0
    tok = jnp.argmax(logits, -1)
    logits2, cache = tf.decode_step(params, cfg, tok, cache, off + S, **fw)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all(), f"NaN in {arch} decode"


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    import dataclasses
    expect = {
        "moonshot_v1_16b_a3b": (48, 2048, 16, 16, 163840),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, 151936),
        "whisper_base": (6, 512, 8, 8, 51865),
        "gemma_7b": (28, 3072, 16, 16, 256000),
        "internvl2_26b": (48, 6144, 48, 8, 92553),
        "mamba2_130m": (24, 768, 1, 1, 50280),
        "qwen2_5_32b": (64, 5120, 40, 8, 152064),
        "recurrentgemma_9b": (38, 4096, 16, 1, 256000),
        "qwen1_5_32b": (64, 5120, 40, 40, 152064),
        "deepseek_v2_236b": (60, 5120, 128, 128, 102400),
    }
    for arch, (L, d, h, kv, v) in expect.items():
        cfg = reg.get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads,
                cfg.num_kv_heads, cfg.vocab_size) == (L, d, h, kv, v), arch
    # family-specific structure
    assert reg.get_config("moonshot_v1_16b_a3b").moe.num_experts == 64
    assert reg.get_config("moonshot_v1_16b_a3b").moe.experts_per_token == 6
    assert reg.get_config("qwen2_moe_a2_7b").moe.num_experts == 60
    assert reg.get_config("qwen2_moe_a2_7b").moe.experts_per_token == 4
    assert reg.get_config("deepseek_v2_236b").mla.kv_lora_rank == 512
    assert reg.get_config("deepseek_v2_236b").moe.num_experts == 160
    assert reg.get_config("mamba2_130m").ssm.d_state == 128
    assert reg.get_config("gemma_7b").head_dim == 256
    assert reg.get_config("recurrentgemma_9b").rglru.pattern == "rra"
    assert reg.get_config("whisper_base").encoder.num_layers == 6
    assert reg.get_config("internvl2_26b").prefix_tokens == 256
