"""SpecPipe-DB dynamic-batching engine tests.

Equivalence strategy (see tests/README.md): the DB engine multiplexes
unchanged per-request ``PipeDecEngine`` state machines through one shared
schedule, so every request's greedy output must BIT-MATCH running it alone
— across slot contention, staggered arrivals, and KV-arena recycling.  The
scheduler invariants (no starvation, no double-allocated slot, every
submitted uid in results) are asserted against the scheduler's lifecycle
stats under churn.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import tree as tree_lib
from repro.core.dynbatch import TreeBatch
from repro.core.pipedec import PipeDecConfig, PipeDecEngine
from repro.core.speculative import (ModelBundle, SamplingParams,
                                    draft_candidates)
from repro.models import transformer as tf
from repro.serving import KVArena, Request, ServingEngine, SpecPipeDBEngine

PCFG = PipeDecConfig(n_stages=3, width=4, branch=2)
MAX_LEN = 128


@pytest.fixture(scope="module")
def bundles(tiny_dense, tiny_draft):
    tp = tf.init_model(jax.random.PRNGKey(0), tiny_dense)
    dp = tf.init_model(jax.random.PRNGKey(9), tiny_draft)
    return ModelBundle(tp, tiny_dense), ModelBundle(dp, tiny_draft)


def _single_outputs(bundles, reqs):
    target, draft = bundles
    eng = PipeDecEngine(target, draft, PCFG, max_len=MAX_LEN)
    return {r.uid: eng.generate(r.prompt, r.max_new_tokens)[0] for r in reqs}


def _mk_reqs(seed, n, arrivals=None, max_new=None):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        prompt = rng.integers(0, 100, size=int(rng.integers(3, 8)))
        reqs.append(Request(
            i, prompt.astype(np.int32),
            int(max_new[i]) if max_new else int(rng.integers(3, 7)),
            arrival_t=int(arrivals[i]) if arrivals else 0))
    return reqs


# --------------------------------------------------------------------------
# (a) greedy-mode equivalence
# --------------------------------------------------------------------------
def test_db_greedy_bitmatches_single_request(bundles):
    """More requests than slots: queueing + slot recycling must not change
    a single token of any request's output."""
    target, draft = bundles
    reqs = _mk_reqs(0, 4)
    want = _single_outputs(bundles, reqs)
    eng = SpecPipeDBEngine(target, draft, PCFG, max_len=MAX_LEN, max_slots=2)
    for r in reqs:
        eng.submit(r)
    res = eng.run()
    assert set(res) == set(want)
    for uid, tokens in want.items():
        np.testing.assert_array_equal(res[uid].tokens, tokens)
        assert len(res[uid].tokens) == \
            next(r for r in reqs if r.uid == uid).max_new_tokens + 1


def test_db_via_serving_engine_facade(bundles):
    target, draft = bundles
    reqs = _mk_reqs(1, 3)
    want = _single_outputs(bundles, reqs)
    se = ServingEngine(target, draft, mode="pipedec-db", max_batch=2,
                       max_len=MAX_LEN, pipedec=PCFG)
    for r in reqs:
        se.submit(r)
    res = se.run()
    for uid, tokens in want.items():
        np.testing.assert_array_equal(res[uid].tokens, tokens)
    assert se.db_stats.total_commits >= sum(r.max_new_tokens for r in reqs)


# --------------------------------------------------------------------------
# (b) scheduler invariants under churn
# --------------------------------------------------------------------------
def test_db_staggered_arrivals_all_complete(bundles):
    """≥4 requests with staggered arrivals and mixed token budgets on 2
    slots: nobody starves, occupancy never exceeds the slot count, and the
    arena fully drains."""
    target, draft = bundles
    reqs = _mk_reqs(2, 5, arrivals=[0, 2, 5, 9, 11], max_new=[4, 6, 3, 5, 4])
    want = _single_outputs(bundles, reqs)
    eng = SpecPipeDBEngine(target, draft, PCFG, max_len=MAX_LEN, max_slots=2)
    for r in reqs:
        eng.submit(r)
    res = eng.run()

    assert set(res) == {r.uid for r in reqs}, "every submitted uid completes"
    for uid, tokens in want.items():
        np.testing.assert_array_equal(res[uid].tokens, tokens)

    ss = eng.sched.stats
    for r in reqs:
        assert ss.admitted_t[r.uid] >= r.arrival_t, "admission after arrival"
        assert ss.finished_t[r.uid] > ss.admitted_t[r.uid]
        # no starvation: bounded queueing delay (predecessors hold a slot
        # for at most their own decode length)
        assert ss.queue_delay(r.uid) <= sum(
            q.max_new_tokens * (PCFG.n_stages + 2) + 17 for q in reqs)
    assert max(ss.occupancy) <= 2
    assert eng.arena.n_used == 0 and eng.arena.n_free == 2
    assert eng.stats.peak_occupancy == 2, "slots actually shared"


def test_kv_arena_no_double_allocation(bundles):
    target, draft = bundles
    arena = KVArena(target, draft, slots=2, max_len=64, tree_capacity=16)
    a = arena.alloc()
    b = arena.alloc()
    assert a != b
    with pytest.raises(RuntimeError, match="exhausted"):
        arena.alloc()
    with pytest.raises(RuntimeError, match="not in use"):
        arena.free(7)
    arena.free(a)
    assert arena.alloc() == a  # slot recycled, caches preserved
    c1 = arena.caches(a)
    assert c1 is not None and len(c1) == 4
    arena.free(a)
    arena.free(b)
    assert arena.n_free == 2 and arena.n_used == 0


# --------------------------------------------------------------------------
# batched tree store (core/dynbatch.py) vs tree_lib on standalone trees
# --------------------------------------------------------------------------
def test_treebatch_rows_match_tree_lib():
    w, c, cap = 3, 2, 13
    tb = TreeBatch(slots=2, capacity=cap)
    ref = [tree_lib.tree_init(cap, 5), tree_lib.tree_init(cap, 9)]
    tb.init_row(0, 5)
    tb.init_row(1, 9)

    rng = np.random.default_rng(0)
    for step in range(3):
        for slot in range(2):
            logits = jnp.asarray(rng.normal(size=(w, 32)), jnp.float32)
            valid = jnp.asarray([True] * min(w, step + 1) +
                                [False] * (w - min(w, step + 1)))
            tok, lp = draft_candidates(logits, valid, c)
            ref[slot] = tree_lib.tree_expand(ref[slot], tok, lp, w)
            tb.expand_row(slot, tok, lp, w)
    for slot in range(2):
        got = tb.get_row(slot)
        for name in tree_lib.Tree._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(got, name)),
                np.asarray(getattr(ref[slot], name)), err_msg=name)

    # prune one row; the other must be untouched
    child = int(np.asarray(tree_lib.root_argmax_child(ref[0])))
    ref0, imap_ref = tree_lib.tree_prune_to_child(ref[0], child)
    got0, imap_got = tb.prune_row(0, child)
    np.testing.assert_array_equal(np.asarray(imap_got), np.asarray(imap_ref))
    np.testing.assert_array_equal(np.asarray(tb.get_row(0).tokens),
                                  np.asarray(ref0.tokens))
    np.testing.assert_array_equal(np.asarray(tb.get_row(1).tokens),
                                  np.asarray(ref[1].tokens))

    # stacked deepest-layer view == per-row last_layer
    toks_b, idx_b, valid_b, mask_b = tb.deepest_layers(w)
    for slot, t in enumerate([ref0, ref[1]]):
        toks, idx, valid, mask = tree_lib.last_layer(t, w)
        np.testing.assert_array_equal(np.asarray(toks_b[slot]),
                                      np.asarray(toks))
        np.testing.assert_array_equal(np.asarray(valid_b[slot]),
                                      np.asarray(valid))
        np.testing.assert_array_equal(np.asarray(mask_b[slot]),
                                      np.asarray(mask))
    tb.release_row(0)
    assert tb.occupancy() == 1


# --------------------------------------------------------------------------
# (c) fused dispatch: ONE batched tree-verify per model per timestep
# --------------------------------------------------------------------------
def test_db_fused_single_dispatch_per_timestep(bundles):
    """With N active slots, one global timestep issues exactly one target
    and one draft tree-verify dispatch (counted via the ModelBundle.calls
    hook), and every per-request output still bit-matches the
    single-request engine."""
    target, draft = bundles
    reqs = _mk_reqs(7, 4, arrivals=[0, 0, 1, 4], max_new=[5, 4, 6, 3])
    want = _single_outputs(bundles, reqs)

    eng = SpecPipeDBEngine(target, draft, PCFG, max_len=MAX_LEN, max_slots=3)
    for r in reqs:
        eng.submit(r)
    before = {b: dict(b.calls) for b in (target, draft)}
    res = eng.run()

    for uid, tokens in want.items():
        np.testing.assert_array_equal(res[uid].tokens, tokens)
    assert eng.stats.peak_occupancy >= 2, "slots actually shared"

    disp = eng.stats.verify_dispatches
    assert len(disp) == eng.stats.timesteps
    assert max(disp) == 1, "never more than one fused dispatch per timestep"
    for b in (target, draft):
        fused = b.calls["tree_verify_rows"] - \
            before[b].get("tree_verify_rows", 0)
        looped = b.calls["tree_verify"] - before[b].get("tree_verify", 0)
        assert fused == sum(disp), f"{b.cfg.name}: one fused call per " \
            "timestep with pending entries"
        assert looped == 0, f"{b.cfg.name}: no per-slot dispatch in DB mode"


def test_db_fused_bitmatches_looped_and_single(bundles):
    """Fused-vs-looped equivalence under staggered arrivals and slot
    churn: the fused entry bit-matches both the per-slot loop
    (``fused=False``) and the single-request engine, per uid."""
    target, draft = bundles
    reqs = _mk_reqs(8, 5, arrivals=[0, 1, 2, 6, 8], max_new=[4, 5, 3, 6, 4])
    want = _single_outputs(bundles, reqs)

    outs = {}
    for fused in (True, False):
        eng = SpecPipeDBEngine(target, draft, PCFG, max_len=MAX_LEN,
                               max_slots=2, fused=fused)
        for r in reqs:
            eng.submit(r)
        outs[fused] = eng.run()
    for uid, tokens in want.items():
        np.testing.assert_array_equal(outs[True][uid].tokens, tokens,
                                      err_msg=f"fused vs single uid={uid}")
        np.testing.assert_array_equal(outs[False][uid].tokens, tokens,
                                      err_msg=f"looped vs single uid={uid}")


# --------------------------------------------------------------------------
# (c2) per-request sampling: mixed greedy/stochastic batches
# --------------------------------------------------------------------------
def test_mixed_sampling_batch_greedy_bitmatches_single(bundles):
    """A greedy request sharing the batch with stochastic requests still
    bit-matches the single-request engine: SamplingParams live on the
    Request and only shape that request's own token selection."""
    target, draft = bundles
    rng = np.random.default_rng(11)
    greedy = Request(0, rng.integers(0, 100, size=5).astype(np.int32), 5)
    hot = Request(1, rng.integers(0, 100, size=6).astype(np.int32), 5,
                  sampling=SamplingParams(temperature=1.0, top_k=8))
    hot2 = Request(2, rng.integers(0, 100, size=4).astype(np.int32), 4,
                   sampling=SamplingParams(temperature=0.7, top_p=0.9))
    want = _single_outputs(bundles, [greedy])

    eng = SpecPipeDBEngine(target, draft, PCFG, max_len=MAX_LEN,
                           max_slots=3)
    for r in (greedy, hot, hot2):
        eng.submit(r)
    res = eng.run()
    np.testing.assert_array_equal(res[0].tokens, want[0])
    assert len(res[1].tokens) == hot.max_new_tokens + 1
    assert len(res[2].tokens) == hot2.max_new_tokens + 1

    # a stochastic request's trace is reproducible under the same run key
    eng2 = SpecPipeDBEngine(target, draft, PCFG, max_len=MAX_LEN,
                            max_slots=3)
    for r in (greedy, hot, hot2):
        eng2.submit(r)
    res2 = eng2.run()
    for uid in res:
        np.testing.assert_array_equal(res2[uid].tokens, res[uid].tokens)


# --------------------------------------------------------------------------
# (c3) streaming: tokens emitted at commit time
# --------------------------------------------------------------------------
def test_streaming_prefix_equals_final_result(bundles):
    """``run(on_token=...)`` emits every (uid, token, timestep) at commit
    time; the streamed per-uid sequence equals the final Result.tokens,
    the first token lands at the admission timestep, and emission
    timesteps are non-decreasing."""
    target, draft = bundles
    reqs = _mk_reqs(6, 4, arrivals=[0, 1, 3, 7], max_new=[4, 5, 3, 4])
    eng = SpecPipeDBEngine(target, draft, PCFG, max_len=MAX_LEN,
                           max_slots=2)
    for r in reqs:
        eng.submit(r)
    events = []
    res = eng.run(on_token=lambda uid, tok, t: events.append((uid, tok, t)))

    streamed = {r.uid: [] for r in reqs}
    times = {r.uid: [] for r in reqs}
    for uid, tok, t in events:
        streamed[uid].append(tok)
        times[uid].append(t)
    for r in reqs:
        np.testing.assert_array_equal(np.asarray(streamed[r.uid]),
                                      res[r.uid].tokens,
                                      err_msg=f"uid={r.uid}")
        assert times[r.uid] == sorted(times[r.uid])
        assert times[r.uid][0] == eng.sched.stats.admitted_t[r.uid], \
            "prefill token streams at the admission timestep"
        # commits stream strictly before the request's retire bookkeeping
        assert times[r.uid][-1] <= eng.sched.stats.finished_t[r.uid]


# --------------------------------------------------------------------------
# (d) recycled-arena regression: recurrent state must reset at prefill
# --------------------------------------------------------------------------
def test_recycled_slot_matches_fresh_slot_hybrid_ssm(tiny_hybrid_ssm,
                                                     tiny_draft):
    """Hybrid (ssm-layer) config on a recycled KV slot: prefill must seed
    the SSD scan from the zero state, not the previous occupant's final
    recurrent state — fresh-slot and recycled-slot outputs are identical.
    (Failed before the _apply_sublayer ssm full-mode fix.)"""
    target = ModelBundle(tf.init_model(jax.random.PRNGKey(3),
                                       tiny_hybrid_ssm), tiny_hybrid_ssm)
    draft = ModelBundle(tf.init_model(jax.random.PRNGKey(9), tiny_draft),
                        tiny_draft)
    eng = PipeDecEngine(target, draft, PCFG, max_len=MAX_LEN)
    arena = KVArena(target, draft, slots=1, max_len=MAX_LEN,
                    tree_capacity=eng.tree_buffer_capacity)
    p_a = np.array([3, 1, 4, 1, 5, 9, 2], np.int32)
    p_b = np.array([9, 2, 6], np.int32)

    # occupy the slot with request A, then retire it (caches stored back)
    slot = arena.alloc()
    st_a = eng.init_state(p_a, 0, caches=arena.caches(slot))
    arena.store(slot, st_a.caches())
    arena.free(slot)

    # recycled slot for request B vs a fresh-cache reference
    slot2 = arena.alloc()
    assert slot2 == slot
    st_b = eng.init_state(p_b, 0, caches=arena.caches(slot2))
    ref = eng.init_state(p_b, 0)
    assert st_b.committed[0] == ref.committed[0]

    # the prefill logits themselves are bit-identical
    lg_rec, _ = target.prefill(jnp.asarray(p_b, jnp.int32)[None],
                               arena.caches(slot2)[0])
    lg_fresh, _ = target.prefill(jnp.asarray(p_b, jnp.int32)[None],
                                 target.init_cache(1, MAX_LEN))
    np.testing.assert_array_equal(np.asarray(lg_rec), np.asarray(lg_fresh))

    # tree-verify through a recurrent sub-layer has no defined semantics
    # (chain-mode covers recurrent architectures) — it must fail loudly
    # instead of silently decoding garbage
    with pytest.raises(NotImplementedError, match="chain-mode"):
        eng.generate(p_b, 2)


# --------------------------------------------------------------------------
# (e) property test over random arrival orders
# --------------------------------------------------------------------------
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_db_random_arrival_orders_property(bundles, seed):
    target, draft = bundles
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 6))
    reqs = _mk_reqs(seed, n,
                    arrivals=[int(a) for a in rng.integers(0, 8, size=n)],
                    max_new=[int(m) for m in rng.integers(2, 6, size=n)])
    want = _single_outputs(bundles, reqs)
    eng = SpecPipeDBEngine(target, draft, PCFG, max_len=MAX_LEN,
                           max_slots=int(rng.integers(1, 4)))
    for r in reqs:
        eng.submit(r)
    res = eng.run()
    assert set(res) == set(want)
    for uid, tokens in want.items():
        np.testing.assert_array_equal(res[uid].tokens, tokens)
    assert eng.arena.n_used == 0
