import jax
import pytest

from repro.models.config import (EncoderConfig, MLAConfig, ModelConfig,
                                 MoEConfig, RGLRUConfig, SSMConfig)

# CPU tests must see exactly ONE device (the dry-run sets its own 512-device
# flag in its own process) — nothing to configure here on purpose.


@pytest.fixture(scope="session")
def tiny_dense():
    return ModelConfig(name="t-dense", family="dense", num_layers=3,
                       d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                       vocab_size=128)


@pytest.fixture(scope="session")
def tiny_draft():
    return ModelConfig(name="t-draft", family="dense", num_layers=1,
                       d_model=32, num_heads=2, num_kv_heads=1, d_ff=64,
                       vocab_size=128)


@pytest.fixture(scope="session")
def tiny_moe():
    return ModelConfig(
        name="t-moe", family="moe", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=128,
        moe=MoEConfig(num_experts=4, experts_per_token=2, d_ff_expert=64,
                      num_shared_experts=1, first_dense=1,
                      capacity_factor=8.0))


@pytest.fixture(scope="session")
def tiny_mla():
    return ModelConfig(
        name="t-mla", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=128,
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=24, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16))


@pytest.fixture(scope="session")
def tiny_ssm():
    return ModelConfig(
        name="t-ssm", family="ssm", num_layers=2, d_model=64, num_heads=1,
        num_kv_heads=1, d_ff=0, vocab_size=128,
        ssm=SSMConfig(d_state=16, head_dim=16, chunk=8))


@pytest.fixture(scope="session")
def tiny_hybrid():
    return ModelConfig(
        name="t-hyb", family="hybrid", num_layers=5, d_model=64, num_heads=4,
        num_kv_heads=1, d_ff=128, vocab_size=128,
        rglru=RGLRUConfig(lru_width=64, window=8, pattern="rra"))
