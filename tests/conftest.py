import sys
import types

import jax
import pytest

from repro.models.config import (EncoderConfig, MLAConfig, ModelConfig,
                                 MoEConfig, RGLRUConfig, SSMConfig)

# CPU tests must see exactly ONE device (the dry-run sets its own 512-device
# flag in its own process) — nothing to configure here on purpose.


# --------------------------------------------------------------------------
# hypothesis shim: the property-based modules (test_tree, test_speculative,
# test_moe, test_ssm_rglru, test_serving_db, ...) import hypothesis at module
# scope.  When it is not installed (it is a dev extra, see
# requirements-dev.txt), install a stub into sys.modules so those modules
# still COLLECT; every @given test then reports as skipped instead of the
# whole module erroring out.
# --------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Chainable stand-in for hypothesis strategy objects."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    def _given(*gargs, **gkwargs):
        def deco(fn):
            # zero-arg replacement: pytest must not try to resolve the
            # @given-provided parameters as fixtures
            def skipper():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def _settings(*sargs, **skwargs):
        def deco(fn):
            return fn
        return deco

    _stub = types.ModuleType("hypothesis")
    _stub.given = _given
    _stub.settings = _settings
    _stub.strategies = _AnyStrategy()
    _stub.HealthCheck = _AnyStrategy()
    _stub.assume = lambda *a, **k: True
    _st_stub = types.ModuleType("hypothesis.strategies")
    _st_stub.__getattr__ = lambda name: _AnyStrategy()
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _st_stub


# --------------------------------------------------------------------------
# tpu_kernel marker: Pallas tests that LOWER for a real TPU (interpret=False)
# only run where TPU compilation is available; their interpret-mode twins run
# everywhere.
# --------------------------------------------------------------------------
def _tpu_available() -> bool:
    try:
        return any(d.platform == "tpu" for d in jax.devices())
    except RuntimeError:
        return False


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "tpu_kernel: Pallas TPU-lowering test (auto-skipped on hosts "
        "without TPU; interpret-mode variants cover the same math)")


def pytest_collection_modifyitems(config, items):
    if _tpu_available():
        return
    skip_tpu = pytest.mark.skip(
        reason="TPU lowering unavailable on this host (interpret-mode "
               "twins cover the same kernels)")
    for item in items:
        if "tpu_kernel" in item.keywords:
            item.add_marker(skip_tpu)


@pytest.fixture(scope="session")
def tiny_dense():
    return ModelConfig(name="t-dense", family="dense", num_layers=3,
                       d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                       vocab_size=128)


@pytest.fixture(scope="session")
def tiny_draft():
    return ModelConfig(name="t-draft", family="dense", num_layers=1,
                       d_model=32, num_heads=2, num_kv_heads=1, d_ff=64,
                       vocab_size=128)


@pytest.fixture(scope="session")
def tiny_moe():
    return ModelConfig(
        name="t-moe", family="moe", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=128,
        moe=MoEConfig(num_experts=4, experts_per_token=2, d_ff_expert=64,
                      num_shared_experts=1, first_dense=1,
                      capacity_factor=8.0))


@pytest.fixture(scope="session")
def tiny_mla():
    return ModelConfig(
        name="t-mla", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=128,
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=24, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16))


@pytest.fixture(scope="session")
def tiny_ssm():
    return ModelConfig(
        name="t-ssm", family="ssm", num_layers=2, d_model=64, num_heads=1,
        num_kv_heads=1, d_ff=0, vocab_size=128,
        ssm=SSMConfig(d_state=16, head_dim=16, chunk=8))


@pytest.fixture(scope="session")
def tiny_hybrid():
    return ModelConfig(
        name="t-hyb", family="hybrid", num_layers=5, d_model=64, num_heads=4,
        num_kv_heads=1, d_ff=128, vocab_size=128,
        rglru=RGLRUConfig(lru_width=64, window=8, pattern="rra"))


@pytest.fixture(scope="session")
def tiny_hybrid_ssm():
    """Jamba-style attn+ssm hybrid (pattern 's' = Mamba-2 SSD sub-layer):
    the recycled-KV-arena regression config — its SSD prefill must seed
    from the zero state, never a previous slot occupant's."""
    return ModelConfig(
        name="t-hyb-ssm", family="hybrid", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
        ssm=SSMConfig(d_state=16, head_dim=16, chunk=8),
        rglru=RGLRUConfig(pattern="sa", window=0))
