"""Executor-layer tests: SpecPipe-DB on pluggable compute backends.

The logical scheduler must produce bit-identical per-request outputs on
every backend — ``LocalFusedExecutor`` (PR-2's fused single-device path),
``ShardedPipelineExecutor`` (the paper's pipelined deployment, flush
schedule), ``OverlappedShardedExecutor`` (the steady-state schedule: ONE
ring tick per timestep, deferred exit logits, in-ring pruning
propagation), and the single-request ``PipeDecEngine`` — because the
executor seam changes *where and when* the batched verify logits
materialise, never *what* is computed.  The 8-stage acceptance pin runs
in a subprocess (``repro.launch.sharded_check --overlap``) so the forced
host-device count never leaks into this process; the in-process tests use
a 1-stage mesh, which exercises the same ring/psum/stage-masking, ctrl
and kill code paths (in-flight layers *behind* a prune need >1 stage and
are covered by the subprocess pin's pruning-propagation scenario).
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.pipedec import PipeDecConfig, PipeDecEngine
from repro.core.speculative import ModelBundle
from repro.models import transformer as tf
from repro.serving import (AsyncExecutorError, AsyncPipelineExecutor,
                           OverlappedShardedExecutor, Request,
                           ShardedPipelineExecutor, SpecPipeDBEngine,
                           generate_with_executor)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PCFG = PipeDecConfig(n_stages=3, width=4, branch=2)
# the overlapped ring length equals pcfg.n_stages, and in-process tests
# only have a 1-device mesh — multi-stage overlap runs via subprocess
PCFG1 = PipeDecConfig(n_stages=1, width=4, branch=2)
MAX_LEN = 128


@pytest.fixture(scope="module")
def bundles(tiny_dense, tiny_draft):
    tp = tf.init_model(jax.random.PRNGKey(0), tiny_dense)
    dp = tf.init_model(jax.random.PRNGKey(9), tiny_draft)
    return ModelBundle(tp, tiny_dense), ModelBundle(dp, tiny_draft)


def _mk_reqs(seed, n, arrivals=None, max_new=None):
    rng = np.random.default_rng(seed)
    return [Request(i,
                    rng.integers(0, 100, size=int(rng.integers(3, 8)))
                    .astype(np.int32),
                    int(max_new[i]) if max_new else int(rng.integers(3, 7)),
                    arrival_t=int(arrivals[i]) if arrivals else 0)
            for i in range(n)]


def _sharded(bundles, slots, n_stages=1, cls=ShardedPipelineExecutor,
             pcfg=PCFG):
    target, draft = bundles
    return cls(
        target, draft, slots=slots, max_len=MAX_LEN,
        tree_capacity=pcfg.tree_buffer_capacity, capacity=pcfg.capacity,
        n_stages=n_stages)


def _overlapped(bundles, slots):
    return _sharded(bundles, slots, cls=OverlappedShardedExecutor,
                    pcfg=PCFG1)


def _async(bundles, slots, pcfg=PCFG):
    # the async backend round-robins stage actors over the available
    # devices, so a 3-stage actor chain runs fine on the 1-device test
    # process (unlike the lockstep mesh executors)
    return _sharded(bundles, slots, cls=AsyncPipelineExecutor,
                    n_stages=pcfg.n_stages, pcfg=pcfg)


def test_sharded_executor_bitmatches_local_and_single(bundles):
    """Staggered arrivals + slot churn on the sharded backend (1-stage
    mesh): per-uid outputs bit-match the local fused backend and the
    single-request engine."""
    target, draft = bundles
    reqs = _mk_reqs(3, 4, arrivals=[0, 1, 4, 6], max_new=[4, 5, 3, 4])
    single = PipeDecEngine(target, draft, PCFG, max_len=MAX_LEN)
    want = {r.uid: single.generate(r.prompt, r.max_new_tokens)[0]
            for r in reqs}

    outs = {}
    for name, ex in (("local", None), ("sharded", _sharded(bundles, 2))):
        eng = SpecPipeDBEngine(target, draft, PCFG, max_len=MAX_LEN,
                               max_slots=2, executor=ex)
        for r in reqs:
            eng.submit(r)
        outs[name] = eng.run()
    for uid, tokens in want.items():
        np.testing.assert_array_equal(outs["local"][uid].tokens, tokens,
                                      err_msg=f"local vs single uid={uid}")
        np.testing.assert_array_equal(outs["sharded"][uid].tokens, tokens,
                                      err_msg=f"sharded vs single uid={uid}")


def test_sharded_one_batched_tick_per_timestep(bundles):
    """The dispatch-count hook: every global timestep with pending entries
    issues exactly ONE sharded pipeline dispatch (and one local draft
    dispatch) — never one per slot."""
    target, draft = bundles
    reqs = _mk_reqs(4, 3, arrivals=[0, 0, 2], max_new=[4, 3, 4])
    ex = _sharded(bundles, 2)
    eng = SpecPipeDBEngine(target, draft, PCFG, max_len=MAX_LEN,
                           max_slots=2, executor=ex)
    before = {b: dict(b.calls) for b in (target, draft)}
    for r in reqs:
        eng.submit(r)
    eng.run()

    disp = eng.stats.verify_dispatches
    assert len(disp) == eng.stats.timesteps
    assert max(disp) == 1
    assert ex.calls["pipeline_verify"] == sum(disp)
    assert ex.calls["verify_rows"] == sum(disp)
    # draft rides the same fused dispatch cadence, replicated locally
    assert draft.calls["tree_verify_rows"] - \
        before[draft].get("tree_verify_rows", 0) == sum(disp)
    # neither model ever falls back to the per-slot looped dispatch
    for b in (target, draft):
        assert b.calls["tree_verify"] == before[b].get("tree_verify", 0)
    # the target's verify runs through the sharded ring, not its bundle
    assert target.calls["tree_verify_rows"] == \
        before[target].get("tree_verify_rows", 0)
    assert eng.stats.peak_occupancy == 2, "slots actually shared"


def test_generate_with_executor_b1_path(bundles):
    """The B=1 PipeDecEngine path runs against either executor and
    bit-matches the direct single-request engine."""
    target, draft = bundles
    prompt = np.asarray([5, 3, 2, 7, 11], np.int32)
    single = PipeDecEngine(target, draft, PCFG, max_len=MAX_LEN)
    want, want_stats = single.generate(prompt, 6)

    for ex in (None, _sharded(bundles, 1)):
        got, stats = generate_with_executor(target, draft, PCFG, prompt, 6,
                                            executor=ex, max_len=MAX_LEN)
        np.testing.assert_array_equal(got, want)
        assert stats.commits == want_stats.commits
        assert stats.acceptance == want_stats.acceptance


def test_executor_slot_count_must_match(bundles):
    target, draft = bundles
    with pytest.raises(AssertionError, match="slot count"):
        SpecPipeDBEngine(target, draft, PCFG, max_len=MAX_LEN, max_slots=3,
                         executor=_sharded(bundles, 2))


def test_sharded_8stage_acceptance_pin_subprocess():
    """The PR's acceptance pin on a REAL 8-device simulated mesh: flush
    AND overlapped sharded backends == local == single per uid, one
    batched flush dispatch per pending timestep, one ring tick per
    executed timestep, and the tick-level pruning-propagation scenario (a
    slot killed with layers in flight writes nothing further, its stale
    exits come out dead, other slots bit-untouched).  Runs
    ``repro.launch.sharded_check --overlap`` in a subprocess so the
    forced host-device count cannot leak into this test process (same
    pattern as test_dryrun)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.sharded_check", "--stages",
         "8", "--requests", "4", "--overlap", "--async"],
        capture_output=True, text=True, timeout=1800, env=env, cwd=REPO)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    lines = proc.stdout.strip().splitlines()
    # the machine-greppable status line the CI legs key on
    assert lines[-1].startswith("SHARDED_CHECK ok stages=8"), lines[-1]
    summary = json.loads(
        [ln for ln in lines if ln.startswith("{")][-1])
    assert summary["bit_identical"]
    assert summary["stages"] == 8
    indep = summary["independent_draft"]
    assert indep["sharded"]["dispatches"]["pipeline_verify"] > 0
    assert (indep["sharded"]["tokens_per_timestep"]
            == indep["local"]["tokens_per_timestep"])
    # the steady-state executor: ONE ring tick per executed timestep, on
    # both the miss-heavy and the perfect-acceptance workloads —
    # admission timesteps included (prefill-in-ring: zero separate
    # prefill dispatches), with the ctrl gate closed on quiet ticks
    for wl in ("independent_draft", "self_draft"):
        over = summary[wl]["sharded_overlapped"]
        assert (over["dispatches"]["pipeline_tick"] == over["timesteps"])
        assert over["dispatches"]["prefill_in_ring"] == 4
        assert 0.0 < over["ctrl_active_rate"] < 1.0
    # hits with a full ring: prune index_maps rode the ring
    assert summary["self_draft"]["acceptance_mean"] > 0.99
    assert summary["self_draft"]["sharded_overlapped"]["dispatches"][
        "remap_rows"] > 0
    # misses with a full ring: in-flight layers were killed
    assert summary["independent_draft"]["sharded_overlapped"][
        "dispatches"]["kill"] > 0
    pp = summary["pruning_propagation"]
    assert pp["killed_rows_untouched"] and pp["other_slot_unaffected"]
    assert pp["stale_exits_dropped"] and pp["live_exits_match"]
    # retire-clear regression: a retired occupant's in-ring ctrl must not
    # leak into the recycled slot's next occupant
    assert summary["slot_recycle"]["bit_identical"]
    assert summary["slot_recycle"]["kills"] >= 2
    # async free-running backend: bit-identical on the same workloads
    # (miss-heavy, self-draft, long-prompt, slot-recycle), with a kill
    # observed to cancel an in-flight layer at stage 0 — before a full
    # ring revolution — plus fail-loudly and clean-shutdown pins
    for wl in ("independent_draft", "self_draft", "long_prompt"):
        asy = summary[wl]["sharded_async"]
        assert asy["dispatches"]["stage_steps"] == \
            asy["dispatches"]["entry_msgs"] * 8
    assert summary["independent_draft"]["sharded_async"][
        "dispatches"]["kill"] > 0
    assert summary["async_kill_latency"]["stale_at_stage0"] >= 1
    assert summary["async_kill_latency"]["revolution_hops_saved"] == 7
    assert summary["async_failfast"]["propagates"]
    assert summary["async_shutdown"]["deterministic"]
    assert summary["async_shutdown"]["no_leaked_threads"]
    assert summary["async_slot_recycle"]["bit_identical"]


def test_overlapped_bitmatches_flush_and_single(bundles):
    """Staggered arrivals + slot churn on the overlapped backend
    (1-stage mesh): per-uid outputs bit-match the flush sharded backend
    and the single-request engine (same ``PipeDecConfig`` so the traces
    are comparable)."""
    target, draft = bundles
    reqs = _mk_reqs(7, 4, arrivals=[0, 1, 4, 6], max_new=[4, 5, 3, 4])
    single = PipeDecEngine(target, draft, PCFG1, max_len=MAX_LEN)
    want = {r.uid: single.generate(r.prompt, r.max_new_tokens)[0]
            for r in reqs}

    outs = {}
    for name, ex in (("flush", _sharded(bundles, 2, pcfg=PCFG1)),
                     ("overlapped", _overlapped(bundles, 2))):
        eng = SpecPipeDBEngine(target, draft, PCFG1, max_len=MAX_LEN,
                               max_slots=2, executor=ex)
        for r in reqs:
            eng.submit(r)
        outs[name] = eng.run()
    for uid, tokens in want.items():
        np.testing.assert_array_equal(
            outs["flush"][uid].tokens, tokens,
            err_msg=f"flush vs single uid={uid}")
        np.testing.assert_array_equal(
            outs["overlapped"][uid].tokens, tokens,
            err_msg=f"overlapped vs single uid={uid}")


def test_overlapped_one_tick_per_timestep(bundles):
    """The steady-state dispatch hook: the overlapped executor issues
    exactly ONE ring tick per executed global timestep — entries pending
    or not — and never falls back to a flush or per-slot dispatch."""
    target, draft = bundles
    reqs = _mk_reqs(8, 3, arrivals=[0, 0, 2], max_new=[4, 3, 4])
    ex = _overlapped(bundles, 2)
    eng = SpecPipeDBEngine(target, draft, PCFG1, max_len=MAX_LEN,
                           max_slots=2, executor=ex)
    before = {b: dict(b.calls) for b in (target, draft)}
    for r in reqs:
        eng.submit(r)
    eng.run()

    assert eng.stats.tick_dispatches == [1] * eng.stats.timesteps
    assert ex.calls["pipeline_tick"] == eng.stats.timesteps
    assert ex.calls["drain_tick"] == 0, \
        "per-timestep ticks must resolve every live flight"
    assert ex.calls["pipeline_verify"] == 0, "no flush dispatches"
    # draft rides the entry cadence, replicated locally
    disp = eng.stats.verify_dispatches
    assert draft.calls["tree_verify_rows"] - \
        before[draft].get("tree_verify_rows", 0) == sum(disp)
    for b in (target, draft):
        assert b.calls["tree_verify"] == before[b].get("tree_verify", 0)
    assert target.calls["tree_verify_rows"] == \
        before[target].get("tree_verify_rows", 0)
    assert eng.stats.peak_occupancy == 2, "slots actually shared"


def test_overlapped_generate_b1_path(bundles):
    """The B=1 path through ``generate_with_executor`` on the overlapped
    backend bit-matches the direct single-request engine."""
    target, draft = bundles
    prompt = np.asarray([5, 3, 2, 7, 11], np.int32)
    single = PipeDecEngine(target, draft, PCFG1, max_len=MAX_LEN)
    want, want_stats = single.generate(prompt, 6)
    got, stats = generate_with_executor(target, draft, PCFG1, prompt, 6,
                                        executor=_overlapped(bundles, 1),
                                        max_len=MAX_LEN)
    np.testing.assert_array_equal(got, want)
    assert stats.commits == want_stats.commits
    assert stats.acceptance == want_stats.acceptance


def test_overlapped_requires_matching_stage_count(bundles):
    """The ring IS the flight bookkeeping: an overlapped executor whose
    mesh stage count differs from ``PipeDecConfig.n_stages`` must be
    rejected (the fill latencies would disagree)."""
    target, draft = bundles
    with pytest.raises(AssertionError, match="n_stages"):
        SpecPipeDBEngine(target, draft, PCFG, max_len=MAX_LEN,
                         max_slots=2, executor=_overlapped(bundles, 2))


def test_overlapped_stale_flight_cannot_commit(bundles):
    """A killed slot's outstanding futures are dead: resolving one raises
    instead of committing from a stale tree (the engine never does — this
    pins the guard rail itself)."""
    from repro.serving import DeferredLogits

    h = DeferredLogits(slot=0, version=3)
    with pytest.raises(RuntimeError, match="not yet|before its exit"):
        h.resolve()
    h.dead = True
    with pytest.raises(RuntimeError, match="stale"):
        h.resolve()


def test_async_bitmatches_lockstep_and_single(bundles):
    """The async free-running backend (3 stage actors + a draft actor on
    the 1-device test process): staggered arrivals + slot churn must
    bit-match the flush sharded backend and the single-request engine —
    same tree policy, radically different schedule."""
    target, draft = bundles
    reqs = _mk_reqs(11, 4, arrivals=[0, 1, 4, 6], max_new=[4, 5, 3, 4])
    single = PipeDecEngine(target, draft, PCFG, max_len=MAX_LEN)
    want = {r.uid: single.generate(r.prompt, r.max_new_tokens)[0]
            for r in reqs}

    outs = {}
    execs = {"flush": _sharded(bundles, 2), "async": _async(bundles, 2)}
    for name, ex in execs.items():
        eng = SpecPipeDBEngine(target, draft, PCFG, max_len=MAX_LEN,
                               max_slots=2, executor=ex)
        for r in reqs:
            eng.submit(r)
        outs[name] = eng.run()
    ex = execs["async"]
    try:
        for uid, tokens in want.items():
            np.testing.assert_array_equal(
                outs["flush"][uid].tokens, tokens,
                err_msg=f"flush vs single uid={uid}")
            np.testing.assert_array_equal(
                outs["async"][uid].tokens, tokens,
                err_msg=f"async vs single uid={uid}")
        # every entry message stepped every free-running stage exactly
        # once, and the drained pipe consumed all its messages
        assert ex.calls["stage_steps"] == \
            ex.calls["entry_msgs"] * PCFG.n_stages
        assert ex._consumed == ex._pushed
    finally:
        ex.shutdown()


def test_async_kill_short_circuits_in_flight_layer(bundles):
    """Kill latency: with the stage gate paused, a pushed layer whose
    slot is killed must die at stage 0 — before even ONE hop, where the
    lockstep ring invalidates one stage per tick and a stale layer rides
    ``n_stages - 1`` more hops before its exit drops."""
    ex = _async(bundles, 2)
    try:
        ex.pause()
        row_on = np.zeros(2, bool)
        row_on[0] = True
        _d, handles = ex.tick_rows(*ex.dead_entry, row_on)
        ex.kill(0)
        ex.resume()
        ex.drain()
        ctr = ex.counters()
        assert ctr["stages"][0]["stale_rows"] >= 1, \
            "kill must beat the paused layer to stage 0"
        assert all(s["stale_rows"] >= 1 for s in ctr["stages"])
        assert handles[0].dead
        assert ex.calls["stale_exits"] >= 1
    finally:
        ex.shutdown()


def test_async_actor_exception_propagates(bundles):
    """Fail loudly, never hang: a stage actor that raises must surface
    on the host thread as ``AsyncExecutorError`` carrying the original
    traceback (within the executor timeout)."""
    ex = _async(bundles, 2)
    ex.timeout_s = 60.0
    ex._apply_j = lambda *a, **k: (_ for _ in ()).throw(
        RuntimeError("injected stage fault"))
    row_on = np.zeros(2, bool)
    row_on[0] = True
    try:
        with pytest.raises(AsyncExecutorError,
                           match="injected stage fault"):
            ex.tick_rows(*ex.dead_entry, row_on)
            ex.drain()
    finally:
        ex.shutdown()


def test_async_shutdown_clean_and_deterministic(bundles):
    """Clean shutdown: every actor thread joins (none leaked), shutdown
    is idempotent, and a fresh executor re-running the workload is
    bit-deterministic."""
    import threading

    target, draft = bundles
    reqs = _mk_reqs(13, 3, arrivals=[0, 1, 3], max_new=[4, 3, 4])

    def run_once():
        ex = _async(bundles, 2)
        eng = SpecPipeDBEngine(target, draft, PCFG, max_len=MAX_LEN,
                               max_slots=2, executor=ex)
        for r in reqs:
            eng.submit(r)
        res = eng.run()
        ex.shutdown()
        ex.shutdown()   # idempotent
        return {u: res[u].tokens for u in res}

    a, b = run_once(), run_once()
    leaked = [t.name for t in threading.enumerate()
              if t.name.startswith("async-")]
    assert not leaked, f"leaked actor threads: {leaked}"
    for u in a:
        np.testing.assert_array_equal(a[u], b[u],
                                      err_msg=f"repeat run uid={u}")


def test_devices_not_polluted_by_sharded_check():
    assert len(jax.devices()) == 1, \
        "test process must never see the sharded check's fake devices"
