"""Executor-layer tests: SpecPipe-DB on pluggable compute backends.

The logical scheduler must produce bit-identical per-request outputs on
every backend — ``LocalFusedExecutor`` (PR-2's fused single-device path),
``ShardedPipelineExecutor`` (the paper's pipelined deployment on an
n-stage mesh), and the single-request ``PipeDecEngine`` — because the
executor seam changes *where* the batched verify runs, never *what* is
computed.  The 8-stage acceptance pin runs in a subprocess
(``repro.launch.sharded_check``) so the forced host-device count never
leaks into this process; the in-process tests use a 1-stage mesh, which
exercises the same ring/psum/stage-masking code paths.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.pipedec import PipeDecConfig, PipeDecEngine
from repro.core.speculative import ModelBundle
from repro.models import transformer as tf
from repro.serving import (Request, ShardedPipelineExecutor,
                           SpecPipeDBEngine, generate_with_executor)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PCFG = PipeDecConfig(n_stages=3, width=4, branch=2)
MAX_LEN = 128


@pytest.fixture(scope="module")
def bundles(tiny_dense, tiny_draft):
    tp = tf.init_model(jax.random.PRNGKey(0), tiny_dense)
    dp = tf.init_model(jax.random.PRNGKey(9), tiny_draft)
    return ModelBundle(tp, tiny_dense), ModelBundle(dp, tiny_draft)


def _mk_reqs(seed, n, arrivals=None, max_new=None):
    rng = np.random.default_rng(seed)
    return [Request(i,
                    rng.integers(0, 100, size=int(rng.integers(3, 8)))
                    .astype(np.int32),
                    int(max_new[i]) if max_new else int(rng.integers(3, 7)),
                    arrival_t=int(arrivals[i]) if arrivals else 0)
            for i in range(n)]


def _sharded(bundles, slots, n_stages=1):
    target, draft = bundles
    return ShardedPipelineExecutor(
        target, draft, slots=slots, max_len=MAX_LEN,
        tree_capacity=PCFG.tree_buffer_capacity, capacity=PCFG.capacity,
        n_stages=n_stages)


def test_sharded_executor_bitmatches_local_and_single(bundles):
    """Staggered arrivals + slot churn on the sharded backend (1-stage
    mesh): per-uid outputs bit-match the local fused backend and the
    single-request engine."""
    target, draft = bundles
    reqs = _mk_reqs(3, 4, arrivals=[0, 1, 4, 6], max_new=[4, 5, 3, 4])
    single = PipeDecEngine(target, draft, PCFG, max_len=MAX_LEN)
    want = {r.uid: single.generate(r.prompt, r.max_new_tokens)[0]
            for r in reqs}

    outs = {}
    for name, ex in (("local", None), ("sharded", _sharded(bundles, 2))):
        eng = SpecPipeDBEngine(target, draft, PCFG, max_len=MAX_LEN,
                               max_slots=2, executor=ex)
        for r in reqs:
            eng.submit(r)
        outs[name] = eng.run()
    for uid, tokens in want.items():
        np.testing.assert_array_equal(outs["local"][uid].tokens, tokens,
                                      err_msg=f"local vs single uid={uid}")
        np.testing.assert_array_equal(outs["sharded"][uid].tokens, tokens,
                                      err_msg=f"sharded vs single uid={uid}")


def test_sharded_one_batched_tick_per_timestep(bundles):
    """The dispatch-count hook: every global timestep with pending entries
    issues exactly ONE sharded pipeline dispatch (and one local draft
    dispatch) — never one per slot."""
    target, draft = bundles
    reqs = _mk_reqs(4, 3, arrivals=[0, 0, 2], max_new=[4, 3, 4])
    ex = _sharded(bundles, 2)
    eng = SpecPipeDBEngine(target, draft, PCFG, max_len=MAX_LEN,
                           max_slots=2, executor=ex)
    before = {b: dict(b.calls) for b in (target, draft)}
    for r in reqs:
        eng.submit(r)
    eng.run()

    disp = eng.stats.verify_dispatches
    assert len(disp) == eng.stats.timesteps
    assert max(disp) == 1
    assert ex.calls["pipeline_verify"] == sum(disp)
    assert ex.calls["verify_rows"] == sum(disp)
    # draft rides the same fused dispatch cadence, replicated locally
    assert draft.calls["tree_verify_rows"] - \
        before[draft].get("tree_verify_rows", 0) == sum(disp)
    # neither model ever falls back to the per-slot looped dispatch
    for b in (target, draft):
        assert b.calls["tree_verify"] == before[b].get("tree_verify", 0)
    # the target's verify runs through the sharded ring, not its bundle
    assert target.calls["tree_verify_rows"] == \
        before[target].get("tree_verify_rows", 0)
    assert eng.stats.peak_occupancy == 2, "slots actually shared"


def test_generate_with_executor_b1_path(bundles):
    """The B=1 PipeDecEngine path runs against either executor and
    bit-matches the direct single-request engine."""
    target, draft = bundles
    prompt = np.asarray([5, 3, 2, 7, 11], np.int32)
    single = PipeDecEngine(target, draft, PCFG, max_len=MAX_LEN)
    want, want_stats = single.generate(prompt, 6)

    for ex in (None, _sharded(bundles, 1)):
        got, stats = generate_with_executor(target, draft, PCFG, prompt, 6,
                                            executor=ex, max_len=MAX_LEN)
        np.testing.assert_array_equal(got, want)
        assert stats.commits == want_stats.commits
        assert stats.acceptance == want_stats.acceptance


def test_executor_slot_count_must_match(bundles):
    target, draft = bundles
    with pytest.raises(AssertionError, match="slot count"):
        SpecPipeDBEngine(target, draft, PCFG, max_len=MAX_LEN, max_slots=3,
                         executor=_sharded(bundles, 2))


def test_sharded_8stage_acceptance_pin_subprocess():
    """The PR's acceptance pin on a REAL 8-device simulated mesh: sharded
    == local == single per uid, one batched tick per timestep.  Runs
    ``repro.launch.sharded_check`` in a subprocess so the forced
    host-device count cannot leak into this test process (same pattern as
    test_dryrun)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.sharded_check", "--stages",
         "8", "--requests", "4"],
        capture_output=True, text=True, timeout=1200, env=env, cwd=REPO)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    summary = json.loads(proc.stdout.strip().splitlines()[-1])
    assert summary["bit_identical"]
    assert summary["stages"] == 8
    assert summary["sharded"]["dispatches"]["pipeline_verify"] > 0
    assert (summary["sharded"]["tokens_per_timestep"]
            == summary["local"]["tokens_per_timestep"])


def test_devices_not_polluted_by_sharded_check():
    assert len(jax.devices()) == 1, \
        "test process must never see the sharded check's fake devices"
