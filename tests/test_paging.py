"""Block-paged KV storage: ``models.paging`` pool/table ops, the Pallas
paged attention kernels vs their jnp oracles, and end-to-end bit-identity
of the paged serving executors (chunked prefill past ``prefill_cap``
included).

The paged invariant mirrors dense slot recycling: unallocated logical
blocks alias physical block 0 (the null block), whose rows every
attention mask already excludes — so gathers are well-defined and writes
at the buffer edge collapse harmlessly onto block 0.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pipedec import PipeDecConfig, PipeDecEngine
from repro.core.speculative import ModelBundle
from repro.kernels import ops, ref
from repro.models import paging
from repro.models import transformer as tf
from repro.serving import (LocalFusedExecutor, OverlappedShardedExecutor,
                           Request, ShardedPipelineExecutor,
                           SpecPipeDBEngine)

TOL = dict(rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# models.paging unit ops
# --------------------------------------------------------------------------
def _dense(rng, shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


def test_make_paged_round_trip_shuffled_table():
    """Dense -> pool+table -> dense is the identity for ANY block
    permutation: the table indirection hides physical placement."""
    rng = np.random.default_rng(0)
    b, length, d, page = 3, 20, 5, 8
    mb = paging.n_blocks(length, page)
    dense = _dense(rng, (b, length, d))
    table = 1 + rng.permutation(b * mb).reshape(b, mb).astype(np.int32)
    p = paging.make_paged(dense, table, page)
    assert paging.is_paged(p) and p.slots == b and p.length == length
    assert paging.dense_shape(p) == dense.shape
    np.testing.assert_array_equal(paging.to_dense(p), dense)


def test_round_trip_stacked_layout_n_pre():
    """Stacked buffers ([reps, B, L, ...]) page the same way with the
    leading dims folded into the physical row."""
    rng = np.random.default_rng(1)
    reps, b, length, d, page = 2, 2, 16, 4, 8
    dense = _dense(rng, (reps, b, length, d))
    table = 1 + np.arange(b * 2, dtype=np.int32).reshape(b, 2)
    p = paging.make_paged(dense, table, page, n_pre=1)
    np.testing.assert_array_equal(paging.to_dense(p), dense)
    upd = _dense(rng, dense.shape)
    np.testing.assert_array_equal(
        paging.to_dense(paging.from_dense(p, upd)), upd)


def test_null_block_aliasing_and_write_drop():
    """Unallocated logical blocks (table entry 0) alias ONE shared null
    block (don't-care rows every mask excludes); out-of-range and
    masked-off ``write_len_rows`` writes are redirected into it without
    corrupting any backed row of any slot."""
    rng = np.random.default_rng(2)
    b, length, d, page = 2, 16, 3, 8
    dense = _dense(rng, (b, length, d))
    # each slot's SECOND logical block is unallocated
    table = np.asarray([[1, 0], [2, 0]], np.int32)
    p = paging.make_paged(dense, table, page)
    got = np.asarray(paging.to_dense(p))
    np.testing.assert_array_equal(got[0, :page], dense[0, :page])
    np.testing.assert_array_equal(got[1, :page], dense[1, :page])
    # both unbacked regions read the SAME physical null block
    np.testing.assert_array_equal(got[0, page:], got[1, page:])

    before = got
    u = _dense(rng, (b, 4, d))
    # slot 0 masked off, slot 1 writes past the buffer edge: both are
    # redirected into the null block — every BACKED row stays bit-intact
    p2 = paging.write_len_rows(p, u, starts=[4, length],
                               on=[False, True])
    after = np.asarray(paging.to_dense(p2))
    np.testing.assert_array_equal(after[0, :page], before[0, :page])
    np.testing.assert_array_equal(after[1, :page], before[1, :page])


def test_write_len_rows_and_take_len_rows():
    rng = np.random.default_rng(3)
    b, length, d, page = 2, 24, 4, 8
    dense = _dense(rng, (b, length, d))
    table = 1 + np.arange(b * 3, dtype=np.int32).reshape(b, 3)
    p = paging.make_paged(dense, table, page)
    u = _dense(rng, (b, 5, d))
    starts = np.asarray([2, 13], np.int32)
    p2 = paging.write_len_rows(p, u, starts)
    want = np.asarray(dense).copy()
    for i in range(b):
        want[i, starts[i]:starts[i] + 5] = u[i]
    np.testing.assert_array_equal(paging.to_dense(p2), want)
    idx = np.asarray([[2, 3, 4], [13, 14, 15]], np.int32)
    np.testing.assert_array_equal(
        paging.take_len_rows(p2, idx),
        np.stack([want[i, idx[i]] for i in range(b)]))


def test_slice_slots_adopt_pool_and_write_slot_rows():
    """Bucketed-dispatch plumbing: a slot-row view shares the pool, its
    functional update is adopted back, and untouched slots are
    bit-unchanged."""
    rng = np.random.default_rng(4)
    b, length, d, page = 3, 16, 4, 8
    dense = _dense(rng, (b, length, d))
    table = 1 + np.arange(b * 2, dtype=np.int32).reshape(b, 2)
    p = paging.make_paged(dense, table, page)
    view = paging.slice_slots(p, 1, 2)
    np.testing.assert_array_equal(paging.to_dense(view),
                                  np.asarray(dense)[1:3])
    upd = _dense(rng, (2, length, d))
    merged = paging.adopt_pool(p, paging.from_dense(view, upd))
    got = np.asarray(paging.to_dense(merged))
    np.testing.assert_array_equal(got[0], dense[0])
    np.testing.assert_array_equal(got[1:], upd)

    upd2 = _dense(rng, (1, length, d))
    got2 = paging.to_dense(paging.write_slot_rows(p, upd2, 2))
    np.testing.assert_array_equal(got2[:2], np.asarray(dense)[:2])
    np.testing.assert_array_equal(got2[2], upd2[0])


def test_where_slots_selects_blocks_per_slot():
    rng = np.random.default_rng(5)
    b, length, d, page = 3, 16, 4, 8
    table = 1 + np.arange(b * 2, dtype=np.int32).reshape(b, 2)
    old = paging.make_paged(_dense(rng, (b, length, d)), table, page)
    new = paging.from_dense(old, _dense(rng, (b, length, d)))
    on = np.asarray([True, False, True])
    got = np.asarray(paging.to_dense(paging.where_slots(on, new, old)))
    want_new = np.asarray(paging.to_dense(new))
    want_old = np.asarray(paging.to_dense(old))
    for i in range(b):
        np.testing.assert_array_equal(got[i],
                                      want_new[i] if on[i] else want_old[i])


def test_densify_repaginate_tree():
    rng = np.random.default_rng(6)
    table = 1 + np.arange(4, dtype=np.int32).reshape(2, 2)
    p = paging.make_paged(_dense(rng, (2, 16, 4)), table, 8)
    tree = {"k": p, "state": _dense(rng, (2, 3)), "none": None}
    assert paging.any_paged(tree)
    d = paging.densify(tree)
    assert not paging.any_paged(d)
    upd = jax.tree.map(lambda x: x + 1.0, d)
    back = paging.repaginate(tree, upd)
    assert paging.is_paged(back["k"])
    np.testing.assert_array_equal(paging.to_dense(back["k"]), upd["k"])
    np.testing.assert_array_equal(back["state"], upd["state"])


# --------------------------------------------------------------------------
# paged Pallas kernels vs oracles (interpret mode, like test_kernels.py)
# --------------------------------------------------------------------------
def _blocked(dense, page, rng):
    """[B,KV,L,hd] -> shuffled ([Nb,KV,page,hd] pool, [B,mb] table)."""
    b, kvh, length, hd = dense.shape
    mb = -(-length // page)
    pad = mb * page - length
    if pad:
        dense = np.pad(np.asarray(dense), ((0, 0), (0, 0), (0, pad), (0, 0)))
    dense = np.asarray(dense)
    ids = 1 + rng.permutation(b * mb)
    pool = np.zeros((1 + b * mb, kvh, page) + dense.shape[3:],
                    dense.dtype)
    table = np.zeros((b, mb), np.int32)
    i = 0
    for bb in range(b):
        for j in range(mb):
            pool[ids[i]] = dense[bb, :, j * page:(j + 1) * page]
            table[bb, j] = ids[i]
            i += 1
    return jnp.asarray(pool), jnp.asarray(table)


def test_paged_gather_ref_is_table_indirection():
    rng = np.random.default_rng(7)
    dense = _dense(rng, (2, 3, 32, 8))
    pool, table = _blocked(dense, 8, rng)
    np.testing.assert_array_equal(ref.paged_gather_ref(pool, table, 32),
                                  dense)


@pytest.mark.parametrize("b,h,kv,hd,page,mbl", [
    (2, 4, 2, 64, 16, 4),
    (1, 2, 1, 32, 8, 6),
])
def test_paged_decode_attention_kernel_vs_oracle_vs_dense(b, h, kv, hd,
                                                          page, mbl):
    """Paged flash-decode == paged oracle == dense reference on the
    gathered view — per-row kv_len, shuffled physical blocks."""
    rng = np.random.default_rng(hash((b, h, hd)) % 2 ** 31)
    lmax = page * mbl
    q = _dense(rng, (b, h, 1, hd))
    k = _dense(rng, (b, kv, lmax, hd))
    v = _dense(rng, (b, kv, lmax, hd))
    # k and v ride ONE table — block both with the same permutation
    k_pool, table = _blocked(k, page, np.random.default_rng(42))
    v_pool, vtab = _blocked(v, page, np.random.default_rng(42))
    np.testing.assert_array_equal(table, vtab)
    kv_len = jnp.asarray(rng.integers(1, lmax, size=b), jnp.int32)
    out = ops.paged_decode_attention(q, k_pool, v_pool, table, kv_len)
    want = ref.paged_decode_attention_ref(q, k_pool, v_pool, table, kv_len)
    dense_want = ref.decode_attention_ref(
        q, k, v, kv_len.reshape(-1, 1, 1, 1))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL)
    np.testing.assert_allclose(np.asarray(want), np.asarray(dense_want),
                               **TOL)


def test_paged_tree_attention_kernel_vs_oracle_with_ragged_tree():
    """Two-level paged tree attention vs its oracle and the dense
    two-level reference; the tree capacity is NOT a multiple of the page
    (the last block's tail must be force-masked)."""
    rng = np.random.default_rng(11)
    b, h, kv, n, hd, page = 2, 4, 2, 4, 32, 8
    lmax, t = 32, 13
    q = _dense(rng, (b, h, n, hd))
    kp = _dense(rng, (b, kv, lmax, hd))
    vp = _dense(rng, (b, kv, lmax, hd))
    kt = _dense(rng, (b, kv, t, hd))
    vt = _dense(rng, (b, kv, t, hd))
    k_pool, table = _blocked(kp, page, np.random.default_rng(42))
    v_pool, _ = _blocked(vp, page, np.random.default_rng(42))
    kt_pool, t_table = _blocked(kt, page, np.random.default_rng(43))
    vt_pool, _ = _blocked(vt, page, np.random.default_rng(43))
    mask = jnp.asarray(rng.random((b, n, t)) > 0.4).at[:, :, 0].set(True)
    plen = jnp.asarray(rng.integers(1, lmax, size=b), jnp.int32)
    out = ops.paged_tree_attention(q, k_pool, v_pool, table, kt_pool,
                                   vt_pool, t_table, mask, plen)
    want = ref.paged_tree_attention_ref(q, k_pool, v_pool, table, kt_pool,
                                        vt_pool, t_table, mask, plen)
    dense_want = ref.tree_attention_ref(q, kp, vp, kt, vt, mask, plen)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL)
    np.testing.assert_allclose(np.asarray(want), np.asarray(dense_want),
                               **TOL)


def test_paged_decode_attention_quant_vs_oracle():
    """Int8 pools with blocked per-row scales ride the same table maps."""
    rng = np.random.default_rng(13)
    b, h, kv, hd, page, lmax = 1, 2, 1, 32, 8, 32
    q = _dense(rng, (b, h, 1, hd))
    k8 = rng.integers(-127, 128, size=(b, kv, lmax, hd)).astype(np.int8)
    v8 = rng.integers(-127, 128, size=(b, kv, lmax, hd)).astype(np.int8)
    ks = rng.random((b, kv, lmax)).astype(np.float32) * 0.02 + 0.001
    vs = rng.random((b, kv, lmax)).astype(np.float32) * 0.02 + 0.001
    k_pool, table = _blocked(jnp.asarray(k8), page,
                             np.random.default_rng(42))
    v_pool, _ = _blocked(jnp.asarray(v8), page, np.random.default_rng(42))
    ks_pool, _ = _blocked(jnp.asarray(ks)[..., None], page,
                          np.random.default_rng(42))
    vs_pool, _ = _blocked(jnp.asarray(vs)[..., None], page,
                          np.random.default_rng(42))
    ks_pool, vs_pool = ks_pool[..., 0], vs_pool[..., 0]
    kv_len = lmax - 5
    out = ops.paged_decode_attention(q, k_pool, v_pool, table, kv_len,
                                     k_scale=ks_pool, v_scale=vs_pool)
    want = ref.paged_decode_attention_ref(q, k_pool, v_pool, table, kv_len,
                                          k_scale=ks_pool, v_scale=vs_pool)
    dense_want = ref.decode_attention_quant_ref(
        q, jnp.asarray(k8), jnp.asarray(v8), kv_len,
        k_scale=jnp.asarray(ks), v_scale=jnp.asarray(vs))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL)
    np.testing.assert_allclose(np.asarray(want), np.asarray(dense_want),
                               **TOL)


# --------------------------------------------------------------------------
# paged serving executors: bit-identity + chunked prefill
# --------------------------------------------------------------------------
PCFG = PipeDecConfig(n_stages=3, width=4, branch=2)
# the overlapped ring length equals pcfg.n_stages, and in-process tests
# only have a 1-device mesh — multi-stage paged overlap runs via the
# subprocess sharded_check --paged CI legs
PCFG1 = PipeDecConfig(n_stages=1, width=4, branch=2)
MAX_LEN = 128


@pytest.fixture(scope="module")
def bundles(tiny_dense, tiny_draft):
    tp = tf.init_model(jax.random.PRNGKey(0), tiny_dense)
    dp = tf.init_model(jax.random.PRNGKey(9), tiny_draft)
    return ModelBundle(tp, tiny_dense), ModelBundle(dp, tiny_draft)


def _reqs():
    rng = np.random.default_rng(21)
    lens = [4, 21, 6]          # 21 > prefill_cap: chunked on overlapped
    return [Request(i, rng.integers(0, 100, size=n).astype(np.int32),
                    3 + i % 2, arrival_t=i)
            for i, n in enumerate(lens)]


def test_paged_executors_bit_identical_to_dense(bundles):
    """Every paged backend must reproduce the dense single-request
    outputs bit-for-bit; the overlapped backend additionally streams the
    long prompt through the ring in prefill_cap chunks with exactly one
    tick per timestep and no standalone prefill dispatch."""
    target, draft = bundles
    reqs = _reqs()
    want = {
        pcfg.n_stages: {r.uid: PipeDecEngine(target, draft, pcfg,
                                             max_len=MAX_LEN)
                              .generate(r.prompt, r.max_new_tokens)[0]
                        for r in reqs}
        for pcfg in (PCFG, PCFG1)}
    cap = 8
    mk = {
        "local": (PCFG, lambda: LocalFusedExecutor(
            target, draft, slots=2, max_len=MAX_LEN,
            tree_capacity=PCFG.tree_buffer_capacity,
            capacity=PCFG.capacity, paged=True, page=16)),
        "sharded": (PCFG1, lambda: ShardedPipelineExecutor(
            target, draft, slots=2, max_len=MAX_LEN,
            tree_capacity=PCFG1.tree_buffer_capacity,
            capacity=PCFG1.capacity, n_stages=1, paged=True, page=16)),
        "overlapped": (PCFG1, lambda: OverlappedShardedExecutor(
            target, draft, slots=2, max_len=MAX_LEN,
            tree_capacity=PCFG1.tree_buffer_capacity,
            capacity=PCFG1.capacity, n_stages=1, prefill_cap=cap,
            paged=True, page=16)),
    }
    for name, (pcfg, make) in mk.items():
        ex = make()
        eng = SpecPipeDBEngine(target, draft, pcfg, max_len=MAX_LEN,
                               max_slots=2, executor=ex)
        before = {m: dict(m.calls) for m in (target, draft)}
        for r in reqs:
            eng.submit(r)
        res = eng.run()
        for uid, tokens in want[pcfg.n_stages].items():
            np.testing.assert_array_equal(res[uid].tokens, tokens,
                                          err_msg=f"paged {name} uid={uid}")
        if name == "overlapped":
            assert ex.calls["pipeline_tick"] == eng.stats.timesteps
            assert ex.calls["prefill_in_ring"] == len(reqs)
            chunks = sum(-(-len(r.prompt) // cap) for r in reqs)
            assert ex.calls["prefill_chunks"] == chunks
            assert eng.stats.separate_prefill_dispatches == 0
            for m in (target, draft):
                assert m.calls["prefill"] == before[m].get("prefill", 0)
        if name == "local":
            ctrs = eng.stats.page_counters
            assert ctrs and ctrs[-1]["blocks_in_use"] >= 0
            assert max(c["peak_blocks"] for c in ctrs) > 0
