"""Substrate tests: data pipeline, optimizer, checkpointing, serving."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.core.speculative import ModelBundle
from repro.data import ByteCorpus, DataConfig, batch_iterator, synthetic_corpus
from repro.models import transformer as tf
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.serving import Request, ServingEngine


def test_corpus_packing_and_labels():
    text = bytes(range(97, 123)) * 100
    cfg = DataConfig(seq_len=16, batch_size=4)
    corpus = ByteCorpus(text, cfg)
    x, y = corpus.example(0)
    assert x.shape == (16,) and y.shape == (16,)
    np.testing.assert_array_equal(x[1:], y[:-1])  # next-token labels


def test_batch_iterator_host_sharding():
    text = synthetic_corpus(1 << 12)
    cfg = DataConfig(seq_len=8, batch_size=2)
    corpus = ByteCorpus(text, cfg)
    b0 = list(batch_iterator(corpus, epochs=1, shuffle=False, host_id=0,
                             host_count=2))
    b1 = list(batch_iterator(corpus, epochs=1, shuffle=False, host_id=1,
                             host_count=2))
    assert len(b0) > 0 and len(b1) > 0
    # disjoint examples
    all0 = np.concatenate([x.ravel() for x, _ in b0])
    assert b0[0][0].shape == (2, 8)


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=1000)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw (w^2)
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_schedule(cfg, 0)) == 0.0
    assert abs(float(cosine_schedule(cfg, 10)) - 1.0) < 1e-6
    assert float(cosine_schedule(cfg, 100)) < 1e-6


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0,
                      warmup_steps=0)
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    _, _, metrics = adamw_update(cfg, params, {"w": jnp.full(3, 1e6)}, state)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_checkpoint_roundtrip(tiny_dense):
    params = tf.init_model(jax.random.PRNGKey(0), tiny_dense)
    tree = {"params": params, "meta": {"step": np.asarray(7)},
            "history": [np.arange(3), np.ones((2, 2))]}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_pytree(path, tree)
        back = load_pytree(path)
    flat_a = jax.tree.leaves(tree)
    flat_b = jax.tree.leaves(back)
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_serving_pp_vs_pipedec_identical(tiny_dense, tiny_draft):
    target = ModelBundle(tf.init_model(jax.random.PRNGKey(0), tiny_dense),
                         tiny_dense)
    draft = ModelBundle(tf.init_model(jax.random.PRNGKey(1), tiny_draft),
                        tiny_draft)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, 100, size=6).astype(np.int32), 8)
            for i in range(3)]

    pp = ServingEngine(target, mode="pp", max_batch=2)
    for r in reqs:
        pp.submit(r)
    pp_res = pp.run()

    pd = ServingEngine(target, draft, mode="pipedec")
    for r in reqs:
        pd.submit(r)
    pd_res = pd.run()

    assert set(pp_res) == set(pd_res) == {0, 1, 2}
    for uid in pp_res:
        np.testing.assert_array_equal(pp_res[uid].tokens,
                                      pd_res[uid].tokens)


def test_serving_pp_batches_mixed_lengths(tiny_dense):
    target = ModelBundle(tf.init_model(jax.random.PRNGKey(0), tiny_dense),
                         tiny_dense)
    rng = np.random.default_rng(1)
    eng = ServingEngine(target, mode="pp", max_batch=4)
    for i, ln in enumerate([4, 6, 4, 6, 4]):
        eng.submit(Request(i, rng.integers(0, 100, ln).astype(np.int32), 5))
    res = eng.run()
    assert len(res) == 5
    for r in res.values():
        assert len(r.tokens) == 6
