"""End-to-end system tests: train → decode → speculative acceleration with
a *trained* draft (realistic acceptance), cache-layout round trips, and the
chunked-CE loss path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import generate_autoregressive
from repro.core.pipedec import PipeDecConfig, PipeDecEngine
from repro.core.speculative import ModelBundle
from repro.launch.train import train
from repro.models import transformer as tf
from repro.models.config import ModelConfig

T_CFG = ModelConfig(name="sys-target", family="dense", num_layers=2,
                    d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                    vocab_size=260)
D_CFG = ModelConfig(name="sys-draft", family="dense", num_layers=1,
                    d_model=64, num_heads=2, num_kv_heads=1, d_ff=128,
                    vocab_size=260, tie_embeddings=True)


@pytest.fixture(scope="module")
def trained():
    tp, tl = train(T_CFG, steps=60, batch=8, seq=32, lr=2e-3, log_every=0,
                   corpus_bytes=1 << 14)
    dp, dl = train(D_CFG, steps=60, batch=8, seq=32, lr=2e-3, log_every=0,
                   corpus_bytes=1 << 14)
    assert tl[-1] < tl[0] and dl[-1] < dl[0], "training must reduce loss"
    return ModelBundle(tp, T_CFG), ModelBundle(dp, D_CFG)


def test_trained_pair_has_nonzero_acceptance(trained):
    """The paper's premise: a weaker model trained on the same distribution
    predicts the target well enough to accelerate it."""
    target, draft = trained
    from repro.data import ByteCorpus, DataConfig, synthetic_corpus
    corpus = ByteCorpus(synthetic_corpus(1 << 12, seed=5),
                        DataConfig(seq_len=24, batch_size=1))
    prompt = corpus.example(0)[0]
    ar = generate_autoregressive(target, prompt, 24, max_len=128)
    eng = PipeDecEngine(target, draft,
                        PipeDecConfig(n_stages=4, width=16, branch=4),
                        max_len=128)
    out, stats = eng.generate(prompt, 24)
    assert np.array_equal(ar, out)
    assert stats.acceptance > 0.25, \
        f"trained draft should hit sometimes (acc={stats.acceptance})"
    assert stats.tokens_per_timestep > 1 / 4  # beats vanilla PP rate


def test_chunked_ce_matches_dense_loss(tiny_dense):
    params = tf.init_model(jax.random.PRNGKey(0), tiny_dense)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                tiny_dense.vocab_size)
    labels = jnp.roll(tokens, -1, 1)
    dense_logits, _ = tf.forward(params, tiny_dense, tokens)
    logp = jax.nn.log_softmax(dense_logits.astype(jnp.float32), -1)
    want = -jnp.take_along_axis(logp, labels[..., None], -1).mean()
    got = tf.loss_fn(params, tiny_dense, tokens, labels, ce_chunk=8)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_cache_stack_unstack_roundtrip(tiny_dense):
    cache = tf.init_cache(tiny_dense, 2, 16)
    params = tf.init_model(jax.random.PRNGKey(0), tiny_dense)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, 128)
    _, cache = tf.prefill(params, tiny_dense, toks, cache)
    un = tf.unstack_cache(tiny_dense, cache)
    re = tf.restack_cache(tiny_dense, un)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(re)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decode_equivalence_unstacked_layout(tiny_dense):
    """Serving layout (per-layer buffers) must decode identically to the
    stacked scan layout."""
    cfg = tiny_dense
    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, 128)
    cache = tf.init_cache(cfg, 1, 16)
    logits, cache = tf.prefill(params, cfg, toks, cache)
    tok = jnp.argmax(logits, -1)

    stacked_logits, _ = tf.decode_step(params, cfg, tok, cache, 8)
    un = tf.unstack_cache(cfg, cache)
    unstacked_logits, un2 = tf.decode_step(params, cfg, tok, un, 8)
    np.testing.assert_allclose(np.asarray(stacked_logits),
                               np.asarray(unstacked_logits),
                               rtol=2e-5, atol=2e-5)
    assert "units" in un2


def test_remat_forward_matches(tiny_dense):
    params = tf.init_model(jax.random.PRNGKey(0), tiny_dense)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 128)
    a, _ = tf.forward(params, tiny_dense, tokens, remat=False)
    b, _ = tf.forward(params, tiny_dense, tokens, remat=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)
