"""Cheap steady-state ticks: the three overlapped-executor cost levers —
gated in-ring ctrl, donated ring/stage-cache buffers, and prefill-in-ring
— must each be free of semantic effect (committed tokens bit-identical
with every lever on or off) while actually engaging (no donation
warnings, no separate prefill dispatches, ctrl gated off on quiet ticks).

All tests run on a 1-stage mesh (the in-process device budget); the same
levers run on a REAL 8-device mesh via ``repro.launch.sharded_check
--overlap`` (see tests/test_executor_sharded.py).
"""
import warnings

import jax
import numpy as np
import pytest

from repro.core.pipedec import PipeDecConfig, PipeDecEngine
from repro.core.speculative import ModelBundle
from repro.models import transformer as tf
from repro.serving import (OverlappedShardedExecutor, Request,
                           SpecPipeDBEngine)

PCFG1 = PipeDecConfig(n_stages=1, width=4, branch=2)
MAX_LEN = 128


@pytest.fixture(scope="module")
def bundles(tiny_dense, tiny_draft):
    tp = tf.init_model(jax.random.PRNGKey(0), tiny_dense)
    dp = tf.init_model(jax.random.PRNGKey(9), tiny_draft)
    return ModelBundle(tp, tiny_dense), ModelBundle(dp, tiny_draft)


def _overlapped(bundles, slots, **kw):
    target, draft = bundles
    return OverlappedShardedExecutor(
        target, draft, slots=slots, max_len=MAX_LEN,
        tree_capacity=PCFG1.tree_buffer_capacity, capacity=PCFG1.capacity,
        n_stages=1, **kw)


def _mk_reqs(seed, n, arrivals, max_new):
    rng = np.random.default_rng(seed)
    return [Request(i,
                    rng.integers(0, 100, size=int(rng.integers(3, 8)))
                    .astype(np.int32), int(max_new[i]),
                    arrival_t=int(arrivals[i]))
            for i in range(n)]


def _run(bundles, reqs, slots=2, **kw):
    target, draft = bundles
    ex = _overlapped(bundles, slots, **kw)
    eng = SpecPipeDBEngine(target, draft, PCFG1, max_len=MAX_LEN,
                           max_slots=slots, executor=ex)
    for r in reqs:
        eng.submit(r)
    return eng, ex, eng.run()


def test_donated_tick_compiles_without_donation_warnings(bundles):
    """The donated tick must actually alias: jax warns ("Some donated
    buffers were not usable") when a donated input cannot be aliased to
    an output — the pin is that no such warning fires across compile and
    steady-state dispatch."""
    reqs = _mk_reqs(11, 3, arrivals=[0, 1, 3], max_new=[4, 3, 4])
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        _run(bundles, reqs, donate=True)
    donation = [w for w in caught if "donat" in str(w.message).lower()]
    assert not donation, [str(w.message) for w in donation]


def test_gating_and_donation_bit_identical_on_vs_off(bundles):
    """Committed tokens must be bit-identical with gated ctrl + donation
    + prefill-in-ring on vs all three off (the off configuration is the
    PR-4 semantics) and vs the single-request engine."""
    target, draft = bundles
    reqs = _mk_reqs(12, 4, arrivals=[0, 1, 4, 6], max_new=[4, 5, 3, 4])
    single = PipeDecEngine(target, draft, PCFG1, max_len=MAX_LEN)
    want = {r.uid: single.generate(r.prompt, r.max_new_tokens)[0]
            for r in reqs}
    _, _, on = _run(bundles, reqs, gate_ctrl=True, donate=True)
    _, ex_off, off = _run(bundles, reqs, gate_ctrl=False, donate=False,
                          prefill_cap=0)
    for uid, tokens in want.items():
        np.testing.assert_array_equal(on[uid].tokens, tokens,
                                      err_msg=f"levers-on uid={uid}")
        np.testing.assert_array_equal(off[uid].tokens, tokens,
                                      err_msg=f"levers-off uid={uid}")
    # ungated: every tick pays the ctrl application
    assert ex_off.calls["ctrl_active_ticks"] == ex_off.calls["pipeline_tick"]


def test_prefill_rides_the_tick_dispatch(bundles):
    """The dispatch-count pin: admission prefill no longer issues its own
    dispatch — ``calls["pipeline_tick"] == timesteps`` with admissions
    included, no ``prefill`` entry in either ``ModelBundle.calls``, and
    one ``prefill_in_ring`` per admitted request."""
    target, draft = bundles
    reqs = _mk_reqs(13, 4, arrivals=[0, 0, 2, 5], max_new=[4, 3, 4, 3])
    before = {b: dict(b.calls) for b in (target, draft)}
    eng, ex, _ = _run(bundles, reqs)
    assert ex.calls["pipeline_tick"] == eng.stats.timesteps
    assert eng.stats.tick_dispatches == [1] * eng.stats.timesteps
    assert ex.calls["prefill_in_ring"] == len(reqs)
    assert ex.calls["drain_tick"] == 0
    for b in (target, draft):
        assert b.calls["prefill"] == before[b].get("prefill", 0), \
            "prefill must ride the tick dispatch, not a ModelBundle call"
    # the ctrl gate actually closes on some ticks of a miss-heavy run
    assert ex.calls["ctrl_active_ticks"] <= ex.calls["pipeline_tick"]


def test_long_prompt_streams_through_ring_in_chunks(bundles):
    """A prompt longer than the ring's prefill lane no longer falls back
    to a separate dispatch: it streams through the lane in
    ``prefill_cap``-token chunks over consecutive ticks — tokens still
    bit-match the single-request engine, with zero ``ModelBundle``
    prefill calls and one tick per timestep throughout."""
    target, draft = bundles
    rng = np.random.default_rng(14)
    long_prompt = rng.integers(0, 100, size=12).astype(np.int32)
    reqs = [Request(0, long_prompt, 4, arrival_t=0),
            Request(1, rng.integers(0, 100, size=4).astype(np.int32), 3,
                    arrival_t=1)]
    single = PipeDecEngine(target, draft, PCFG1, max_len=MAX_LEN)
    want = {r.uid: single.generate(r.prompt, r.max_new_tokens)[0]
            for r in reqs}
    before = {b: dict(b.calls) for b in (target, draft)}
    eng, ex, res = _run(bundles, reqs, prefill_cap=8)
    for uid, tokens in want.items():
        np.testing.assert_array_equal(res[uid].tokens, tokens,
                                      err_msg=f"uid={uid}")
    assert ex.calls["prefill_in_ring"] == 2, "both prompts ride the ring"
    assert ex.calls["prefill_chunks"] == 3, \
        "12-token prompt = 2 chunks at cap 8, short prompt = 1"
    for b in (target, draft):
        assert b.calls["prefill"] == before[b].get("prefill", 0), \
            "no separate-dispatch prefill at any prompt length"
    assert eng.stats.separate_prefill_dispatches == 0
    assert ex.calls["pipeline_tick"] == eng.stats.timesteps


def test_sim_ctrl_and_prefill_cost_terms():
    """The ``flush=False`` pricing's steady-state cost terms: the gated
    ctrl term scales with the active rate (``ctrl_rate=0`` reproduces
    the old cost exactly), and the separate-prefill term is paid by the
    flush schedule only — the overlapped schedule rides admission in the
    hop."""
    from repro.core import sim

    hw = sim.StageHardware(n_stages=8, t_stage_one=1e-4,
                           t_stage_width=4e-4, t_comm=5e-5, t_draft=1e-4,
                           t_sync=1e-5)
    base = sim.specpipe_db_sharded_timestep(hw, 4)
    assert sim.specpipe_db_sharded_timestep(hw, 4, ctrl_rate=0.0,
                                            t_ctrl=1e-3) == base
    gated = sim.specpipe_db_sharded_timestep(hw, 4, ctrl_rate=0.2,
                                             t_ctrl=1e-3)
    ungated = sim.specpipe_db_sharded_timestep(hw, 4, ctrl_rate=1.0,
                                               t_ctrl=1e-3)
    assert base < gated < ungated
    assert abs((gated - base) - 0.2e-3) < 1e-12
    # prefill: flush pays per admission, overlapped rides the ring
    fl = sim.specpipe_db_sharded_timestep(hw, 4, flush=True)
    fl_adm = sim.specpipe_db_sharded_timestep(hw, 4, flush=True,
                                              prefill_rate=0.5,
                                              t_prefill=2e-3)
    assert abs(fl_adm - (fl + 0.5 * 2e-3)) < 1e-12
    over_adm = sim.specpipe_db_sharded_timestep(hw, 4, prefill_rate=0.5,
                                                t_prefill=2e-3)
    assert over_adm == base


def test_kill_cancels_in_flight_prefill(bundles):
    """A slot killed while its prompt is riding the prefill lane must
    leave the executor clean: the ``DeferredPrefill`` dies (resolve
    raises), ``drain()`` terminates, and the slot can admit a fresh
    prefill."""
    ex = _overlapped(bundles, 1)
    prompt = np.asarray([1, 2, 3], np.int32)
    h = ex.begin_prefill(0, prompt)
    assert h is not None
    ex.kill(0)
    with pytest.raises(RuntimeError, match="killed"):
        h.resolve()
    assert ex.drain() == 0, "no outstanding futures after the kill"
    h2 = ex.begin_prefill(0, prompt)
    assert h2 is not None and not h2.dead


def test_prefix_embeds_bundle_disables_prefill_in_ring(tiny_dense,
                                                       tiny_draft):
    """ModelBundle prefill semantics the raw-token lane cannot express
    (prefix_embeds / enc_out / window_override) must force the
    separate-dispatch fallback."""
    import jax.numpy as jnp

    tp = tf.init_model(jax.random.PRNGKey(0), tiny_dense)
    dp = tf.init_model(jax.random.PRNGKey(9), tiny_draft)
    target = ModelBundle(tp, tiny_dense,
                         prefix_embeds=jnp.zeros((1, 2, tiny_dense.d_model)))
    draft = ModelBundle(dp, tiny_draft)
    ex = OverlappedShardedExecutor(
        target, draft, slots=1, max_len=MAX_LEN,
        tree_capacity=PCFG1.tree_buffer_capacity, capacity=PCFG1.capacity,
        n_stages=1)
    assert ex.prefill_cap == 0
    assert ex.begin_prefill(0, np.asarray([1, 2, 3], np.int32)) is None
